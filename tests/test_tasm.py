"""VideoStore engine + storage + policies end to end (plus the deprecated
TASM shim)."""
import numpy as np
import pytest

from repro.codec.encode import EncoderConfig
from repro.core import (TASM, KQKOPolicy, LazyPolicy, MorePolicy,
                        NoTilingPolicy, PretileAllPolicy, RegretPolicy,
                        VideoStore, uniform_layout)
from repro.core.cost import CostModel

ENC = EncoderConfig(gop=16, qp=8)
# deterministic cost model so policy tests do not depend on host speed
MODEL = CostModel(beta=1.4e-8, gamma=1e-5)
MODEL.encode_per_pixel = 3.4e-8
MODEL.encode_per_tile = 1e-4


def make_store(frames, dets, policy=None, **kw):
    # inline tuning: these are policy-convergence tests — layouts must
    # evolve synchronously inside the scans that trigger them
    store = VideoStore(store_root=kw.pop("store_root", None),
                       tuning="inline")
    store.add_video("v", encoder=ENC, policy=policy or NoTilingPolicy(),
                    cost_model=MODEL, **kw)
    store.ingest("v", frames)
    store.add_detections("v", {f: d for f, d in enumerate(dets)})
    return store


def scan(store, labels, t_range=None, **kw):
    q = store.scan("v").labels(labels)
    if t_range is not None:
        q = q.frames(*t_range)
    return q.execute()


class TestScan:
    def test_scan_returns_correct_pixels(self, small_video):
        frames, dets = small_video
        store = make_store(frames, dets)
        res = scan(store, "car", (0, 16))
        assert res.stats.regions > 0
        for f, box, px in res.regions:
            y1, x1, y2, x2 = box
            src = frames[f, y1:y2, x1:x2]
            assert np.abs(px - src).mean() < 6.0  # lossy but close

    def test_scan_empty_label(self, small_video):
        frames, dets = small_video
        store = make_store(frames, dets)
        res = scan(store, "unicorn")
        assert res.regions == [] and res.stats.pixels_decoded == 0

    def test_temporal_restriction(self, small_video):
        frames, dets = small_video
        store = make_store(frames, dets)
        res = scan(store, "car", (0, 8))
        assert all(f < 8 for f, _, _ in res.regions)

    def test_tiled_scan_decodes_fewer_pixels(self, small_video):
        # under a standard full-tile decoder (roi_decode=False) tiling cuts
        # decoded pixels; with ROI-restricted block decode the pixel count
        # is layout-invariant, which test_roi.py covers separately
        frames, dets = small_video

        def full_tile_store(policy=None):
            store = VideoStore(tuning="inline", roi_decode=False)
            store.add_video("v", encoder=ENC,
                            policy=policy or NoTilingPolicy(),
                            cost_model=MODEL)
            store.ingest("v", frames)
            store.add_detections("v", {f: d for f, d in enumerate(dets)})
            return store

        s1 = full_tile_store()
        p1 = scan(s1, "car", (0, 16)).stats.pixels_decoded
        s2 = full_tile_store(policy=PretileAllPolicy())
        # re-run ingest-time pretile with detections now present
        e2 = s2.video("v")
        for rec_id, lay in e2.policy.on_ingest(e2.index, e2.store, "v",
                                               frames.shape[1:]).items():
            e2.store.retile(rec_id, lay)
        p2 = scan(s2, "car", (0, 16)).stats.pixels_decoded
        assert p2 < p1
        # ROI decode on the untiled store beats even the tiled full decode
        s3 = make_store(frames, dets)
        p3 = scan(s3, "car", (0, 16)).stats.pixels_decoded
        assert p3 <= p2

    def test_what_if_interface(self, small_video):
        frames, dets = small_video
        store = make_store(frames, dets)
        H, W = frames.shape[1:]
        cur = store.what_if("v", "car", {})
        alt = store.what_if("v", "car", {0: uniform_layout(H, W, 2, 2),
                                        1: uniform_layout(H, W, 2, 2)})
        assert alt <= cur  # tiling can only reduce estimated pixels


class TestPolicies:
    def test_regret_retiles_after_repeats(self, small_video):
        frames, dets = small_video
        store = make_store(frames, dets, policy=RegretPolicy())
        for _ in range(8):
            scan(store, "car", (0, 16))
        assert any(rec.layout.n_tiles > 1
                   for rec in store.video("v").store.sots[:1])

    def test_regret_respects_eta(self, small_video):
        frames, dets = small_video
        store = make_store(frames, dets, policy=RegretPolicy(eta=1e9))
        for _ in range(8):
            scan(store, "car", (0, 16))
        assert all(rec.layout.n_tiles == 1
                   for rec in store.video("v").store.sots)

    def test_lazy_tiles_when_locations_known(self, small_video):
        frames, dets = small_video
        store = make_store(frames, dets, policy=LazyPolicy(["car"]))
        scan(store, "car", (0, 16))
        assert store.video("v").store.sots[0].layout.n_tiles > 1

    def test_lazy_waits_for_unknown_objects(self, small_video):
        frames, dets = small_video
        store = VideoStore(tuning="inline")
        store.add_video("v", encoder=ENC,
                        policy=LazyPolicy(["car", "ghost"]), cost_model=MODEL)
        store.ingest("v", frames)
        store.add_detections("v", {f: d for f, d in enumerate(dets)})
        scan(store, "car", (0, 16))
        # 'ghost' never detected: the SOT must remain untiled
        assert store.video("v").store.sots[0].layout.n_tiles == 1

    def test_more_policy_accumulates_labels(self, small_video):
        frames, dets = small_video
        store = make_store(frames, dets, policy=MorePolicy())
        scan(store, "car", (0, 16))
        lay_car = store.video("v").store.sots[0].layout
        scan(store, "person", (0, 16))
        lay_both = store.video("v").store.sots[0].layout
        assert lay_car.n_tiles > 1
        assert lay_both != lay_car  # re-tiled around {car, person}

    def test_kqko_pretile(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        store.add_video("v", encoder=ENC, policy=KQKOPolicy(["car"]),
                        cost_model=MODEL)
        store.add_detections("v", {f: d for f, d in enumerate(dets)})
        store.ingest("v", frames)
        assert any(rec.layout.n_tiles > 1
                   for rec in store.video("v").store.sots)


class TestStorageDisk:
    def test_on_disk_layout(self, small_video, tmp_path):
        frames, dets = small_video
        store = VideoStore(store_root=str(tmp_path))
        store.add_video("v", encoder=ENC, cost_model=MODEL)
        store.ingest("v", frames)
        store.add_detections("v", {f: d for f, d in enumerate(dets)})
        # paper Fig. 1 directory structure
        assert (tmp_path / "v" / "frames_0-15" / "tile0.npz").exists()
        res = scan(store, "car", (0, 16))
        assert res.stats.regions > 0
        # retile rewrites the SOT directory
        H, W = frames.shape[1:]
        store.video("v").store.retile(0, uniform_layout(H, W, 2, 2))
        assert (tmp_path / "v" / "frames_0-15" / "tile3.npz").exists()

    def test_storage_bytes_tracked(self, small_video):
        frames, dets = small_video
        store = make_store(frames, dets)
        assert store.storage_bytes() > 0
        assert store.storage_bytes("v") == store.storage_bytes()


class TestDeprecatedShim:
    """The old single-video TASM facade still works, via VideoStore."""

    def test_shim_warns_and_matches_engine(self, small_video):
        frames, dets = small_video
        with pytest.warns(DeprecationWarning):
            t = TASM("v", ENC, policy=NoTilingPolicy(), cost_model=MODEL)
        t.ingest(frames)
        t.add_detections({f: d for f, d in enumerate(dets)})
        res_old = t.scan("car", (0, 16))

        store = make_store(frames, dets)
        res_new = scan(store, "car", (0, 16))
        assert len(res_old.regions) == len(res_new.regions)
        for (f1, b1, p1), (f2, b2, p2) in zip(res_old.regions,
                                              res_new.regions):
            assert f1 == f2 and b1 == b2
            np.testing.assert_array_equal(p1, p2)
        assert t.storage_bytes() > 0
        assert t.store.sots and t.index.stats()["entries"] > 0
        assert len(t.history) == 1

    def test_shim_ingest_contract(self, small_video):
        frames, dets = small_video
        with pytest.warns(DeprecationWarning):
            t = TASM("v", ENC, policy=PretileAllPolicy(), cost_model=MODEL)
        t.add_detections({f: d for f, d in enumerate(dets)})
        st = t.ingest(frames)
        assert st.encode_s > 0 and st.pretile_s > 0
        assert st.total_s == st.encode_s + st.pretile_s
