"""TASM facade + storage + policies end to end."""
import numpy as np
import pytest

from repro.codec.encode import EncoderConfig
from repro.core import (TASM, KQKOPolicy, LazyPolicy, MorePolicy,
                        NoTilingPolicy, PretileAllPolicy, RegretPolicy,
                        uniform_layout)
from repro.core.cost import CostModel

ENC = EncoderConfig(gop=16, qp=8)
# deterministic cost model so policy tests do not depend on host speed
MODEL = CostModel(beta=1.4e-8, gamma=1e-5)
MODEL.encode_per_pixel = 3.4e-8
MODEL.encode_per_tile = 1e-4


def make_tasm(frames, dets, policy=None, **kw):
    t = TASM("v", ENC, policy=policy or NoTilingPolicy(), cost_model=MODEL, **kw)
    t.ingest(frames)
    t.add_detections({f: d for f, d in enumerate(dets)})
    return t


class TestScan:
    def test_scan_returns_correct_pixels(self, small_video):
        frames, dets = small_video
        t = make_tasm(frames, dets)
        res = t.scan("car", (0, 16))
        assert res.stats.regions > 0
        for f, box, px in res.regions:
            y1, x1, y2, x2 = box
            src = frames[f, y1:y2, x1:x2]
            assert np.abs(px - src).mean() < 6.0  # lossy but close

    def test_scan_empty_label(self, small_video):
        frames, dets = small_video
        t = make_tasm(frames, dets)
        res = t.scan("unicorn")
        assert res.regions == [] and res.stats.pixels_decoded == 0

    def test_temporal_restriction(self, small_video):
        frames, dets = small_video
        t = make_tasm(frames, dets)
        res = t.scan("car", (0, 8))
        assert all(f < 8 for f, _, _ in res.regions)

    def test_tiled_scan_decodes_fewer_pixels(self, small_video):
        frames, dets = small_video
        t1 = make_tasm(frames, dets)
        p1 = t1.scan("car", (0, 16)).stats.pixels_decoded
        t2 = make_tasm(frames, dets, policy=PretileAllPolicy())
        # re-run ingest-time pretile with detections now present
        for rec_id, lay in t2.policy.on_ingest(t2.index, t2.store, "v",
                                               frames.shape[1:]).items():
            t2.store.retile(rec_id, lay)
        p2 = t2.scan("car", (0, 16)).stats.pixels_decoded
        assert p2 < p1

    def test_what_if_interface(self, small_video):
        frames, dets = small_video
        t = make_tasm(frames, dets)
        H, W = frames.shape[1:]
        cur = t.what_if("car", {})
        alt = t.what_if("car", {0: uniform_layout(H, W, 2, 2),
                                1: uniform_layout(H, W, 2, 2)})
        assert alt <= cur  # tiling can only reduce estimated pixels


class TestPolicies:
    def test_regret_retiles_after_repeats(self, small_video):
        frames, dets = small_video
        t = make_tasm(frames, dets, policy=RegretPolicy())
        for _ in range(8):
            t.scan("car", (0, 16))
        assert any(rec.layout.n_tiles > 1 for rec in t.store.sots[:1])

    def test_regret_respects_eta(self, small_video):
        frames, dets = small_video
        t = make_tasm(frames, dets, policy=RegretPolicy(eta=1e9))
        for _ in range(8):
            t.scan("car", (0, 16))
        assert all(rec.layout.n_tiles == 1 for rec in t.store.sots)

    def test_lazy_tiles_when_locations_known(self, small_video):
        frames, dets = small_video
        t = make_tasm(frames, dets, policy=LazyPolicy(["car"]))
        t.scan("car", (0, 16))
        assert t.store.sots[0].layout.n_tiles > 1

    def test_lazy_waits_for_unknown_objects(self, small_video):
        frames, dets = small_video
        t = TASM("v", ENC, policy=LazyPolicy(["car", "ghost"]),
                 cost_model=MODEL)
        t.ingest(frames)
        t.add_detections({f: d for f, d in enumerate(dets)})
        t.scan("car", (0, 16))
        # 'ghost' never detected: the SOT must remain untiled
        assert t.store.sots[0].layout.n_tiles == 1

    def test_more_policy_accumulates_labels(self, small_video):
        frames, dets = small_video
        t = make_tasm(frames, dets, policy=MorePolicy())
        t.scan("car", (0, 16))
        lay_car = t.store.sots[0].layout
        t.scan("person", (0, 16))
        lay_both = t.store.sots[0].layout
        assert lay_car.n_tiles > 1
        assert lay_both != lay_car  # re-tiled around {car, person}

    def test_kqko_pretile(self, small_video):
        frames, dets = small_video
        t = TASM("v", ENC, policy=KQKOPolicy(["car"]), cost_model=MODEL)
        t.add_detections({f: d for f, d in enumerate(dets)})
        t.ingest(frames)
        assert any(rec.layout.n_tiles > 1 for rec in t.store.sots)


class TestStorageDisk:
    def test_on_disk_layout(self, small_video, tmp_path):
        frames, dets = small_video
        t = TASM("v", ENC, cost_model=MODEL, store_root=str(tmp_path))
        t.ingest(frames)
        t.add_detections({f: d for f, d in enumerate(dets)})
        # paper Fig. 1 directory structure
        assert (tmp_path / "v" / "frames_0-15" / "tile0.npz").exists()
        res = t.scan("car", (0, 16))
        assert res.stats.regions > 0
        # retile rewrites the SOT directory
        H, W = frames.shape[1:]
        t.store.retile(0, uniform_layout(H, W, 2, 2))
        assert (tmp_path / "v" / "frames_0-15" / "tile3.npz").exists()

    def test_storage_bytes_tracked(self, small_video):
        frames, dets = small_video
        t = make_tasm(frames, dets)
        assert t.storage_bytes() > 0
