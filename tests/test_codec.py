"""Codec: round-trip quality, GOP random access, tile independence, size
model behaviour, PSNR sanity."""
import numpy as np
import pytest

from repro.codec.encode import EncoderConfig, decode_tile, encode_tile
from repro.codec.psnr import psnr


@pytest.fixture(scope="module")
def video(sparse_video):
    return sparse_video[0]  # [64, 96, 160]


def test_roundtrip_quality(video):
    enc = encode_tile(video, EncoderConfig(qp=8))
    rec = decode_tile(enc)
    assert rec.shape == video.shape
    assert psnr(video, rec) > 38.0


def test_qp_quality_tradeoff(video):
    q_lo = encode_tile(video, EncoderConfig(qp=2))
    q_hi = encode_tile(video, EncoderConfig(qp=24))
    assert q_lo["size_bytes"] > q_hi["size_bytes"]
    assert psnr(video, decode_tile(q_lo)) > psnr(video, decode_tile(q_hi))


def test_gop_random_access(video):
    """Decoding GOP k alone must equal the same frames from a full decode."""
    cfg = EncoderConfig(gop=16, qp=8)
    enc = encode_tile(video, cfg)
    full = decode_tile(enc)
    for g in (1, 3):
        part = decode_tile(enc, gop_indices=[g])
        np.testing.assert_allclose(part, full[g * 16:(g + 1) * 16], atol=1e-4)


def test_tile_independence(video):
    """A tile encoded alone decodes identically to itself (no cross-tile
    references) and close to the source region."""
    region = np.ascontiguousarray(video[:, 32:64, 48:112])
    enc = encode_tile(region, EncoderConfig(qp=8))
    rec = decode_tile(enc)
    assert psnr(region, rec) > 36.0


def test_shorter_gops_cost_more_bytes(video):
    small = encode_tile(video, EncoderConfig(gop=8, qp=8))
    large = encode_tile(video, EncoderConfig(gop=32, qp=8))
    assert small["size_bytes"] > large["size_bytes"]


def test_keyframe_larger_than_p_frames(video):
    enc = encode_tile(video, EncoderConfig(qp=8))
    from repro.codec.bitstream import stream_bytes_np

    k = stream_bytes_np(enc["kq"][0])
    p = stream_bytes_np(enc["pq"][0][0])
    assert k > p


def test_psnr_identity():
    x = np.random.default_rng(0).uniform(0, 255, (4, 16, 16)).astype(np.float32)
    assert psnr(x, x) == 99.0
    assert psnr(x, x + 10) < 40


def test_partial_gop_decode(video):
    """frames_within must equal the prefix of the full GOP decode."""
    cfg = EncoderConfig(gop=16, qp=8)
    enc = encode_tile(video, cfg)
    full = decode_tile(enc, gop_indices=[1])
    part = decode_tile(enc, gop_indices=[1], frames_within=5)
    assert part.shape[0] == 5
    np.testing.assert_allclose(part, full[:5], atol=1e-4)
