"""Batched fused decode: bit-identity of the "batched" backend against the
numpy oracle — the kernel batch op, ``decode_tile_batch``, and every engine
path that can reach ``TileStore.decode_tiles`` (serial scans, merged
``execute_many`` batches, serve sessions, mid-batch retiles)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.codec.batch import decode_tile_batch
from repro.codec.encode import EncoderConfig, decode_tile, encode_tile
from repro.core import (NoTilingPolicy, RegretPolicy, VideoStore,
                        uniform_layout)
from repro.core.cost import CostModel
from repro.core.storage import TileStore
from repro.kernels.decode import MIN_COLUMNS, pad_bucket

ENC = EncoderConfig(gop=16, qp=8)
MODEL = CostModel(beta=1.4e-8, gamma=1e-5)
MODEL.encode_per_pixel = 3.4e-8
MODEL.encode_per_tile = 1e-4


def fill(store, name, frames, dets, policy=None):
    store.add_video(name, encoder=ENC, policy=policy or NoTilingPolicy(),
                    cost_model=MODEL)
    store.ingest(name, frames)
    store.add_detections(name, {f: d for f, d in enumerate(dets)})


def assert_regions_equal(a, b):
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        assert ra[:-1] == rb[:-1]
        np.testing.assert_array_equal(ra[-1], rb[-1])


# ------------------------------------------------------------- pad_bucket
@settings(max_examples=60, deadline=None)
@given(st.integers(1, 1 << 16), st.sampled_from([1, 8, 64]))
def test_pad_bucket_properties(n, lo):
    b = pad_bucket(n, lo)
    assert b >= n and b >= lo
    assert b & (b - 1) == 0 or b == lo  # power of two (or the floor)
    assert pad_bucket(b, lo) == b       # idempotent
    if n > lo:
        assert b < 2 * n                # never more than one octave up


def test_pad_bucket_bounds_trace_count():
    # any workload's distinct padded sizes grow logarithmically
    sizes = {pad_bucket(n, MIN_COLUMNS) for n in range(1, 5000)}
    assert len(sizes) <= 8


# ----------------------------------------------- decode_tile_batch oracle
def _rand_enc(rng, h, w, gop, qp, n_gops):
    frames = (rng.random((n_gops * gop, h, w), dtype=np.float32) * 255.0)
    return encode_tile(frames, EncoderConfig(gop=gop, qp=qp))


layout_st = st.tuples(st.integers(1, 4), st.integers(1, 4),
                      st.integers(1, 3), st.sampled_from([4, 8]),
                      st.sampled_from([4, 8, 12]))


@settings(max_examples=12, deadline=None)
@given(st.lists(layout_st, min_size=1, max_size=6), st.integers(0, 999))
def test_batch_bit_identical_to_decode_tile(specs, seed):
    rng = np.random.default_rng(seed)
    items = []
    for bh, bw, n_gops, gop, qp in specs:
        h, w = bh * 8, bw * 8
        enc = _rand_enc(rng, h, w, gop, qp, n_gops)
        # random GOP subset, tail depth, and ROI mask (sometimes full)
        gsel = sorted(rng.choice(n_gops, size=rng.integers(1, n_gops + 1),
                                 replace=False).tolist())
        fw = (None if rng.random() < 0.5
              else int(rng.integers(1, gop + 1)))
        nb = bh * bw
        roll = rng.random()
        if roll < 0.4:
            blocks = None                        # full tile
        elif roll < 0.5:
            blocks = tuple(range(nb))            # mask == every block
        else:
            k = int(rng.integers(1, nb + 1))
            blocks = tuple(sorted(
                rng.choice(nb, size=k, replace=False).tolist()))
        items.append((enc, gsel, fw, blocks))
    got = decode_tile_batch(items)
    for (enc, gsel, fw, blocks), arr in zip(items, got):
        want = decode_tile(enc, gop_indices=gsel, frames_within=fw,
                           blocks=blocks)
        assert arr.dtype == want.dtype and arr.shape == want.shape
        np.testing.assert_array_equal(arr, want)


class TestDecodeTileBatchOracle:
    def test_pallas_interpret_matches_oracle(self):
        # the TPU kernel path, interpreted on CPU: same contract
        rng = np.random.default_rng(7)
        items = []
        for bh, bw, n_gops in [(1, 1, 1), (2, 3, 2), (4, 2, 1)]:
            enc = _rand_enc(rng, bh * 8, bw * 8, 8, 8, n_gops)
            items.append((enc, list(range(n_gops)), None, None))
        items.append((items[1][0], [0], 3, (0, 2, 5)))
        got = decode_tile_batch(items, use_pallas=True, interpret=True)
        for (enc, gsel, fw, blocks), arr in zip(items, got):
            np.testing.assert_array_equal(
                arr, decode_tile(enc, gop_indices=gsel, frames_within=fw,
                                 blocks=blocks))

    def test_degenerate_items(self):
        rng = np.random.default_rng(3)
        enc = _rand_enc(rng, 16, 16, 4, 8, 2)
        got = decode_tile_batch([
            (enc, [], None, None),          # no GOPs selected
            (enc, [0], None, ()),           # empty ROI mask
            (enc, [0, 1], 1, None),         # single-frame prefix
        ])
        assert got[0].shape == (0, 16, 16)
        np.testing.assert_array_equal(
            got[1], decode_tile(enc, gop_indices=[0], blocks=()))
        np.testing.assert_array_equal(
            got[2], decode_tile(enc, gop_indices=[0, 1], frames_within=1))


# ------------------------------------------------ TileStore backend parity
class TestStoreBackends:
    def _pair(self, frames, layout=None):
        stores = []
        for backend in ("numpy", "batched"):
            ts = TileStore("v", ENC, sot_len=32, decode_backend=backend)
            ts.ingest(frames)
            if layout is not None:
                ts.retile(0, layout)
            stores.append(ts)
        return stores

    def test_decode_tiles_identical_with_depths_and_masks(self, small_video):
        frames, _ = small_video
        H, W = frames.shape[1:]
        a, b = self._pair(frames, uniform_layout(H, W, 3, 4))
        base_a, base_b = a.tiles_decoded_total, b.tiles_decoded_total
        depths = {0: 5, 1: 16, 2: 32, 5: 23, 11: 1}
        masks = {0: (0, 1, 7), 2: None, 5: tuple(range(10))}
        tiles = sorted(depths)
        da = a.decode_tiles(0, tiles, n_frames=depths, blocks=masks)
        db = b.decode_tiles(0, tiles, n_frames=depths, blocks=masks)
        assert sorted(da) == sorted(db) == tiles
        for t in tiles:
            assert da[t].shape[0] == depths[t]
            np.testing.assert_array_equal(da[t], db[t])
        assert (a.tiles_decoded_total - base_a ==
                b.tiles_decoded_total - base_b == len(tiles))
        assert a.pixels_decoded_total == b.pixels_decoded_total

    def test_full_sot_roundtrip_identical(self, small_video):
        frames, _ = small_video
        H, W = frames.shape[1:]
        a, b = self._pair(frames, uniform_layout(H, W, 2, 2))
        np.testing.assert_array_equal(a.decode_full_sot(0),
                                      b.decode_full_sot(0))

    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError, match="decode_backend"):
            TileStore("v", ENC, decode_backend="cuda")
        with pytest.raises(ValueError, match="decode_backend"):
            VideoStore(decode_backend="cuda")

    def test_env_override_selects_backend(self, monkeypatch):
        monkeypatch.setenv("REPRO_DECODE_BACKEND", "batched")
        assert VideoStore().decode_backend == "batched"
        # an explicit argument wins over the environment
        assert VideoStore(decode_backend="numpy").decode_backend == "numpy"


# ----------------------------------------------- engine paths, both backends
def _pair_stores(frames, dets, *, policy=None, **kw):
    out = []
    for backend in ("numpy", "batched"):
        s = VideoStore(decode_backend=backend, **kw)
        fill(s, "cam0", frames, dets,
             policy=policy() if policy else None)
        out.append(s)
    return out


class TestEngineBackendParity:
    def test_serial_scans_identical(self, small_video):
        frames, dets = small_video
        H, W = frames.shape[1:]
        a, b = _pair_stores(frames, dets)
        for s in (a, b):
            s.retile("cam0", 0, uniform_layout(H, W, 3, 4))
        queries = [("car", (0, 32)), ("person", (3, 21)), ("car", (10, 11))]
        for lbl, fr in queries:
            ra = a.scan("cam0").labels(lbl).frames(*fr).execute()
            rb = b.scan("cam0").labels(lbl).frames(*fr).execute()
            assert_regions_equal(ra.regions, rb.regions)
            assert ra.stats.pixels_decoded == rb.stats.pixels_decoded
            assert ra.stats.tiles_fetched == rb.stats.tiles_fetched
        sa, sb = a.video("cam0").store, b.video("cam0").store
        assert sa.tiles_decoded_total == sb.tiles_decoded_total
        assert sa.pixels_decoded_total == sb.pixels_decoded_total

    def test_execute_many_merged_batch_identical(self, small_video):
        frames, dets = small_video
        H, W = frames.shape[1:]
        a, b = _pair_stores(frames, dets)
        for s in (a, b):
            s.retile("cam0", 0, uniform_layout(H, W, 2, 3))
        queries = [("car", (0, 32)), ("car", (0, 5)), ("person", (8, 30)),
                   ("car", (12, 19))]
        ra = a.execute_many(
            [a.scan("cam0").labels(l).frames(*fr) for l, fr in queries])
        rb = b.execute_many(
            [b.scan("cam0").labels(l).frames(*fr) for l, fr in queries])
        for x, y in zip(ra, rb):
            assert_regions_equal(x.regions, y.regions)
            assert x.stats.cache_misses == y.stats.cache_misses
        sa, sb = a.video("cam0").store, b.video("cam0").store
        assert sa.tiles_decoded_total == sb.tiles_decoded_total
        assert sa.pixels_decoded_total == sb.pixels_decoded_total

    def test_mid_batch_retile_identical(self, small_video):
        frames, dets = small_video
        a, b = _pair_stores(frames, dets, policy=RegretPolicy,
                            tuning="inline", tile_cache_bytes=0)
        n = 10  # enough repeats to push RegretPolicy over its threshold
        ra = a.execute_many(
            [a.scan("cam0").labels("car").frames(0, 32) for _ in range(n)])
        rb = b.execute_many(
            [b.scan("cam0").labels("car").frames(0, 32) for _ in range(n)])
        assert any(r.stats.retile_s > 0 for r in ra)  # it retiled
        for x, y in zip(ra, rb):
            assert_regions_equal(x.regions, y.regions)
        layouts = lambda s: [(r.layout, r.epoch)
                             for r in s.video("cam0").store.sots]
        assert layouts(a) == layouts(b)

    def test_serve_session_identical(self, small_video):
        frames, dets = small_video
        a, b = _pair_stores(frames, dets)
        results = []
        for s in (a, b):
            with s.serve() as session:
                futs = [session.submit(
                    s.scan("cam0").labels("car").frames(0, 32))
                    for _ in range(6)]
                results.append([f.result(timeout=60) for f in futs])
        for x, y in zip(*results):
            assert_regions_equal(x.regions, y.regions)
        sa, sb = a.video("cam0").store, b.video("cam0").store
        assert sa.tiles_decoded_total == sb.tiles_decoded_total
        assert sa.pixels_decoded_total == sb.pixels_decoded_total
