"""Test fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see 1 device;
multi-device tests run in subprocesses (tests/test_distributed.py)."""
import numpy as np
import pytest

import _hypothesis_compat

# the container has no `hypothesis`; install the API-compatible shim so the
# property-test modules collect and run (no-op when the real package exists)
_hypothesis_compat.install()


@pytest.fixture(scope="session")
def sparse_video():
    from repro.data.video_gen import generate, sparse_spec

    spec = sparse_spec(seed=3, n_frames=64, height=96, width=160)
    frames, dets = generate(spec)
    return frames, dets


@pytest.fixture(scope="session")
def small_video():
    from repro.data.video_gen import VideoSpec, ObjectSpec, generate

    spec = VideoSpec(height=96, width=160, n_frames=32, seed=5,
                     objects=[ObjectSpec("car", 2, (16, 24), 2.0),
                              ObjectSpec("person", 1, (18, 10), 1.0)])
    frames, dets = generate(spec)
    return frames, dets
