"""B+-tree vs dict oracle (hypothesis)."""
from hypothesis import given, settings, strategies as st

from repro.core.btree import BPlusTree

key_st = st.tuples(st.sampled_from(["v1", "v2"]),
                   st.sampled_from(["car", "person", "boat"]),
                   st.integers(0, 200))


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(key_st, st.integers()), max_size=200),
       st.integers(4, 9))
def test_btree_matches_dict(items, order):
    tree = BPlusTree(order=order)
    oracle: dict = {}
    for k, v in items:
        tree.insert(k, v)
        oracle.setdefault(k, []).append(v)
    # point lookups
    for k, vs in oracle.items():
        assert tree.get(k) == vs
    # full ordering
    assert list(tree.keys()) == sorted(oracle.keys())
    # range scans
    keys = sorted(oracle)
    if keys:
        lo, hi = keys[0], keys[-1]
        got = {k: vs for k, vs in tree.scan(lo, hi)}
        expect = {k: oracle[k] for k in oracle if lo <= k < hi}
        assert got == expect


def test_scan_is_sorted_and_bounded():
    tree = BPlusTree(order=4)
    for f in range(100):
        tree.insert(("v", "car", f), f)
    got = list(tree.scan(("v", "car", 10), ("v", "car", 20)))
    assert [k[2] for k, _ in got] == list(range(10, 20))


def test_depth_grows_logarithmically():
    tree = BPlusTree(order=8)
    for i in range(2000):
        tree.insert(("v", "l", i), i)
    assert tree.depth() <= 5
