"""Background physical tuner: observation emission off the scan path,
drain barrier, coalescing, racing-scan bit-identity, policy runtime-state
persistence (manifest v3), and crash-safe log ordering."""
import json
import threading

import numpy as np
import pytest

from repro.codec.encode import EncoderConfig
from repro.core import (MorePolicy, NoTilingPolicy, RegretPolicy, VideoStore,
                        uniform_layout)
from repro.core.cost import CostModel
from repro.core.policies import Policy

ENC = EncoderConfig(gop=16, qp=8)
MODEL = CostModel(beta=1.4e-8, gamma=1e-5)
MODEL.encode_per_pixel = 3.4e-8
MODEL.encode_per_tile = 1e-4


def fill(store, name, frames, dets, policy=None):
    store.add_video(name, encoder=ENC, policy=policy or NoTilingPolicy(),
                    cost_model=MODEL)
    store.ingest(name, frames)
    store.add_detections(name, {f: d for f, d in enumerate(dets)})


def assert_regions_equal(a, b):
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        assert ra[:-1] == rb[:-1]
        np.testing.assert_array_equal(ra[-1], rb[-1])


def layouts_of(store, name="v"):
    return [(tuple(r.layout.heights), tuple(r.layout.widths), r.epoch)
            for r in store.video(name).store.sots]


class CyclingPolicy(Policy):
    """Test stub: proposes the next layout from a fixed cycle on every
    observation (so repeated observations of one SOT produce *distinct*
    proposals, exercising coalescing)."""

    name = "cycling"
    stateful = False

    def __init__(self, layouts):
        self.layouts = list(layouts)
        self.i = 0

    def observe(self, q, index, store, model):
        lay = self.layouts[self.i % len(self.layouts)]
        self.i += 1
        return lay


# -------------------------------------------------------------- scan path
class TestScanPathOffloading:
    def test_background_queries_never_charged_retile(self, small_video):
        frames, dets = small_video
        store = VideoStore(tile_cache_bytes=0)  # background is the default
        fill(store, "v", frames, dets, policy=RegretPolicy())
        res = [store.scan("v").labels("car").frames(0, 16).execute()
               for _ in range(8)]
        # the scan path never pays re-encode latency ...
        assert all(r.stats.retile_s == 0.0 for r in res)
        st = store.drain_tuner()
        # ... but tuning happened: observations replayed, a retile applied
        assert st.observed == 8 and st.applied >= 1 and st.retile_s > 0
        assert store.video("v").store.sots[0].layout.n_tiles > 1
        store.close()

    def test_inline_preserves_synchronous_semantics(self, small_video):
        frames, dets = small_video
        store = VideoStore(tile_cache_bytes=0, tuning="inline")
        fill(store, "v", frames, dets, policy=RegretPolicy())
        res = [store.scan("v").labels("car").frames(0, 16).execute()
               for _ in range(8)]
        assert any(r.stats.retile_s > 0 for r in res)  # charged to the query
        st = store.tuner_stats()
        assert st.observed == 8 and st.applied >= 1
        # TunerStats mirror the per-query charges exactly
        assert st.retile_s == pytest.approx(
            sum(r.stats.retile_s for r in res))
        assert store.tuner.backlog == 0  # inline never queues

    def test_tuning_off_disables_query_driven_tuning(self, small_video):
        frames, dets = small_video
        pol = RegretPolicy()
        store = VideoStore(tile_cache_bytes=0, tuning="off")
        fill(store, "v", frames, dets, policy=pol)
        for _ in range(8):
            store.scan("v").labels("car").frames(0, 16).execute()
        store.drain_tuner()  # no-op
        assert not pol.seen  # the policy never saw a query
        assert all(r.layout.n_tiles == 1 for r in store.video("v").store.sots)
        assert store.tuner_stats().observed == 0

    def test_unknown_tuning_mode_rejected(self):
        with pytest.raises(ValueError, match="tuning"):
            VideoStore(tuning="lazy")

    def test_no_emission_for_inert_policies(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "v", frames, dets)  # NoTilingPolicy: base observe
        store.scan("v").labels("car").frames(0, 16).execute()
        assert store.tuner_stats().observed == 0  # log never woke the tuner
        assert store.tuner._thread is None


# ------------------------------------------------------------ drain barrier
class TestDrainBarrier:
    def test_drain_is_a_true_barrier(self, small_video):
        frames, dets = small_video
        store = VideoStore(tile_cache_bytes=0)
        pol = RegretPolicy()
        fill(store, "v", frames, dets, policy=pol)
        for _ in range(8):
            store.scan("v").labels("car").frames(0, 32).execute()
        st = store.drain_tuner(timeout=60)
        # after the barrier: log empty, every observation replayed through
        # the policy, surviving proposals applied
        assert store.tuner.backlog == 0
        assert st.observed == 16  # 8 scans x 2 SOTs
        assert pol.seen == {"car"}
        assert st.applied >= 1
        assert store.video("v").store.sots[0].layout.n_tiles > 1

    def test_drain_noop_for_inline_and_off(self, small_video):
        frames, dets = small_video
        for mode in ("inline", "off"):
            store = VideoStore(tuning=mode)
            fill(store, "v", frames, dets, policy=RegretPolicy())
            store.scan("v").labels("car").frames(0, 16).execute()
            store.drain_tuner(timeout=1)  # returns immediately

    def test_per_query_drain_matches_inline_exactly(self, small_video):
        """With a drain after every query the tuner replays observations at
        the inline cadence, so layouts, epochs, storage bytes, and scan
        results are all identical to tuning='inline'."""
        frames, dets = small_video
        queries = [("car", (0, 32))] * 6 + [("person", (0, 32))] * 4 \
            + [("car", (0, 16))] * 2

        inline = VideoStore(tile_cache_bytes=0, tuning="inline")
        fill(inline, "v", frames, dets, policy=RegretPolicy())
        ires = [inline.scan("v").labels(l).frames(*fr).execute()
                for l, fr in queries]
        assert any(r.stats.retile_s > 0 for r in ires)

        bg = VideoStore(tile_cache_bytes=0, tuning="background")
        fill(bg, "v", frames, dets, policy=RegretPolicy())
        bres = []
        for l, fr in queries:
            bres.append(bg.scan("v").labels(l).frames(*fr).execute())
            bg.drain_tuner(timeout=60)
        assert all(r.stats.retile_s == 0 for r in bres)

        assert layouts_of(bg) == layouts_of(inline)
        assert bg.storage_bytes() == inline.storage_bytes()
        for ri, rb in zip(ires, bres):
            assert_regions_equal(ri.regions, rb.regions)
        bg.close(), inline.close()

    def test_overflow_never_evicts_inflight_batch_members(self, small_video):
        """A bounded-log overflow racing an in-flight batch must only drop
        not-yet-taken observations — never batch members (which would make
        the fixed-size post-persist drop destroy a newer, unprocessed
        observation and break the drain() barrier contract)."""
        frames, dets = small_video
        store = VideoStore(tile_cache_bytes=0)
        pol = RegretPolicy()
        fill(store, "v", frames, dets, policy=pol)
        store.tuner.pause()
        for _ in range(3):
            store.scan("v").labels("car").frames(0, 16).execute()
        # take the batch exactly as the worker thread would
        batch = store.tuner._take_batch()
        assert len(batch) == 3 and store.tuner.backlog == 3
        # overflow while the batch is in flight: the new observation must
        # land (and survive) even though log+inflight exceed max_log
        store.tuner.max_log = 1
        store.scan("v").labels("person").frames(0, 16).execute()
        assert store.tuner.backlog == 4
        store.tuner._process_batch(batch)
        # the in-flight batch is gone, the raced observation is intact
        assert store.tuner.backlog == 1
        assert store.tuner._log[0].labels == ("person",)
        assert pol.seen == {"car"}  # batch replayed, new obs not yet
        store.tuner.resume()
        store.drain_tuner(timeout=60)
        assert pol.seen == {"car", "person"}
        store.close()

    def test_bounded_log_drops_oldest(self, small_video):
        frames, dets = small_video
        store = VideoStore(tile_cache_bytes=0)
        fill(store, "v", frames, dets, policy=RegretPolicy())
        store.tuner.pause()
        store.tuner.max_log = 4
        for _ in range(6):
            store.scan("v").labels("car").frames(0, 16).execute()
        st = store.tuner_stats()
        assert store.tuner.backlog == 4  # bounded
        assert st.observed == 6 and st.dropped == 2
        store.tuner.resume()
        store.drain_tuner(timeout=60)
        assert store.tuner.backlog == 0


# -------------------------------------------------------------- coalescing
class TestCoalescing:
    def test_applies_only_newest_proposal_per_sot(self, small_video):
        frames, dets = small_video
        H, W = frames.shape[1:]
        cycle = [uniform_layout(H, W, 2, 2), uniform_layout(H, W, 3, 2),
                 uniform_layout(H, W, 2, 4)]
        store = VideoStore(tile_cache_bytes=0)
        fill(store, "v", frames, dets, policy=CyclingPolicy(cycle))
        store.tuner.pause()  # build one multi-observation batch
        for _ in range(3):
            store.scan("v").labels("car").frames(0, 16).execute()
        assert store.tuner.backlog == 3
        store.tuner.resume()
        st = store.drain_tuner(timeout=60)
        # three distinct proposals for SOT 0, one re-encode: the newest
        assert st.proposals == 3 and st.coalesced == 2 and st.applied == 1
        rec = store.video("v").store.sots[0]
        assert rec.epoch == 1
        assert rec.layout == cycle[2]

    def test_coalesced_noop_is_skipped(self, small_video):
        frames, dets = small_video
        H, W = frames.shape[1:]
        lay = uniform_layout(H, W, 2, 2)
        store = VideoStore(tile_cache_bytes=0)
        fill(store, "v", frames, dets, policy=CyclingPolicy([lay]))
        store.retile("v", 0, lay)  # a foreground retile got there first
        store.scan("v").labels("car").frames(0, 16).execute()
        st = store.drain_tuner(timeout=60)
        assert st.proposals == 1 and st.applied == 0 and st.skipped == 1
        assert store.video("v").store.sots[0].epoch == 1  # no second bump


# ------------------------------------------------- racing scans/sessions
class TestBackgroundRaces:
    def test_scans_racing_background_retiles_bit_identical(self, small_video):
        """Scans racing the tuner's retiles return regions bit-identical to
        a serial inline execution: epoch-consistent fetches + the
        block-aligned codec (reconstruction is layout-invariant)."""
        frames, dets = small_video
        queries = ([("car", (0, 32))] * 4 + [("person", (0, 32))] * 4
                   + [("car", (0, 32))] * 4)

        serial = VideoStore(tile_cache_bytes=0, tuning="inline")
        fill(serial, "v", frames, dets, policy=RegretPolicy())
        want = [serial.scan("v").labels(l).frames(*fr).execute()
                for l, fr in queries]

        bg = VideoStore(tuning="background")  # cache ON: epochs invalidate
        fill(bg, "v", frames, dets, policy=RegretPolicy())
        got = [bg.scan("v").labels(l).frames(*fr).execute()
               for l, fr in queries]  # tuner retiles concurrently
        bg.drain_tuner(timeout=60)
        for w, g in zip(want, got):
            assert_regions_equal(w.regions, g.regions)
        bg.close(), serial.close()

    def test_serve_session_racing_background_tuner(self, small_video):
        frames, dets = small_video
        serial = VideoStore(tile_cache_bytes=0, tuning="inline")
        fill(serial, "v", frames, dets, policy=RegretPolicy())
        want = serial.scan("v").labels("car").frames(0, 32).execute()

        bg = VideoStore(tuning="background")
        fill(bg, "v", frames, dets, policy=RegretPolicy())
        with bg.serve() as session:
            futs = [session.submit(
                bg.scan("v").labels("car").frames(0, 32))
                for _ in range(8)]
            results = [f.result(timeout=60) for f in futs]
        bg.drain_tuner(timeout=60)
        for r in results:
            assert r.stats.retile_s == 0.0
            assert_regions_equal(want.regions, r.regions)
        bg.close(), serial.close()

    def test_concurrent_scans_and_drains(self, small_video):
        frames, dets = small_video
        store = VideoStore(tile_cache_bytes=0)
        fill(store, "v", frames, dets, policy=RegretPolicy())
        expected = len(
            store.scan("v").labels("car").frames(0, 32).execute().regions)
        errors, results = [], []
        lock = threading.Lock()

        def scan_loop():
            try:
                for _ in range(5):
                    r = store.scan("v").labels("car").frames(0, 32).execute()
                    with lock:
                        results.append(r)
            except Exception as e:  # pragma: no cover - failure path
                errors.append(e)

        def drain_loop():
            try:
                for _ in range(5):
                    store.drain_tuner(timeout=60)
            except Exception as e:  # pragma: no cover - failure path
                errors.append(e)

        threads = [threading.Thread(target=scan_loop) for _ in range(3)] \
            + [threading.Thread(target=drain_loop)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        store.drain_tuner(timeout=60)
        assert not errors and len(results) == 15
        for r in results:
            assert len(r.regions) == expected
            for f, (y1, x1, y2, x2), px in r.regions:
                assert np.abs(px - frames[f, y1:y2, x1:x2]).mean() < 6.0
        store.close()

    def test_failing_policy_surfaces_at_drain_not_silently(self,
                                                           small_video):
        frames, dets = small_video

        class ExplodingPolicy(Policy):
            name = "exploding"

            def __init__(self):
                self.calls = 0

            def observe(self, q, index, store, model):
                self.calls += 1
                if self.calls == 1:
                    raise RuntimeError("boom")
                return None

        store = VideoStore(tile_cache_bytes=0)
        fill(store, "v", frames, dets, policy=ExplodingPolicy())
        store.scan("v").labels("car").frames(0, 16).execute()
        with pytest.raises(RuntimeError, match="boom"):
            store.drain_tuner(timeout=60)
        # the failing batch was dropped, the tuner stays alive
        store.scan("v").labels("car").frames(0, 16).execute()
        store.drain_tuner(timeout=60)  # no error left to re-raise
        assert store.tuner.backlog == 0
        store.close()

    def test_close_flushes_pending_tuning(self, small_video):
        frames, dets = small_video
        store = VideoStore(tile_cache_bytes=0)
        pol = RegretPolicy()
        fill(store, "v", frames, dets, policy=pol)
        store.tuner.pause()  # force the backlog to survive until close
        for _ in range(8):
            store.scan("v").labels("car").frames(0, 16).execute()
        assert store.tuner.backlog == 8
        store.close()  # stops the thread AND flushes the log
        assert store.tuner.backlog == 0
        assert pol.seen == {"car"}
        assert store.video("v").store.sots[0].layout.n_tiles > 1


# ------------------------------------------- manifest v3 / policy state
class TestPolicyStatePersistence:
    def test_regret_state_roundtrips_across_reopen(self, small_video,
                                                   tmp_path):
        frames, dets = small_video
        store = VideoStore(store_root=str(tmp_path), tile_cache_bytes=0,
                           tuning="inline")
        fill(store, "v", frames, dets, policy=RegretPolicy())
        for _ in range(4):
            store.scan("v").labels("car").frames(0, 32).execute()
        store.close()
        state = store.video("v").policy.state_dict()
        assert state["regret"] and state["seen"] == ["car"]

        reopened = VideoStore(store_root=str(tmp_path), tile_cache_bytes=0)
        pol = reopened.video("v").policy
        # resumes from persisted regret, not cold
        assert pol.state_dict() == state
        assert pol.regret and pol.seen == {"car"}

    def test_more_policy_seen_set_roundtrips(self, small_video, tmp_path):
        frames, dets = small_video
        store = VideoStore(store_root=str(tmp_path), tile_cache_bytes=0,
                           tuning="inline")
        fill(store, "v", frames, dets, policy=MorePolicy())
        store.scan("v").labels("car").frames(0, 16).execute()
        store.scan("v").labels("person").frames(0, 16).execute()
        store.close()
        reopened = VideoStore(store_root=str(tmp_path))
        assert reopened.video("v").policy.seen == {"car", "person"}

    def test_background_tuner_persists_state(self, small_video, tmp_path):
        frames, dets = small_video
        store = VideoStore(store_root=str(tmp_path), tile_cache_bytes=0)
        fill(store, "v", frames, dets, policy=RegretPolicy())
        for _ in range(4):
            store.scan("v").labels("car").frames(0, 32).execute()
        store.drain_tuner(timeout=60)
        # the drain persisted the shard: reopen WITHOUT closing the first
        # store and the replayed observations are already durable
        reopened = VideoStore(store_root=str(tmp_path))
        assert reopened.video("v").policy.state_dict() == \
            store.video("v").policy.state_dict()
        store.close()

    def test_v2_manifest_migrates_to_v3_on_open(self, small_video, tmp_path):
        frames, dets = small_video
        store = VideoStore(store_root=str(tmp_path), tile_cache_bytes=0,
                           tuning="inline")
        fill(store, "v", frames, dets, policy=RegretPolicy())
        for _ in range(8):
            store.scan("v").labels("car").frames(0, 32).execute()
        res1 = store.scan("v").labels("car").frames(0, 32).execute()
        store.close()

        # rewrite the on-disk state in the v2 format (no policy_state)
        shard = tmp_path / "v" / "manifest.json"
        doc = json.loads(shard.read_text())
        doc.pop("policy_state")
        doc["version"] = 2
        shard.write_text(json.dumps(doc))
        cat_path = tmp_path / "catalog.json"
        cat = json.loads(cat_path.read_text())
        cat["version"] = 2
        cat_path.write_text(json.dumps(cat))

        store2 = VideoStore(store_root=str(tmp_path), tile_cache_bytes=0,
                            tuning="inline")
        # adopted without re-ingest: layouts and pixels survive
        assert layouts_of(store2) == layouts_of(store)
        res2 = store2.scan("v").labels("car").frames(0, 32).execute()
        assert_regions_equal(res1.regions, res2.regions)
        # v2 carried no runtime state: the policy restarts cold ...
        assert store2.video("v").policy.state_dict()["regret"] == []
        # ... and the shards were rewritten as v3 on open
        assert json.loads(shard.read_text())["version"] == 3
        assert json.loads(cat_path.read_text())["version"] == 3
        # round-trip: new state persists in the migrated store
        for _ in range(2):
            store2.scan("v").labels("car").frames(0, 32).execute()
        store2.close()
        store3 = VideoStore(store_root=str(tmp_path))
        assert store3.video("v").policy.state_dict() == \
            store2.video("v").policy.state_dict()
        assert store3.video("v").policy.seen == {"car"}  # resumed, not cold

    def test_unknown_versions_still_rejected(self, small_video, tmp_path):
        frames, dets = small_video
        store = VideoStore(store_root=str(tmp_path))
        fill(store, "v", frames, dets)
        store.close()
        cat_path = tmp_path / "catalog.json"
        cat = json.loads(cat_path.read_text())
        cat["version"] = 99
        cat_path.write_text(json.dumps(cat))
        with pytest.raises(ValueError, match="version"):
            VideoStore(store_root=str(tmp_path))


# ------------------------------------------------------ crash-safe ordering
class TestCrashSafeOrdering:
    def test_shard_persisted_before_log_entries_dropped(self, small_video,
                                                        tmp_path):
        frames, dets = small_video
        store = VideoStore(store_root=str(tmp_path), tile_cache_bytes=0)
        fill(store, "v", frames, dets, policy=RegretPolicy())

        backlog_at_save = []
        orig_save = store.save

        def spy_save(**kw):
            backlog_at_save.append(store.tuner.backlog)
            orig_save(**kw)

        store.save = spy_save
        store.tuner.pause()
        for _ in range(3):
            store.scan("v").labels("car").frames(0, 16).execute()
        assert store.tuner.backlog == 3
        store.tuner.resume()
        store.drain_tuner(timeout=60)
        # the tuner saved while the drained batch was STILL in the log:
        # a crash between replay and persist can never lose observations
        # whose effects were not yet durable
        assert backlog_at_save and backlog_at_save[-1] == 3
        assert store.tuner.backlog == 0
        store.close()


# ------------------------------------------------------ admission control
class TestAdmissionControl:
    """admission="gated": what-if scores gate and rank coalesced winners;
    the default "policy" mode trusts the policies' own gates (unchanged)."""

    def _store(self, frames, dets, policy, **kw):
        store = VideoStore(tile_cache_bytes=0, **kw)
        fill(store, "v", frames, dets, policy=policy)
        H, W = frames.shape[1:]
        # "small": a 32x32 corner box (tiling pays off); "big": the whole
        # frame (tiling only adds tile-open cost — net-negative)
        store.add_detections("v", {f: [("small", (0, 0, 32, 32))]
                                   for f in range(16)})
        store.add_detections("v", {f: [("big", (0, 0, H, W))]
                                   for f in range(16, 32)})
        return store

    def test_gated_defers_net_negative_proposals(self, small_video):
        frames, dets = small_video
        H, W = frames.shape[1:]
        store = self._store(frames, dets,
                            CyclingPolicy([uniform_layout(H, W, 2, 2)]),
                            tuner_admission="gated")
        store.tuner.pause()
        for _ in range(3):
            store.scan("v").labels("big").frames(16, 32).execute()
        store.tuner.resume()
        st = store.drain_tuner(timeout=60)
        # splitting a full-frame workload saves no pixels: deferred, and
        # the SOT keeps its layout
        assert st.proposals == 3 and st.coalesced == 2
        assert st.deferred == 1 and st.applied == 0
        assert store.video("v").store.sots[1].layout.n_tiles == 1
        assert store.video("v").store.sots[1].epoch == 0
        store.close()

    def test_policy_mode_applies_unchanged(self, small_video):
        frames, dets = small_video
        H, W = frames.shape[1:]
        store = self._store(frames, dets,
                            CyclingPolicy([uniform_layout(H, W, 2, 2)]))
        store.scan("v").labels("big").frames(16, 32).execute()
        st = store.drain_tuner(timeout=60)
        # default admission stays with the policy: the proposal applies
        assert st.applied == 1 and st.deferred == 0
        assert store.video("v").store.sots[1].layout.n_tiles == 4
        store.close()

    def test_gated_admits_net_positive_and_ranks_mixed_batch(self,
                                                             small_video):
        frames, dets = small_video
        H, W = frames.shape[1:]
        # 3x5 grid puts the small box in its own 32x32 tile
        store = self._store(frames, dets,
                            CyclingPolicy([uniform_layout(H, W, 3, 5)]),
                            tuner_admission="gated")
        store.tuner.pause()
        for _ in range(4):    # enough observed workload to beat the gate
            store.scan("v").labels("small").frames(0, 16).execute()
        store.scan("v").labels("big").frames(16, 32).execute()
        store.tuner.resume()
        st = store.drain_tuner(timeout=60)
        # one winner per SOT: the small-ROI one pays off and applies, the
        # full-frame one is deferred
        assert st.applied == 1 and st.deferred == 1
        sots = store.video("v").store.sots
        assert sots[0].layout.n_tiles == 15 and sots[0].epoch == 1
        assert sots[1].layout.n_tiles == 1 and sots[1].epoch == 0
        store.close()

    def test_unknown_admission_mode_rejected(self):
        with pytest.raises(ValueError, match="admission"):
            VideoStore(tuner_admission="yolo")


# ------------------------------------------------------ proposal feedback
class TestProposalFeedback:
    """Policy.on_superseded/on_applied: a coalesced-away (or deferred, or
    epoch-stale) proposal restores the policy bookkeeping its proposal
    reset, instead of silently losing it."""

    def test_hooks_restore_and_discard(self):
        # unit semantics: on_superseded restores every stacked reset for
        # that layout, on_applied discards them; both tolerate absent keys
        pol = RegretPolicy()
        lay = uniform_layout(96, 160, 2, 2)
        k1, k2 = (0, frozenset({"car"})), (0, frozenset({"person"}))
        pol._pending[(0, lay)] = [(k1, 1.5), (k2, 0.5)]
        pol.on_superseded(0, lay)
        assert pol.regret[k1] == 1.5 and pol.regret[k2] == 0.5
        assert not pol._pending
        pol._pending[(0, lay)] = [(k1, 2.0)]
        pol.on_applied(0, lay)
        assert pol.regret[k1] == 1.5 and not pol._pending  # discarded
        pol.on_applied(0, lay)      # resolving an unknown layout: no-op
        pol.on_superseded(1, lay)

    def test_subsumed_same_layout_proposals_finalize_on_apply(self,
                                                              small_video):
        # re-proposals of the SAME layout within one batch are subsumed by
        # the applied winner: their resets become legitimate (regret ends
        # 0, exactly as inline would leave it), nothing leaks in _pending
        frames, dets = small_video
        pol = RegretPolicy(eta=1e-9)   # proposes on every observation
        store = VideoStore(tile_cache_bytes=0)
        fill(store, "v", frames, dets, policy=pol)
        store.tuner.pause()
        for _ in range(3):
            store.scan("v").labels("car").frames(0, 16).execute()
        store.tuner.resume()
        st = store.drain_tuner(timeout=60)
        assert st.proposals == 3 and st.coalesced == 2 and st.applied == 1
        key = (0, frozenset({"car"}))
        assert pol.regret.get(key, 0.0) == 0.0
        assert not pol._pending   # every pending proposal resolved
        store.close()

    def test_inline_apply_finalizes_bookkeeping(self, small_video):
        frames, dets = small_video
        pol = RegretPolicy(eta=1e-9)
        store = VideoStore(tile_cache_bytes=0, tuning="inline")
        fill(store, "v", frames, dets, policy=pol)
        for _ in range(3):
            store.scan("v").labels("car").frames(0, 16).execute()
        # synchronous applies resolve each proposal on the spot
        assert not pol._pending
        store.close()

    def test_deferred_proposal_restores_regret(self, small_video):
        frames, dets = small_video
        H, W = frames.shape[1:]
        pol = RegretPolicy(eta=1e-9)
        store = VideoStore(tile_cache_bytes=0, tuner_admission="gated")
        fill(store, "v", frames, dets, policy=pol)
        store.scan("v").labels("car").frames(0, 16).execute()
        st = store.drain_tuner(timeout=60)
        if st.deferred:   # single-query evidence below the what-if gate
            key = (0, frozenset({"car"}))
            assert pol.regret.get(key, 0.0) > 0.0
        assert not pol._pending
        store.close()

    def test_stale_epoch_proposal_superseded_hook(self, small_video):
        frames, dets = small_video
        H, W = frames.shape[1:]
        proposed = uniform_layout(H, W, 2, 2)
        sneak = uniform_layout(H, W, 3, 3)

        class StaleMaker(Policy):
            """Proposes once, then sneaks a store-level retile in during
            the next observation so the recorded proposal goes stale."""
            name = "stale_maker"
            calls = 0
            superseded: list = []
            applied: list = []

            def observe(self, q, index, store, model):
                StaleMaker.calls += 1
                if StaleMaker.calls == 1:
                    return proposed
                if StaleMaker.calls == 2:
                    store.retile(0, sneak)   # epoch bump behind our back
                return None

            def on_superseded(self, sot_id, layout):
                StaleMaker.superseded.append((sot_id, layout))

            def on_applied(self, sot_id, layout):
                StaleMaker.applied.append((sot_id, layout))

        StaleMaker.superseded, StaleMaker.applied, StaleMaker.calls = [], [], 0
        store = VideoStore(tile_cache_bytes=0)
        fill(store, "v", frames, dets, policy=StaleMaker())
        store.tuner.pause()
        for _ in range(2):
            store.scan("v").labels("car").frames(0, 16).execute()
        store.tuner.resume()
        st = store.drain_tuner(timeout=60)
        # the proposal was never applied (a newer retile won): skipped,
        # with the superseded hook fired so the policy can recover state
        assert st.applied == 0 and st.skipped == 1
        assert StaleMaker.superseded == [(0, proposed)]
        assert StaleMaker.applied == []
        assert store.video("v").store.sots[0].layout == sneak
        store.close()
