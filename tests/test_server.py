"""Cross-process serving: VideoStoreServer + RemoteVideoStore.

The contract under test: results over the wire are bit-identical to
in-process ``execute()``, client processes share one scheduler/cache/tuner
(a repeat of another client's scan decodes zero tiles), malformed frames
get an error frame instead of killing the server, and shutdown is clean.
"""
import json
import os
import socket
import struct
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.codec.encode import EncoderConfig
from repro.core import (NoTilingPolicy, RemoteError, RemoteVideoStore,
                        VideoStore, VideoStoreServer, uniform_layout)
from repro.core import wire
from repro.core.cost import CostModel

ENC = EncoderConfig(gop=16, qp=8)
MODEL = CostModel(beta=1.4e-8, gamma=1e-5)
MODEL.encode_per_pixel = 3.4e-8
MODEL.encode_per_tile = 1e-4


def fill(store, name, frames, dets, policy=None):
    store.add_video(name, encoder=ENC, policy=policy or NoTilingPolicy(),
                    cost_model=MODEL)
    store.ingest(name, frames)
    store.add_detections(name, {f: d for f, d in enumerate(dets)})


def assert_regions_equal(a, b):
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        assert ra[:-1] == rb[:-1]
        np.testing.assert_array_equal(ra[-1], rb[-1])


@pytest.fixture
def served(tmp_path, small_video):
    """One server over a Unix socket, seeded store, one connected client.
    ``owns_store=False`` keeps the in-process store open so tests can
    compare remote results against literal in-process ``execute()``."""
    frames, dets = small_video
    store = VideoStore()
    fill(store, "cam0", frames, dets)
    sock = str(tmp_path / "tasm.sock")
    server = VideoStoreServer(store, path=sock, owns_store=False).start()
    client = RemoteVideoStore(sock)
    yield store, server, client, sock
    client.close()
    server.stop()
    store.close()


# -------------------------------------------------------------- scan RPCs
class TestRemoteScans:
    def test_scan_bit_identical_to_in_process_execute(self, served):
        store, _, client, _ = served
        ref = store.scan("cam0").labels("car").frames(0, 32).execute()
        got = client.scan("cam0").labels("car").frames(0, 32).execute()
        assert_regions_equal(ref.regions, got.regions)
        assert got.stats.regions == ref.stats.regions
        assert got.plan is not None
        assert got.plan.logical == ref.plan.logical

    def test_repeat_scan_shares_cache_across_the_wire(self, served):
        store, _, client, _ = served
        q = client.scan("cam0").labels("car").frames(0, 32)
        r1 = q.execute()
        assert r1.stats.cache_misses > 0
        decoded = store.video("cam0").store.tiles_decoded_total
        r2 = q.execute()
        assert r2.stats.cache_misses == 0
        assert r2.stats.cache_hit_rate == 1.0
        assert store.video("cam0").store.tiles_decoded_total == decoded
        assert_regions_equal(r1.regions, r2.regions)

    def test_execute_many_matches_serial(self, served):
        store, _, client, _ = served
        mk = lambda s: [s.scan("cam0").labels("car").frames(0, 32),
                        s.scan("cam0").labels("person").frames(0, 16),
                        s.scan("cam0").labels("car").frames(16, 32)]
        ref = [q.execute() for q in mk(store)]
        got = client.execute_many(mk(client))
        assert len(got) == 3
        for r, g in zip(ref, got):
            assert_regions_equal(r.regions, g.regions)

    def test_limit_and_estimation_only(self, served):
        store, _, client, _ = served
        ref = store.scan("cam0").labels("car").frames(0, 32).limit(3) \
            .execute()
        got = client.scan("cam0").labels("car").frames(0, 32).limit(3) \
            .execute()
        assert_regions_equal(ref.regions, got.regions)
        est = client.scan("cam0").labels("car").decode(False).execute()
        assert est.regions == [] and est.stats.pixels_decoded > 0

    def test_explain_matches_in_process_lower(self, served):
        store, _, client, _ = served
        q = lambda s: s.scan("cam0").labels("car").frames(0, 32)
        ref, got = q(store).explain(), q(client).explain()
        assert got.describe() == ref.describe()
        assert got.est_pixels == ref.est_pixels
        assert [s.tile_idxs for s in got.sot_scans] == \
            [s.tile_idxs for s in ref.sot_scans]

    def test_multi_video_scan(self, served, small_video):
        store, _, client, _ = served
        frames, dets = small_video
        fill(store, "cam1", frames, dets)
        q = lambda s: s.scan(["cam0", "cam1"]).labels("car").frames(0, 32)
        ref, got = q(store).execute(), q(client).execute()
        assert_regions_equal(ref.regions, got.regions)
        assert sorted(got.regions_by_video) == ["cam0", "cam1"]

    def test_want_plans_false_omits_plan(self, served):
        store, _, _, sock = served
        c = RemoteVideoStore(sock, want_plans=False)
        try:
            ref = store.scan("cam0").labels("car").frames(0, 32).execute()
            got = c.scan("cam0").labels("car").frames(0, 32).execute()
            assert got.plan is None
            assert_regions_equal(ref.regions, got.regions)
        finally:
            c.close()

    def test_serving_session(self, served):
        store, _, client, _ = served
        ref = store.scan("cam0").labels("car").frames(0, 32).execute()
        with client.serve() as session:
            futs = [session.submit(client.scan("cam0").labels("car")
                                   .frames(0, 32)) for _ in range(4)]
            results = [f.result() for f in futs]
        for r in results:
            assert_regions_equal(ref.regions, r.regions)
        with pytest.raises(RuntimeError, match="closed"):
            session.submit(client.scan("cam0").labels("car"))

    def test_concurrent_clients_one_socket_each(self, served, small_video):
        _, _, _, sock = served
        frames, dets = small_video
        clients = [RemoteVideoStore(sock) for _ in range(3)]
        try:
            futs = [c.scan("cam0").labels("car").frames(0, 32).submit()
                    for c in clients]
            results = [f.result() for f in futs]
            for r in results[1:]:
                assert_regions_equal(results[0].regions, r.regions)
        finally:
            for c in clients:
                c.close()


# ----------------------------------------------------------- mutation RPCs
class TestRemoteMutations:
    def test_remote_ingest_matches_local(self, tmp_path, small_video):
        frames, dets = small_video
        sock = str(tmp_path / "t.sock")
        with VideoStoreServer(VideoStore(), path=sock).start() as server:
            with RemoteVideoStore(sock) as client:
                client.add_video("cam0", encoder=ENC,
                                 policy=NoTilingPolicy(), cost_model=MODEL)
                stats = client.ingest("cam0", frames)
                assert stats.encode_s > 0
                client.add_detections("cam0",
                                      {f: d for f, d in enumerate(dets)})
                got = client.scan("cam0").labels("car").frames(0, 32) \
                    .execute()
                with pytest.raises(ValueError, match="already"):
                    client.ingest("cam0", frames)
        local = VideoStore()
        fill(local, "cam0", frames, dets)
        ref = local.scan("cam0").labels("car").frames(0, 32).execute()
        local.close()
        assert_regions_equal(ref.regions, got.regions)

    def test_remote_add_metadata_and_retile(self, served):
        store, _, client, _ = served
        client.add_metadata("cam0", 0, "thing", 8, 8, 40, 40)
        r = client.scan("cam0").labels("thing").frames(0, 8).execute()
        assert len(r.regions) == 1
        before = store.video("cam0").store.sots[0].epoch
        dt = client.retile("cam0", 0, uniform_layout(96, 160, 2, 2))
        assert dt > 0
        assert store.video("cam0").store.sots[0].epoch == before + 1
        # post-retile scans still bit-identical to in-process
        ref = store.scan("cam0").labels("car").frames(0, 16).execute()
        got = client.scan("cam0").labels("car").frames(0, 16).execute()
        assert_regions_equal(ref.regions, got.regions)

    def test_tuner_and_stats_rpcs(self, served):
        store, _, client, _ = served
        ts = client.drain_tuner(timeout=30)
        assert ts.observed == store.tuner_stats().observed
        client.scan("cam0").labels("car").frames(0, 32).execute()
        doc = client.stats()
        assert doc["videos"] == ["cam0"]
        assert doc["tiles_decoded_total"] == \
            store.video("cam0").store.tiles_decoded_total
        assert doc["cache"]["entries"] >= 1


# ------------------------------------------------------------ error paths
class TestErrorHandling:
    def test_unknown_video_maps_to_key_error(self, served):
        _, _, client, _ = served
        with pytest.raises(KeyError, match="unknown video"):
            client.scan("nope").labels("car").execute()

    def test_unknown_op_maps_to_value_error(self, served):
        _, _, client, _ = served
        with pytest.raises(ValueError, match="unknown op"):
            client._call("no_such_op")

    def test_malformed_frame_gets_error_reply_server_survives(self, served):
        _, _, client, sock = served
        raw = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        try:
            raw.connect(sock)
            raw.sendall(struct.pack(">I", 7) + b"garbage")
            resp = wire.read_frame(raw)
            assert resp["ok"] is False and resp["id"] is None
            assert "frame" in resp["error"]["message"] \
                or resp["error"]["type"] == "WireError"
            # the poisoned connection is closed...
            with pytest.raises(wire.WireError):
                while True:
                    wire.read_frame(raw)
        finally:
            raw.close()
        # ...but the server and other connections live on
        assert client.ping()["pong"] is True

    def test_oversized_frame_rejected_without_allocation(self, served):
        _, _, client, sock = served
        raw = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        try:
            raw.connect(sock)
            raw.sendall(struct.pack(">I", 1 << 31))  # 2 GiB claim
            resp = wire.read_frame(raw)
            assert resp["ok"] is False
            assert "limit" in resp["error"]["message"]
        finally:
            raw.close()
        assert client.ping()["pong"] is True

    def test_request_without_op_gets_error_frame(self, served):
        _, _, _, sock = served
        raw = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        try:
            raw.connect(sock)
            wire.write_frame(raw, {"id": 9, "noop": True})
            resp = wire.read_frame(raw)
            assert resp["id"] == 9 and resp["ok"] is False
            assert resp["error"]["type"] == "ValueError"
            # same connection keeps working (the frame itself was valid)
            wire.write_frame(raw, {"id": 10, "op": "ping"})
            assert wire.read_frame(raw)["ok"] is True
        finally:
            raw.close()

    def test_response_over_frame_limit_maps_to_error(self, tmp_path,
                                                     small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        sock = str(tmp_path / "t.sock")
        # pin the npz transport: this test exercises the oversized-PAYLOAD
        # path, and under shm the crops leave the frame (descriptors only)
        with VideoStoreServer(store, path=sock, transport="socket",
                              max_frame_bytes=32_768).start():
            with RemoteVideoStore(sock) as client:
                # the result (hundreds of KB of crops) breaks the frame
                # limit: the server must answer with an error frame, not
                # drop the connection
                with pytest.raises(RemoteError, match="exceeds"):
                    client.scan("cam0").labels("car").frames(0, 32) \
                        .execute()
                assert client.ping()["pong"] is True

    def test_client_close_fails_pending_and_rejects_new(self, served):
        _, _, _, sock = served
        c = RemoteVideoStore(sock)
        c.close()
        with pytest.raises(RuntimeError, match="closed"):
            c.ping()

    def test_client_timeout_is_connect_only(self, served):
        """Regression: timeout= left armed on the socket fires in the
        reader thread during any idle gap, killing it and poisoning the
        connection."""
        _, _, _, sock = served
        c = RemoteVideoStore(sock, timeout=0.3)
        try:
            assert c.ping()["pong"] is True
            time.sleep(0.6)  # idle longer than the connect timeout
            assert c._reader.is_alive()
            assert c.ping()["pong"] is True
        finally:
            c.close()

    def test_requests_fail_fast_after_server_death(self, tmp_path,
                                                   small_video):
        """Regression: once the reader thread died (server gone), a new
        request must raise instead of parking a future nobody resolves."""
        frames, dets = small_video
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        sock = str(tmp_path / "t.sock")
        server = VideoStoreServer(store, path=sock).start()
        c = RemoteVideoStore(sock)
        assert c.ping()["pong"] is True
        server.stop()
        c._reader.join(timeout=10)
        assert not c._reader.is_alive()
        with pytest.raises((wire.ConnectionClosed, OSError)):
            c.ping()
        c.close()


# --------------------------------------------------- reconnect and epochs
class TestReconnectRetry:
    def _restartable(self, tmp_path, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        sock = str(tmp_path / "t.sock")
        server = VideoStoreServer(store, path=sock,
                                  owns_store=False).start()
        return store, server, sock

    def test_idempotent_rpcs_retry_across_server_restart(
            self, tmp_path, small_video):
        store, s1, sock = self._restartable(tmp_path, small_video)
        c = RemoteVideoStore(sock, retries=3)
        try:
            ref = c.scan("cam0").labels("car").frames(0, 16).execute()
            s1.stop()
            c._reader.join(timeout=10)
            with VideoStoreServer(store, path=sock,
                                  owns_store=False).start():
                # redials transparently: ping, stats, and a scan all
                # succeed on the fresh connection
                assert c.ping()["pong"] is True
                assert c.stats()["videos"] == ["cam0"]
                got = c.scan("cam0").labels("car").frames(0, 16).execute()
                assert_regions_equal(ref.regions, got.regions)
        finally:
            c.close()
            store.close()

    def test_mutations_never_retry(self, tmp_path, small_video):
        store, s1, sock = self._restartable(tmp_path, small_video)
        c = RemoteVideoStore(sock, retries=3)
        try:
            s1.stop()
            c._reader.join(timeout=10)
            with VideoStoreServer(store, path=sock,
                                  owns_store=False).start():
                # the server may have applied a mutation before the drop:
                # re-sending could double it, so the error surfaces...
                with pytest.raises((wire.ConnectionClosed, OSError)):
                    c.add_metadata("cam0", 0, "x", 0, 0, 8, 8)
                # ...and the next idempotent call heals the connection
                assert c.ping()["pong"] is True
        finally:
            c.close()
            store.close()

    def test_zero_retries_stays_fail_fast(self, tmp_path, small_video):
        store, s1, sock = self._restartable(tmp_path, small_video)
        c = RemoteVideoStore(sock)  # default retries=0
        try:
            s1.stop()
            c._reader.join(timeout=10)
            with VideoStoreServer(store, path=sock,
                                  owns_store=False).start():
                with pytest.raises((wire.ConnectionClosed, OSError)):
                    c.ping()
        finally:
            c.close()
            store.close()


class TestEpochs:
    def test_epochs_rpc_matches_store(self, served):
        store, _, client, _ = served
        assert client.epochs("cam0") == store.epochs("cam0")

    def test_epochs_tracks_retile(self, served):
        _, _, client, _ = served
        before = client.epochs("cam0")
        client.retile("cam0", 0, uniform_layout(96, 160, 2, 2))
        after = client.epochs("cam0")
        assert after[0] == before[0] + 1
        assert all(after[s] == before[s] for s in before if s != 0)

    def test_ingest_ack_carries_epochs(self, served, small_video):
        store, _, client, _ = served
        frames, _ = small_video
        assert client.last_ingest_epochs == {}
        client.add_video("cam9", encoder=ENC, policy=NoTilingPolicy(),
                         cost_model=MODEL)
        client.ingest("cam9", frames)
        assert client.last_ingest_epochs == store.epochs("cam9")
        assert client.last_ingest_epochs == client.epochs("cam9")


# ------------------------------------------------------------- transports
class TestTransports:
    def test_tcp_transport(self, served):
        store, _, _, _ = served
        with VideoStoreServer(store, host="127.0.0.1", port=0,
                              owns_store=False).start() as tcp_server:
            host, port = tcp_server.address
            with RemoteVideoStore(host=host, port=port) as client:
                assert client.ping()["pong"] is True
                ref = store.scan("cam0").labels("car").frames(0, 16) \
                    .execute()
                got = client.scan("cam0").labels("car").frames(0, 16) \
                    .execute()
                assert_regions_equal(ref.regions, got.regions)

    def test_serve_cli_shutdown_rpc_completes_cleanup(self, tmp_path):
        """Regression: the shutdown RPC runs stop() on a daemon thread —
        serve_forever must wait for cleanup to COMPLETE, or the CLI exits
        mid-stop, leaving the socket file behind and the store unflushed."""
        sock = str(tmp_path / "cli.sock")
        root = tmp_path / "root"
        script = os.path.join(os.path.dirname(__file__), "..", "scripts",
                              "tasm_serve.py")
        env = dict(os.environ, PYTHONPATH=os.pathsep.join(sys.path))
        proc = subprocess.Popen(
            [sys.executable, script, "--socket", sock,
             "--store-root", str(root)], env=env)
        try:
            deadline = time.time() + 60
            while not os.path.exists(sock):
                assert proc.poll() is None, "server died early"
                assert time.time() < deadline, "socket never appeared"
                time.sleep(0.05)
            with RemoteVideoStore(sock) as client:
                client.add_video("cam0", encoder=ENC)  # dirties the catalog
                client.shutdown_server()
            assert proc.wait(timeout=60) == 0
            assert not os.path.exists(sock), "socket file left behind"
            # close() ran: the dirty catalog was flushed before exit
            assert (root / "catalog.json").exists()
        finally:
            if proc.poll() is None:
                proc.kill()
                proc.wait(timeout=30)

    def test_start_refuses_to_hijack_live_socket(self, served):
        """start() recovers stale socket files but must not unlink a LIVE
        server's address (supervisor double-start = silent split-brain)."""
        _, _, client, sock = served
        dup = VideoStoreServer(VideoStore(), path=sock)
        with pytest.raises(OSError, match="in use"):
            dup.start()
        dup.store.close()
        # the live server kept its socket and keeps serving
        assert os.path.exists(sock)
        assert client.ping()["pong"] is True

    def test_shutdown_rpc_stops_server(self, tmp_path, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        sock = str(tmp_path / "t.sock")
        server = VideoStoreServer(store, path=sock).start()
        with RemoteVideoStore(sock) as client:
            client.shutdown_server()
        deadline = time.time() + 10
        while os.path.exists(sock) and time.time() < deadline:
            time.sleep(0.02)
        assert not os.path.exists(sock)
        server.stop()  # idempotent


# ---------------------------------------------------- real client processes
CLIENT_PROG = """
import json, sys
import numpy as np
from repro.core import RemoteVideoStore
sock, out = sys.argv[1], sys.argv[2]
with RemoteVideoStore(sock) as cli:
    r = cli.scan("cam0").labels("car").frames(0, 32).execute()
np.savez(out + ".npz",
         **{f"px_{j}": px for j, (_, _, px) in enumerate(r.regions)})
with open(out + ".json", "w") as fh:
    json.dump({"regions": [[f, list(b)] for f, b, _ in r.regions],
               "cache_misses": r.stats.cache_misses,
               "tiles_fetched": r.stats.tiles_fetched}, fh)
"""


def run_client_process(sock, out):
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(sys.path))
    res = subprocess.run([sys.executable, "-c", CLIENT_PROG, sock, out],
                         env=env, capture_output=True, text=True,
                         timeout=300)
    assert res.returncode == 0, res.stderr
    meta = json.loads(open(out + ".json").read())
    npz = np.load(out + ".npz")
    regions = [(f, tuple(b), npz[f"px_{j}"])
               for j, (f, b) in enumerate(meta["regions"])]
    return regions, meta


def test_two_client_processes_share_one_cache(served, tmp_path):
    """The acceptance gate: two real client PROCESSES against one server —
    bit-identical to in-process execute(), and the second client's repeat
    of the first client's scan decodes zero tiles."""
    store, _, _, sock = served
    ref = store.scan("cam0").labels("car").frames(0, 32).execute()

    r1, m1 = run_client_process(sock, str(tmp_path / "c1"))
    assert_regions_equal(ref.regions, r1)
    assert m1["tiles_fetched"] > 0

    decoded = store.video("cam0").store.tiles_decoded_total
    r2, m2 = run_client_process(sock, str(tmp_path / "c2"))
    assert_regions_equal(ref.regions, r2)
    assert m2["cache_misses"] == 0, "second process re-decoded tiles"
    assert store.video("cam0").store.tiles_decoded_total == decoded
