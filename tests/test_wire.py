"""Wire layer: frame codecs, npz array payloads, query/plan/result doc
round trips, and the oversized/malformed-frame rejection contract."""
import socket
import struct
import threading
import time

import numpy as np
import pytest

import _hypothesis_compat

_hypothesis_compat.install()

from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import wire  # noqa: E402
from repro.core.query import (PhysicalPlan, ScanPlan, ScanQuery,  # noqa: E402
                              ScanResult, ScanStats, SOTScan)

CODECS = ["json"] + (["msgpack"] if wire._msgpack is not None else [])


# ----------------------------------------------------------------- framing
@pytest.mark.parametrize("codec", CODECS)
class TestFraming:
    def test_doc_roundtrip(self, codec):
        doc = {"id": 3, "op": "x", "nested": {"a": [1, 2.5, None, "s"]},
               "flag": True}
        assert wire.loads(wire.dumps(doc, codec=codec)) == doc

    def test_ndarray_npz_roundtrip(self, codec):
        arrs = {"f32": np.arange(12, dtype=np.float32).reshape(3, 4),
                "u8": np.arange(8, dtype=np.uint8),
                "i64": np.array([[-(2 ** 40), 7]]),
                "empty": np.zeros((0, 3), dtype=np.float32)}
        doc = {"id": 0, "data": arrs, "list": [arrs["f32"], 1]}
        out = wire.loads(wire.dumps(doc, codec=codec))
        for k, a in arrs.items():
            got = out["data"][k]
            assert got.dtype == a.dtype and got.shape == a.shape
            np.testing.assert_array_equal(got, a)
        np.testing.assert_array_equal(out["list"][0], arrs["f32"])

    def test_socket_roundtrip(self, codec):
        a, b = socket.socketpair()
        try:
            doc = {"id": 1, "arr": np.ones((2, 2), dtype=np.float32)}
            wire.write_frame(a, doc, codec=codec)
            out = wire.read_frame(b)
            assert out["id"] == 1
            np.testing.assert_array_equal(out["arr"], doc["arr"])
        finally:
            a.close()
            b.close()

    def test_oversized_dumps_rejected(self, codec):
        doc = {"id": 0, "blob": np.zeros(100_000, dtype=np.float32)}
        with pytest.raises(wire.WireError, match="exceeds"):
            wire.dumps(doc, codec=codec, max_bytes=1024)

    def test_numpy_scalars_coerced(self, codec):
        doc = {"id": 0, "i": np.int64(7), "f": np.float32(1.5),
               "b": np.bool_(True)}
        out = wire.loads(wire.dumps(doc, codec=codec))
        assert out == {"id": 0, "i": 7, "f": 1.5, "b": True}


class TestFragmentedReads:
    """``read_frame`` against short/fragmented ``recv`` returns: the
    kernel is free to deliver one byte per ``recv``, or to split the
    4-byte header / payload at any boundary — framing must reassemble
    bit-identically in every case."""

    @staticmethod
    def _dribble(sock, data: bytes, chunks) -> threading.Thread:
        """Send ``data`` in the given chunk sizes from a helper thread
        (the reader blocks in ``read_frame`` meanwhile)."""
        def _send():
            pos = 0
            for c in chunks:
                sock.sendall(data[pos:pos + c])
                pos += c
                time.sleep(0.001)  # let the reader drain between chunks
            assert pos == len(data)
        t = threading.Thread(target=_send, daemon=True)
        t.start()
        return t

    def _frame_bytes(self, doc) -> bytes:
        payload = wire.dumps(doc)
        return struct.pack(">I", len(payload)) + payload

    def test_one_byte_at_a_time(self):
        a, b = socket.socketpair()
        try:
            doc = {"id": 9, "op": "ping", "arr": np.arange(6,
                                                           dtype=np.uint8)}
            data = self._frame_bytes(doc)
            t = self._dribble(a, data, [1] * len(data))
            out = wire.read_frame(b)
            t.join(timeout=30)
            assert out["id"] == 9 and out["op"] == "ping"
            np.testing.assert_array_equal(out["arr"], doc["arr"])
        finally:
            a.close()
            b.close()

    @pytest.mark.parametrize("split", [1, 2, 3])
    def test_header_split_across_recvs(self, split):
        # the 4-byte length header itself arrives in two pieces
        a, b = socket.socketpair()
        try:
            data = self._frame_bytes({"id": 1, "v": "x"})
            t = self._dribble(a, data, [split, len(data) - split])
            assert wire.read_frame(b)["id"] == 1
            t.join(timeout=30)
        finally:
            a.close()
            b.close()

    def test_split_straddles_header_payload_boundary(self):
        # one recv ends mid-header, the next spans header-end + payload
        a, b = socket.socketpair()
        try:
            data = self._frame_bytes({"id": 2, "v": [1, 2, 3]})
            t = self._dribble(a, data, [3, 4, len(data) - 7])
            assert wire.read_frame(b)["v"] == [1, 2, 3]
            t.join(timeout=30)
        finally:
            a.close()
            b.close()

    def test_two_frames_dribbled_back_to_back(self):
        # fragmentation must never lose the boundary BETWEEN frames
        a, b = socket.socketpair()
        try:
            data = self._frame_bytes({"id": 1}) + self._frame_bytes(
                {"id": 2, "arr": np.ones((2, 3), dtype=np.float32)})
            chunks = [5] * (len(data) // 5) + [len(data) % 5]
            t = self._dribble(a, data, [c for c in chunks if c])
            first = wire.read_frame(b)
            second = wire.read_frame(b)
            t.join(timeout=30)
            assert first["id"] == 1 and second["id"] == 2
            np.testing.assert_array_equal(
                second["arr"], np.ones((2, 3), dtype=np.float32))
        finally:
            a.close()
            b.close()

    def test_eof_after_partial_payload_is_truncation(self):
        a, b = socket.socketpair()
        data = self._frame_bytes({"id": 3})
        a.sendall(data[:len(data) - 2])  # header + most of the payload
        a.close()
        with pytest.raises(wire.WireError, match="mid-frame"):
            wire.read_frame(b)
        b.close()


class TestFramingRejects:
    def test_oversized_header_rejected_before_alloc(self):
        a, b = socket.socketpair()
        try:
            a.sendall(struct.pack(">I", 1 << 30))  # 1 GiB claim, no payload
            with pytest.raises(wire.WireError, match="limit"):
                wire.read_frame(b, max_bytes=1 << 20)
        finally:
            a.close()
            b.close()

    def test_clean_eof_vs_truncation(self):
        a, b = socket.socketpair()
        a.close()
        with pytest.raises(wire.ConnectionClosed):
            wire.read_frame(b)
        b.close()
        a, b = socket.socketpair()
        a.sendall(struct.pack(">I", 100) + b"short")
        a.close()
        with pytest.raises(wire.WireError, match="mid-frame"):
            wire.read_frame(b)
        b.close()

    @pytest.mark.parametrize("payload", [
        b"garbage-with-no-tag", b"Mnot-msgpack" if wire._msgpack else b"J{",
        b"J{truncated", b"Z???", b"J[1,2,3]"])
    def test_malformed_payloads_raise_wire_error(self, payload):
        with pytest.raises(wire.WireError):
            wire.loads(payload)

    def test_zero_length_frame_rejected(self):
        a, b = socket.socketpair()
        try:
            a.sendall(struct.pack(">I", 0))
            with pytest.raises(wire.WireError, match="zero-length"):
                wire.read_frame(b)
        finally:
            a.close()
            b.close()

    def test_object_arrays_rejected_sender_side(self):
        # rejected at dumps(): np.savez would silently pickle them, and
        # the receiver-side allow_pickle=False failure would kill the
        # whole connection instead of the offending request
        doc = {"id": 0, "a": np.array([{"x": 1}], dtype=object)}
        with pytest.raises(wire.WireError, match="object-dtype"):
            wire.dumps(doc)


# ------------------------------------------------------------ plan docs
bboxes = st.tuples(st.integers(0, 10), st.integers(0, 10),
                   st.integers(11, 30), st.integers(11, 30))
clauses = st.lists(st.sampled_from(["car", "person", "boat"]),
                   min_size=1, max_size=2).map(tuple)
plans = st.builds(
    ScanPlan,
    videos=st.lists(st.sampled_from(["a", "b", "c"]), min_size=1,
                    max_size=3).map(lambda v: tuple(dict.fromkeys(v))),
    cnf=st.lists(clauses, min_size=0, max_size=2).map(tuple),
    frame_range=st.tuples(st.booleans(), st.integers(0, 50),
                          st.integers(51, 100)).map(
        lambda t: None if t[0] else (t[1], t[2])),
    limit=st.tuples(st.booleans(), st.integers(0, 64)).map(
        lambda t: None if t[0] else t[1]),
    decode=st.booleans())


# the shim's @given produces a zero-arg wrapper, so property tests live at
# module level (same pattern as the other property-test modules)
@settings(max_examples=50)
@given(plan=plans)
def test_scan_plan_roundtrip_property(plan):
    doc = wire.loads(wire.dumps(ScanPlan.from_doc(plan.to_doc()).to_doc()))
    assert ScanPlan.from_doc(doc) == plan


class TestQueryDocs:
    def test_scan_plan_all_labels_sentinel(self):
        plan = ScanPlan(videos=("v",), cnf=())  # .labels() with no args
        assert ScanPlan.from_doc(plan.to_doc()) == plan

    def test_scan_query_roundtrip_including_partial(self):
        q = ScanQuery(None, ("a", "b")).labels("car", "person") \
            .frames(4, 32).limit(5).decode(False)
        q2 = ScanQuery.from_doc(None, wire.loads(wire.dumps(q.to_doc())))
        assert q2.plan() == q.plan()
        partial = ScanQuery(None, "v")  # no labels yet: still ships
        p2 = ScanQuery.from_doc(None, partial.to_doc())
        assert p2._cnf is None and p2.to_doc() == partial.to_doc()

    def test_scan_stats_roundtrip(self):
        s = ScanStats(lookup_s=0.1, decode_s=0.5, pixels_decoded=123.0,
                      tiles_decoded=3.0, cache_hits=2, cache_misses=1,
                      regions=7)
        s2 = ScanStats.from_doc(wire.loads(wire.dumps(s.to_doc())))
        assert s2 == s and s2.cache_hit_rate == s.cache_hit_rate

    def test_sot_scan_and_physical_plan_roundtrip(self):
        ss = SOTScan(video="v", sot_id=2, epoch=1, tile_idxs=(0, 3),
                     n_frames=16,
                     boxes_by_frame={4: [(0, 0, 8, 8), (8, 8, 24, 24)],
                                     7: [(16, 16, 32, 32)]},
                     query_range=(0, 32), labels=("car",),
                     est_pixels=100.0, est_tiles=2.0, est_cost_s=0.01,
                     blocks_by_tile={0: (0, 1, 5), 3: None})
        pp = PhysicalPlan(logical=ScanPlan(videos=("v",), cnf=(("car",),)),
                          sot_scans=[ss], lookup_s=0.002)
        pp2 = PhysicalPlan.from_doc(wire.loads(wire.dumps(pp.to_doc())))
        assert pp2.logical == pp.logical
        assert pp2.lookup_s == pp.lookup_s
        s2 = pp2.sot_scans[0]
        assert s2 == ss  # dataclass equality covers every field
        assert isinstance(s2.tile_idxs, tuple)
        assert all(isinstance(b, tuple)
                   for bs in s2.boxes_by_frame.values() for b in bs)
        assert s2.blocks_by_tile[3] is None
        assert pp2.describe() == pp.describe()

    def test_empty_physical_plan_roundtrip(self):
        pp = PhysicalPlan(logical=ScanPlan(videos=("v",), cnf=(("car",),)))
        pp2 = PhysicalPlan.from_doc(wire.loads(wire.dumps(pp.to_doc())))
        assert pp2.sot_scans == [] and pp2.est_pixels == 0.0


# ------------------------------------------------------------ result docs
def _result(videos, rbv, plan=None):
    if len(videos) == 1:
        regions = list(rbv.get(videos[0], []))
    else:
        regions = [(v, f, b, px) for v in videos
                   for f, b, px in rbv.get(v, [])]
    return ScanResult(regions=regions, stats=ScanStats(regions=len(regions)),
                      plan=plan, regions_by_video=rbv)


class TestResultDocs:
    def test_empty_result_roundtrip(self):
        r = _result(["v"], {"v": []})
        r2 = ScanResult.from_doc(wire.loads(wire.dumps(r.to_doc())))
        assert r2.regions == [] and r2.stats == r.stats and r2.plan is None

    def test_single_video_result_roundtrip(self):
        px = np.arange(64, dtype=np.float32).reshape(8, 8)
        r = _result(["v"], {"v": [(3, (0, 0, 8, 8), px),
                                  (4, (8, 0, 16, 8), px * 2)]})
        r2 = ScanResult.from_doc(wire.loads(wire.dumps(r.to_doc())))
        assert len(r2.regions) == 2
        for (f, b, p), (f2, b2, p2) in zip(r.regions, r2.regions):
            assert (f, b) == (f2, b2) and isinstance(b2, tuple)
            np.testing.assert_array_equal(p, p2)
            assert p2.dtype == p.dtype

    def test_multi_video_flat_regions_rebuilt_in_plan_order(self):
        px = np.ones((4, 4), dtype=np.float32)
        plan = PhysicalPlan(logical=ScanPlan(videos=("b", "a"),
                                             cnf=(("car",),)))
        r = _result(["b", "a"], {"b": [(1, (0, 0, 4, 4), px)],
                                 "a": [(2, (4, 4, 8, 8), px * 3)]},
                    plan=plan)
        r2 = ScanResult.from_doc(wire.loads(wire.dumps(r.to_doc())))
        # flat regions preserve the plan's video order, not sorted order
        assert [t[0] for t in r2.regions] == ["b", "a"]
        assert r2.regions[0][:3] == ("b", 1, (0, 0, 4, 4))
        np.testing.assert_array_equal(r2.regions[1][3], px * 3)
        assert r2.plan.logical.videos == ("b", "a")

    def test_result_with_limit_stats_and_plan(self):
        px = np.zeros((2, 2), dtype=np.float32)
        plan = PhysicalPlan(logical=ScanPlan(videos=("v",), cnf=(("car",),),
                                             limit=1))
        r = _result(["v"], {"v": [(0, (0, 0, 2, 2), px)]}, plan=plan)
        r.stats.cache_hits = 5
        r2 = ScanResult.from_doc(wire.loads(wire.dumps(r.to_doc())))
        assert r2.plan.logical.limit == 1 and r2.stats.cache_hits == 5
