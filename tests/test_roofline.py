"""Roofline accounting: jaxpr FLOP counter and HLO collective-bytes walker."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.analytic_cost import count_flops, hbm_bytes_per_chip
from repro.launch.roofline import collective_bytes_from_hlo


class TestJaxprFlops:
    def test_matmul(self):
        n = 64
        a = jax.ShapeDtypeStruct((n, n), jnp.float32)
        got = count_flops(lambda x, y: x @ y, a, a)
        assert got == 2 * n ** 3

    def test_scan_multiplies_by_length(self):
        n, L = 32, 10
        w = jax.ShapeDtypeStruct((n, n), jnp.float32)

        def f(w):
            def body(h, _):
                return h @ w, None

            h, _ = jax.lax.scan(body, jnp.eye(n), None, length=L)
            return h

        assert count_flops(f, w) == L * 2 * n ** 3

    def test_nested_scan(self):
        n, L1, L2 = 16, 3, 5
        w = jax.ShapeDtypeStruct((n, n), jnp.float32)

        def f(w):
            def outer(h, _):
                def inner(h2, _):
                    return h2 @ w, None

                h, _ = jax.lax.scan(inner, h, None, length=L2)
                return h, None

            h, _ = jax.lax.scan(outer, jnp.eye(n), None, length=L1)
            return h

        assert count_flops(f, w) == L1 * L2 * 2 * n ** 3

    def test_grad_includes_backward(self):
        n = 32
        a = jax.ShapeDtypeStruct((n, n), jnp.float32)

        def loss(w, x):
            return jnp.sum((x @ w) ** 2)

        fwd = count_flops(loss, a, a)
        both = count_flops(jax.grad(loss), a, a)
        assert both >= 1.9 * fwd  # fwd matmul + x^T @ g in bwd

    def test_remat_recompute_counted(self):
        n = 32
        a = jax.ShapeDtypeStruct((n, n), jnp.float32)

        def loss(w, x):
            f = jax.checkpoint(lambda x: jnp.tanh(x @ w) @ w)
            return jnp.sum(f(x))

        plain = count_flops(jax.grad(lambda w, x: jnp.sum(jnp.tanh(x @ w) @ w)), a, a)
        remat = count_flops(jax.grad(loss), a, a)
        assert remat >= plain  # recompute adds forward flops


SYNTH_HLO = """
HloModule test

%cond_comp (p: (s32[], f32[128,256])) -> pred[] {
  %p = (s32[], f32[128,256]) parameter(0)
  %iter = s32[] get-tuple-element(%p), index=0
  %trip = s32[] constant(12)
  ROOT %lt = pred[] compare(%iter, %trip), direction=LT
}

%body_comp (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %x = f32[128,256] get-tuple-element(%p), index=1
  %ar = f32[128,256] all-reduce(%x), replica_groups={}, to_apply=%sum
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[128,256]) tuple(%i, %ar)
}

ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %a = f32[128,256] parameter(0)
  %ag = f32[256,256] all-gather(%a), dimensions={0}
  %w = (s32[], f32[128,256]) while((s32[] %c0, f32[128,256] %a)), condition=%cond_comp, body=%body_comp
  ROOT %out = f32[128,256] get-tuple-element(%w), index=1
}
"""


class TestCollectiveWalk:
    def test_while_trip_multiplication(self):
        got = collective_bytes_from_hlo(SYNTH_HLO)
        ar_bytes = 128 * 256 * 4 * 2.0  # all-reduce multiplier 2
        ag_bytes = 256 * 256 * 4
        assert got["bytes_by_kind"]["all-reduce"] == ar_bytes * 12
        assert got["bytes_by_kind"]["all-gather"] == ag_bytes
        assert got["count_by_kind"] == {"all-reduce": 1, "all-gather": 1}

    def test_empty_module(self):
        got = collective_bytes_from_hlo("ENTRY %m (x: f32[4]) -> f32[4] {\n}")
        assert got["total_bytes"] == 0.0


class TestHbmModel:
    def test_decode_dominated_by_weights_and_cache(self):
        from repro.configs.base import get_config, get_shape
        import jax as _jax

        class FakeMesh:
            shape = {"data": 16, "model": 16}

        cfg = get_config("qwen2-72b")
        flows = hbm_bytes_per_chip(cfg, get_shape("decode_32k"), FakeMesh(),
                                   mode="decode",
                                   cache_bytes_total=4.3e12)
        assert flows["weights"] > 0.5 * 72e9 * 2 / 16
        assert flows["kv_cache_read"] > flows["activations"]
