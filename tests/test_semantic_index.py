"""Semantic index: CNF predicate semantics + API."""
from repro.core.semantic_index import SemanticIndex, parse_predicate


def make_index():
    ix = SemanticIndex(order=4)
    ix.add("v", 0, "car", (0, 0, 10, 10))
    ix.add("v", 0, "car", (50, 50, 60, 60))
    ix.add("v", 0, "red", (5, 5, 20, 20))
    ix.add("v", 1, "car", (2, 2, 12, 12))
    ix.add("v", 5, "person", (30, 30, 44, 40))
    return ix


def test_single_label():
    ix = make_index()
    got = ix.query("v", "car")
    assert set(got) == {0, 1}
    assert len(got[0]) == 2


def test_disjunction_union():
    ix = make_index()
    got = ix.query("v", ["car", "person"])  # car OR person
    assert set(got) == {0, 1, 5}


def test_conjunction_intersection():
    ix = make_index()
    got = ix.query("v", [["car"], ["red"]])  # car AND red
    assert set(got) == {0}
    assert got[0] == [(5, 5, 10, 10)]  # the overlap region


def test_conjunction_empty_when_disjoint():
    ix = make_index()
    got = ix.query("v", [["person"], ["red"]])
    assert got == {}


def test_temporal_predicate():
    ix = make_index()
    assert set(ix.query("v", "car", (1, 10))) == {1}


def test_add_metadata_signature_xy_order():
    ix = SemanticIndex()
    ix.add_metadata("v", 7, "car", 10, 20, 30, 40)  # x1,y1,x2,y2
    got = ix.query("v", "car")
    assert got[7] == [(20, 10, 40, 30)]  # stored as (y1,x1,y2,x2)


def test_parse_predicate_forms():
    assert parse_predicate("car") == (("car",),)
    assert parse_predicate(["car", "bike"]) == (("car", "bike"),)
    assert parse_predicate([["car"], ["red"]]) == (("car",), ("red",))


def test_has_locations():
    ix = make_index()
    assert ix.has_locations("v", ["car"], (0, 2))
    assert not ix.has_locations("v", ["person"], (0, 2))


def test_stats_nonempty():
    ix = make_index()
    s = ix.stats()
    assert s["entries"] == 5 and s["depth"] >= 1
