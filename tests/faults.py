"""Byte-level fault-injection proxy for the cluster copy path.

Grown from the byte-dribbling sender in ``test_wire.py``: instead of a
one-shot helper thread inside a test, a real listener that sits between a
client (the router / repair worker) and a backend node socket and relays
bytes — injecting the failure modes a repair stream must survive:

* **drop** — the connection is accepted and immediately closed (the node
  is reachable but refuses service);
* **delay** — every relayed chunk is held for ``delay_s`` (a slow link);
* **stall** — the first byte in a direction is held for ``stall_s`` (a
  hung node: connects fine, never answers — what RPC deadlines catch);
* **torn frame** — one byte at stream offset ``corrupt_at`` is flipped
  (a frame that arrives, but wrong — what checksums catch);
* **mid-stream disconnect** — the stream is severed after ``cut_after``
  relayed bytes (what chunked, resumable waves recover from).

Faults are consumed one per accepted connection, in order; once the list
is exhausted every further connection relays cleanly — so "first attempt
torn, retry succeeds" is one ``FaultProxy(..., faults=[Fault(...)])``.
Register the proxy's ``address`` with the router in place of the node's
and the whole copy path — dial, handshake, every chunk RPC — flows
through it.
"""
from __future__ import annotations

import dataclasses
import os
import socket
import tempfile
import threading
from typing import Optional


@dataclasses.dataclass
class Fault:
    """What to do to one proxied connection.  ``direction`` selects which
    byte stream the byte-offset faults meter: ``"c2b"`` (client uploads —
    e.g. an ``import_chunk`` payload), ``"b2c"`` (backend replies — e.g.
    an ``export_chunk`` payload), or ``"both"`` (one shared offset
    counter across both)."""
    drop: bool = False                  # close immediately on accept
    delay_s: float = 0.0                # per-relayed-chunk delay
    stall_s: float = 0.0                # hold the FIRST byte this long
    cut_after: Optional[int] = None     # sever after N relayed bytes
    corrupt_at: Optional[int] = None    # flip the byte at stream offset N
    direction: str = "both"


class _ConnState:
    def __init__(self, fault: Optional[Fault]):
        self.fault = fault
        self.lock = threading.Lock()
        self.sent = {"c2b": 0, "b2c": 0, "both": 0}
        self.corrupted = False
        self.stalled = set()


class FaultProxy:
    """A Unix-socket man-in-the-middle for one backend node.

    >>> proxy = FaultProxy(node_path, faults=[Fault(cut_after=9000)])
    >>> router = ClusterRouter({...,"n2": proxy.address}, ...)

    The first connection through the proxy is severed 9000 bytes in; every
    retry relays cleanly.  ``add_fault`` queues more mid-test.  Counters
    (``connections``, ``faults_fired``) let tests assert the fault
    actually hit the path under test.
    """

    def __init__(self, backend: str, path: Optional[str] = None,
                 faults=None):
        self.backend = backend
        if path is None:
            fd, p = tempfile.mkstemp(suffix=".sock", prefix="faultproxy-")
            os.close(fd)
            os.unlink(p)
            path = p
        self.address = path
        self._faults = list(faults or [])
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._conns: list[socket.socket] = []
        self.connections = 0
        self.faults_fired = 0
        self._listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        self._listener.bind(path)
        self._listener.listen(16)
        self._thread = threading.Thread(target=self._accept_loop,
                                        daemon=True, name="fault-proxy")
        self._thread.start()

    # ------------------------------------------------------------- control
    def add_fault(self, fault: Fault) -> None:
        with self._lock:
            self._faults.append(fault)

    def pending_faults(self) -> int:
        with self._lock:
            return len(self._faults)

    def clear_faults(self) -> None:
        with self._lock:
            self._faults.clear()

    def close(self) -> None:
        self._stop.set()
        try:
            self._listener.close()
        except OSError:
            pass
        with self._lock:
            conns, self._conns = self._conns[:], []
        for c in conns:
            try:
                c.close()
            except OSError:
                pass
        try:
            os.unlink(self.address)
        except OSError:
            pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -------------------------------------------------------------- relay
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                client, _ = self._listener.accept()
            except OSError:
                return
            with self._lock:
                self.connections += 1
                fault = self._faults.pop(0) if self._faults else None
                if fault is not None:
                    self.faults_fired += 1
            if fault is not None and fault.drop:
                try:
                    client.close()
                except OSError:
                    continue
                continue
            try:
                backend = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
                backend.connect(self.backend)
            except OSError:
                client.close()
                continue
            with self._lock:
                self._conns += [client, backend]
            state = _ConnState(fault)
            for src, dst, direction in ((client, backend, "c2b"),
                                        (backend, client, "b2c")):
                threading.Thread(target=self._relay, daemon=True,
                                 args=(src, dst, direction, state)).start()

    def _relay(self, src: socket.socket, dst: socket.socket,
               direction: str, state: _ConnState) -> None:
        fault = state.fault
        metered = fault is not None and fault.direction in (direction,
                                                            "both")
        key = fault.direction if metered else direction
        try:
            while not self._stop.is_set():
                try:
                    data = src.recv(65536)
                except OSError:
                    break
                if not data:
                    break
                sever = False
                if metered:
                    data, sever = self._apply(fault, key, direction, data,
                                              state)
                if data:
                    try:
                        dst.sendall(data)
                    except OSError:
                        break
                    with state.lock:
                        state.sent[key] += len(data)
                if sever:
                    break
        finally:
            for s in (src, dst):
                try:
                    s.shutdown(socket.SHUT_RDWR)
                except OSError:
                    pass
                try:
                    s.close()
                except OSError:
                    pass

    def _apply(self, fault: Fault, key: str, direction: str, data: bytes,
               state: _ConnState):
        """Fault one relayed chunk.  Returns ``(bytes_to_forward,
        sever)`` — forwarding a partial prefix then severing is exactly
        what a mid-write crash looks like to the reader."""
        if fault.stall_s and direction not in state.stalled:
            state.stalled.add(direction)
            if self._stop.wait(fault.stall_s):
                return b"", True
        if fault.delay_s and self._stop.wait(fault.delay_s):
            return b"", True
        with state.lock:
            offset = state.sent[key]
            tear = (fault.corrupt_at is not None and not state.corrupted
                    and offset <= fault.corrupt_at < offset + len(data))
            if tear:
                state.corrupted = True
        if tear:
            i = fault.corrupt_at - offset
            data = data[:i] + bytes([data[i] ^ 0xFF]) + data[i + 1:]
        if fault.cut_after is not None and \
                offset + len(data) >= fault.cut_after:
            return data[:max(0, fault.cut_after - offset)], True
        return data, False
