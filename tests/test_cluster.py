"""Distributed VideoStore: PlacementMap, ClusterRouter, replicated failover.

The contract under test: consistent-hash placement is stable (adding a
node moves ~1/N of ring owners) and balanced (bounded-load primaries);
the placement map survives a JSON round-trip; a multi-node cluster behind
the router is bit-identical to a single in-process store for
execute / execute_many / serve() — including mid-batch retiles and
``limit`` across videos on different nodes; and with K=2 replication,
killing a node loses no reads while the epoch check keeps a stale replica
from ever serving a pre-retile generation.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.codec.encode import EncoderConfig
from repro.core import (ClusterClient, ClusterRouter, ClusterRouterServer,
                        NoTilingPolicy, PlacementMap, VideoStore,
                        VideoStoreServer, uniform_layout, wire)
from repro.core.cost import CostModel

ENC = EncoderConfig(gop=16, qp=8)
MODEL = CostModel(beta=1.4e-8, gamma=1e-5)
MODEL.encode_per_pixel = 3.4e-8
MODEL.encode_per_tile = 1e-4


def assert_regions_equal(a, b):
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        assert ra[:-1] == rb[:-1]
        np.testing.assert_array_equal(ra[-1], rb[-1])


def fill(store, name, frames, dets):
    store.add_video(name, encoder=ENC, policy=NoTilingPolicy(),
                    cost_model=MODEL)
    store.ingest(name, frames)
    store.add_detections(name, {f: d for f, d in enumerate(dets)})


# ============================================================== placement
class TestPlacementMap:
    def test_ring_owner_deterministic(self):
        a = PlacementMap(["n0", "n1", "n2"])
        b = PlacementMap(["n2", "n0", "n1"])  # order-independent ring
        for i in range(50):
            assert a.ring_owner(f"cam{i}") == b.ring_owner(f"cam{i}")

    def test_adding_a_node_moves_about_one_over_n(self):
        """The consistent-hashing contract: growing N-1 -> N nodes
        re-homes ~1/N of ring owners, nowhere near the ~(N-1)/N a mod-N
        hash would."""
        videos = [f"cam{i}" for i in range(400)]
        pm3 = PlacementMap(["n0", "n1", "n2"], vnodes=128)
        before = {v: pm3.ring_owner(v) for v in videos}
        pm3.add_node("n3")
        moved = sum(1 for v in videos if pm3.ring_owner(v) != before[v])
        # expectation 1/4 = 100 of 400; generous band, but well under the
        # ~300 a naive rehash would move
        assert 40 <= moved <= 180
        # every move lands on the NEW node (CH only steals, never shuffles)
        for v in videos:
            if pm3.ring_owner(v) != before[v]:
                assert pm3.ring_owner(v) == "n3"

    def test_bounded_load_primaries_balanced(self):
        pm = PlacementMap(["n0", "n1", "n2"], replication=2)
        for i in range(12):
            pm.place(f"cam{i}")
        counts = {n: 0 for n in pm.nodes}
        for reps in pm.assignments.values():
            counts[reps[0]] += 1
        assert max(counts.values()) - min(counts.values()) <= 1
        # replicas are distinct nodes
        for reps in pm.assignments.values():
            assert len(reps) == 2 and len(set(reps)) == 2

    def test_place_is_sticky(self):
        pm = PlacementMap(["n0", "n1"])
        first = pm.place("cam0")
        pm.add_node("n2")  # membership change must not re-home cam0
        assert pm.place("cam0") == first
        assert pm.nodes_for("cam0") == first

    def test_round_trip_through_json_file(self, tmp_path):
        path = str(tmp_path / "placement.json")
        pm = PlacementMap(["n0", "n1", "n2"], replication=2, vnodes=32,
                          path=path)
        for i in range(7):
            pm.place(f"cam{i}")
        pm2 = PlacementMap.load(path)
        assert pm2.nodes == pm.nodes
        assert pm2.replication == 2 and pm2.vnodes == 32
        assert pm2.assignments == pm.assignments
        # the persisted doc is plain JSON (operators can read/edit it)
        doc = json.loads(open(path).read())
        assert doc["version"] == 1 and len(doc["assignments"]) == 7

    def test_plan_rebalance_suggests_never_applies(self):
        pm = PlacementMap(["n0", "n1"], vnodes=128)
        for i in range(40):
            pm.place(f"cam{i}")
        snap = {v: list(r) for v, r in pm.assignments.items()}
        pm.add_node("n2")
        moves = pm.plan_rebalance()
        assert moves, "adding a node should suggest some moves"
        for v, (cur, new) in moves.items():
            assert cur == snap[v][0] and new != cur
        # the new node is the dominant target (CH steals toward it; a few
        # moves also undo old bounded-load redirects)
        assert sum(1 for _, new in moves.values() if new == "n2") \
            >= len(moves) * 0.5
        # nothing moved by itself
        assert {v: list(r) for v, r in pm.assignments.items()} == snap


# ================================================================ cluster
@pytest.fixture
def cluster(tmp_path, small_video):
    """3 nodes + router (K=2) and a single reference store seeded with
    the same two videos, so every test can assert bit-identity."""
    frames, dets = small_video
    nodes, servers = {}, []
    for i in range(3):
        p = str(tmp_path / f"n{i}.sock")
        servers.append(VideoStoreServer(VideoStore(), path=p).start())
        nodes[f"n{i}"] = p
    router = ClusterRouter(nodes, replication=2,
                           placement_path=str(tmp_path / "placement.json"))
    ref = VideoStore()
    for name in ("cam0", "cam1"):
        fill(router, name, frames, dets)
        fill(ref, name, frames, dets)
    yield router, ref, servers, nodes
    router.close()
    for s in servers:
        s.stop()
    ref.close()


class TestClusterBitIdentity:
    def test_execute_matches_single_store(self, cluster):
        router, ref, _, _ = cluster
        for q in (lambda s: s.scan("cam0").labels("car").frames(0, 32),
                  lambda s: s.scan("cam1").labels("person").frames(8, 24),
                  lambda s: s.scan(["cam0", "cam1"]).labels("car")
                  .frames(0, 32)):
            assert_regions_equal(q(ref).execute().regions,
                                 q(router).execute().regions)

    def test_limit_spends_sequentially_across_nodes(self, cluster):
        router, ref, _, _ = cluster
        q = lambda s: s.scan(["cam0", "cam1"]).labels("car") \
            .frames(0, 32).limit(5)
        r, g = q(ref).execute(), q(router).execute()
        assert_regions_equal(r.regions, g.regions)
        assert g.stats.regions == r.stats.regions == 5

    def test_execute_many_strict_submission_order(self, cluster):
        router, ref, _, _ = cluster
        mk = lambda s: [s.scan("cam0").labels("car").frames(0, 32),
                        s.scan("cam1").labels("car").frames(0, 16),
                        s.scan("cam0").labels("person").frames(0, 32),
                        s.scan(["cam0", "cam1"]).labels("car").frames(16, 32)]
        refs = [q.execute() for q in mk(ref)]
        gots = router.execute_many(mk(router))
        assert len(gots) == 4
        for r, g in zip(refs, gots):
            assert_regions_equal(r.regions, g.regions)

    def test_serve_session_with_mid_batch_retile(self, cluster):
        router, ref, _, _ = cluster
        q = lambda s: s.scan("cam0").labels("car").frames(0, 32)
        with router.serve() as session:
            first = session.submit(q(router)).result()
            dt = router.retile("cam0", 0, uniform_layout(96, 160, 2, 2))
            assert dt > 0
            second = session.submit(q(router)).result()
        expect = q(ref).execute()
        assert_regions_equal(expect.regions, first.regions)
        # retiling changes the physical layout, never the bits
        assert_regions_equal(expect.regions, second.regions)
        assert router._epochs["cam0"][0] >= 1

    def test_explain_routes(self, cluster):
        router, ref, _, _ = cluster
        r = ref.scan("cam0").labels("car").frames(0, 32).explain()
        g = router.scan("cam0").labels("car").frames(0, 32).explain()
        assert g.est_pixels == r.est_pixels
        assert [s.tile_idxs for s in g.sot_scans] == \
            [s.tile_idxs for s in r.sot_scans]

    def test_mutations_hit_every_replica(self, cluster):
        router, _, _, nodes = cluster
        reps = router.placement.nodes_for("cam0")
        assert len(reps) == 2
        router.add_metadata("cam0", 0, "thing", 8, 8, 40, 40)
        from repro.core import RemoteVideoStore
        for node in reps:
            with RemoteVideoStore(nodes[node]) as direct:
                r = direct.scan("cam0").labels("thing").frames(0, 8) \
                    .execute()
                assert len(r.regions) == 1


class TestClusterClient:
    def test_front_end_serves_identical_results(self, cluster, tmp_path):
        router, ref, _, _ = cluster
        sock = str(tmp_path / "router.sock")
        with ClusterRouterServer(router, path=sock,
                                 owns_store=False).start():
            with ClusterClient(sock) as cc:
                pong = cc.ping()
                assert pong["cluster"] is True
                assert pong["nodes"] == ["n0", "n1", "n2"]
                assert sorted(cc.videos()) == ["cam0", "cam1"]
                q = lambda s: s.scan(["cam0", "cam1"]).labels("car") \
                    .frames(0, 32)
                assert_regions_equal(q(ref).execute().regions,
                                     q(cc).execute().regions)
                got = cc.execute_many([
                    cc.scan("cam0").labels("car").frames(0, 16),
                    cc.scan("cam1").labels("person").frames(0, 32)])
                refs = [ref.scan("cam0").labels("car").frames(0, 16)
                        .execute(),
                        ref.scan("cam1").labels("person").frames(0, 32)
                        .execute()]
                for r, g in zip(refs, got):
                    assert_regions_equal(r.regions, g.regions)
                assert cc.placement()["assignments"] == \
                    {v: list(r) for v, r in
                     router.placement.assignments.items()}
                assert cc.node_health() == {"n0": True, "n1": True,
                                            "n2": True}


class TestFailover:
    def _kill(self, cluster, video):
        router, _, servers, _ = cluster
        primary = router.placement.primary(video)
        servers[int(primary[1:])].stop()
        return primary

    def test_reads_survive_primary_death(self, cluster):
        router, ref, _, _ = cluster
        expect = ref.scan("cam0").labels("car").frames(0, 32).execute()
        primary = self._kill(cluster, "cam0")
        got = router.scan("cam0").labels("car").frames(0, 32).execute()
        assert_regions_equal(expect.regions, got.regions)
        assert primary in router._down
        # repeat read sticks to the surviving replica (it is now warm)
        got2 = router.scan("cam0").labels("car").frames(0, 32).execute()
        assert_regions_equal(expect.regions, got2.regions)

    def test_batches_survive_node_death_mid_routing(self, cluster):
        router, ref, _, _ = cluster
        self._kill(cluster, "cam0")
        mk = lambda s: [s.scan("cam0").labels("car").frames(0, 32),
                        s.scan("cam1").labels("car").frames(0, 32)]
        refs = [q.execute() for q in mk(ref)]
        gots = router.execute_many(mk(router))
        for r, g in zip(refs, gots):
            assert_regions_equal(r.regions, g.regions)

    def test_stale_replica_never_serves_pre_retile_layout(self, cluster):
        """The epoch-consistency check: a replica that missed a retile
        (it was down when the mutation fanned out) is excluded from reads
        for that video even after it comes back."""
        router, ref, servers, _ = cluster
        reps = router.placement.nodes_for("cam0")
        replica = reps[1]
        servers[int(replica[1:])].stop()
        # retile while the replica is down: it misses the epoch bump
        dt = router.retile("cam0", 0, uniform_layout(96, 160, 2, 2))
        assert dt > 0
        assert (("cam0", replica) in router._stale)
        # node comes back (same store object would be wrong here — the
        # point is the ROUTER must not read cam0 from it regardless)
        assert router._reader_name("cam0") == reps[0]
        got = router.scan("cam0").labels("car").frames(0, 32).execute()
        expect = ref.scan("cam0").labels("car").frames(0, 32).execute()
        assert_regions_equal(expect.regions, got.regions)

    def test_all_replicas_down_raises(self, cluster):
        router, _, servers, _ = cluster
        for name in router.placement.nodes_for("cam0"):
            servers[int(name[1:])].stop()
        with pytest.raises((wire.ConnectionClosed, OSError)):
            router.scan("cam0").labels("car").frames(0, 32).execute()

    def test_replica_epochs_agree_after_router_retile(self, cluster):
        router, _, _, nodes = cluster
        from repro.core import RemoteVideoStore
        router.retile("cam0", 1, uniform_layout(96, 160, 2, 2))
        tables = []
        for node in router.placement.nodes_for("cam0"):
            with RemoteVideoStore(nodes[node]) as direct:
                tables.append(direct.epochs("cam0"))
        assert tables[0] == tables[1]
        assert tables[0][1] == 1  # the retiled SOT bumped everywhere


class TestRouterAccounting:
    def test_stats_merge_and_down_marking(self, cluster):
        router, _, servers, _ = cluster
        router.scan("cam0").labels("car").frames(0, 32).execute()
        doc = router.stats()
        assert doc["videos"] == ["cam0", "cam1"]
        assert doc["replication"] == 2
        assert set(doc["nodes"]) == {"n0", "n1", "n2"}
        assert doc["tiles_decoded_total"] > 0
        live = [d for d in doc["nodes"].values() if d]
        assert doc["storage_bytes"] == sum(d["storage_bytes"]
                                           for d in live)
        servers[0].stop()
        assert router.ping_nodes() == {"n0": False, "n1": True,
                                       "n2": True}
        assert router.stats()["nodes"]["n0"] is None

    def test_tuner_stats_summed(self, cluster):
        router, _, _, _ = cluster
        ts = router.drain_tuner(timeout=30)
        from repro.core.tuner import TunerStats
        assert isinstance(ts, TunerStats)
        total = router.tuner_stats()
        assert total.observed >= 0

    def test_ingest_rejects_on_any_replica_semantic_error(self, cluster,
                                                          small_video):
        router, _, _, _ = cluster
        frames, _ = small_video
        with pytest.raises(ValueError, match="already"):
            router.ingest("cam0", frames)

    def test_unknown_video_raises_key_error(self, cluster):
        router, _, _, _ = cluster
        with pytest.raises(KeyError, match="unknown video"):
            router.scan("nope").labels("car").execute()
