"""Workload-predictive cache (prefetch, expected-reuse eviction,
block-packed ROI entries) + the unified config surface.

The load-bearing contracts:

- ``eviction="lru"`` (with packing off) reproduces the pre-predictive
  cache byte-for-byte: same eviction order, same counters, same bytes —
  property-tested against a literal re-implementation of the seed code.
- Block-packed entries serve bit-identical pixels through every
  ``get``/``coverage``/``put`` shape (superset serving, never-shrink
  union) while charging fewer bytes.
- The full predictive configuration (prefetch + reuse eviction + packing)
  never changes scan results or per-query ``pixels_decoded`` accounting
  vs a cache-off control — serial, ``execute_many``, ``serve``,
  mid-batch retile, and cross-process.
- The deprecated ``VideoStore`` kwargs map 1:1 onto the config objects.
"""
import threading
import warnings
from collections import OrderedDict

import numpy as np
import pytest

import _hypothesis_compat

_hypothesis_compat.install()

from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.codec.encode import EncoderConfig  # noqa: E402
from repro.core import (CacheConfig, DecodeConfig, NoTilingPolicy,  # noqa: E402
                        RegretPolicy, RemoteVideoStore, TileCache,
                        TuningConfig, VideoStore, VideoStoreServer,
                        WorkloadPredictor)
from repro.core.cost import CostModel  # noqa: E402
from repro.core.tile_cache import _covers  # noqa: E402

ENC = EncoderConfig(gop=16, qp=8)
MODEL = CostModel(beta=1.4e-8, gamma=1e-5)
MODEL.encode_per_pixel = 3.4e-8
MODEL.encode_per_tile = 1e-4

LRU = CacheConfig(eviction="lru", block_packed=False)


def fill(store, name, frames, dets, policy=None, sot_len=None):
    store.add_video(name, encoder=ENC, policy=policy or NoTilingPolicy(),
                    cost_model=MODEL, sot_len=sot_len)
    store.ingest(name, frames)
    store.add_detections(name, {f: d for f, d in enumerate(dets)})


def assert_regions_equal(a, b):
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        assert ra[:-1] == rb[:-1]
        np.testing.assert_array_equal(ra[-1], rb[-1])


@pytest.fixture(scope="module")
def long_video():
    from repro.data.video_gen import VideoSpec, ObjectSpec, generate

    spec = VideoSpec(height=96, width=160, n_frames=256, seed=7,
                     objects=[ObjectSpec("car", 2, (16, 24), 2.0),
                              ObjectSpec("person", 1, (18, 10), 1.0)])
    frames, dets = generate(spec)
    return frames, dets


# =========================================================== lru bit-for-bit
class _SeedLru:
    """The pre-predictive TileCache, verbatim (OrderedDict + popitem):
    the reference model ``eviction="lru"`` must match byte-for-byte."""

    def __init__(self, budget_bytes):
        self.budget_bytes = int(budget_bytes)
        self._lru = OrderedDict()          # key -> (arr, blocks)
        self.hits = self.misses = self.evictions = 0
        self.bytes = 0

    def get(self, key, n_frames=None, blocks=None):
        requested = None if blocks is None else frozenset(blocks)
        e = self._lru.get(key)
        if e is None or (n_frames is not None
                         and e[0].shape[0] < n_frames) \
                or not _covers(e[1], requested):
            self.misses += 1
            return None
        self._lru.move_to_end(key)
        self.hits += 1
        return e[0] if n_frames is None else e[0][:n_frames]

    def put(self, key, arr, blocks=None):
        if arr.nbytes > self.budget_bytes:
            return
        new_blocks = None if blocks is None else frozenset(blocks)
        old = self._lru.pop(key, None)
        if old is not None:
            if old[0].shape[0] > arr.shape[0] \
                    or not _covers(new_blocks, old[1]):
                self._lru[key] = old
                return
            self.bytes -= old[0].nbytes
        self._lru[key] = (arr, new_blocks)
        self.bytes += arr.nbytes
        while self.bytes > self.budget_bytes and self._lru:
            _, victim = self._lru.popitem(last=False)
            self.bytes -= victim[0].nbytes
            self.evictions += 1

    def invalidate(self, before_epoch):
        doomed = [k for k in self._lru if k[2] < before_epoch]
        for k in doomed:
            self.bytes -= self._lru.pop(k)[0].nbytes


def _arr(n_frames, tag):
    a = np.arange(n_frames * 16 * 16, dtype=np.float32)
    return (a + 1000.0 * tag).reshape(n_frames, 16, 16)


# op = ("put", tile, epoch, depth, blocks, tag) | ("get", tile, epoch,
# depth, blocks) | ("invalidate", epoch)
_blocks = st.sampled_from([None, (0,), (1, 2), (0, 1, 2, 3)])
_ops = st.lists(
    st.tuples(st.sampled_from(["put", "put", "get", "invalidate"]),
              st.integers(min_value=0, max_value=5),
              st.integers(min_value=0, max_value=1),
              st.sampled_from([2, 4, 8]),
              _blocks,
              st.integers(min_value=0, max_value=7)),
    min_size=1, max_size=60)


# the shim's @given produces a zero-arg wrapper, so this property test
# lives at module level
@settings(max_examples=60)
@given(ops=_ops)
def test_lru_mode_matches_seed_implementation(ops):
    budget = 3 * _arr(8, 0).nbytes
    cache = TileCache(config=CacheConfig(budget_bytes=budget,
                                         eviction="lru",
                                         block_packed=False))
    seed = _SeedLru(budget)
    for op, tile, epoch, depth, blocks, tag in ops:
        key = ("v", 0, epoch, tile)
        if op == "put":
            a = _arr(depth, tag)
            cache.put(key, a, blocks=blocks)
            seed.put(key, a, blocks=blocks)
        elif op == "get":
            got = cache.get(key, n_frames=depth, blocks=blocks)
            want = seed.get(key, n_frames=depth, blocks=blocks)
            assert (got is None) == (want is None)
            if got is not None:
                np.testing.assert_array_equal(got, want)
        else:
            cache.invalidate(before_epoch=epoch)
            seed.invalidate(before_epoch=epoch)
        # eviction ORDER and accounting, not just membership
        assert list(cache._lru) == list(seed._lru)
        st_ = cache.stats()
        assert st_.bytes_cached == seed.bytes
        assert st_.evictions == seed.evictions
        assert (st_.hits, st_.misses) == (seed.hits, seed.misses)


# ============================================================ packed entries
def _masked(n_frames, blocks, tag=0):
    """A canvas whose pixels outside ``blocks`` are zero — exactly what a
    masked decode produces (entry semantics: outside = not content)."""
    a = _arr(n_frames, tag)
    grid = np.zeros((2, 2), dtype=bool)
    grid.flat[list(blocks)] = True
    mask = np.repeat(np.repeat(grid, 8, 0), 8, 1)
    return a * mask


class TestBlockPackedEntries:
    def test_superset_serving_roundtrip(self):
        c = TileCache(config=CacheConfig(budget_bytes=1 << 20,
                                         block_packed=True))
        key = ("v", 0, 0, 0)
        a = _masked(8, {0, 1})
        c.put(key, a, blocks=[0, 1])
        # subset masks and frame prefixes serve bit-identically
        np.testing.assert_array_equal(c.get(key, blocks=[0, 1]), a)
        np.testing.assert_array_equal(c.get(key, 4, blocks=[0]), a[:4])
        # outside the mask, deeper, or full-tile requests miss
        assert c.get(key, blocks=[2]) is None
        assert c.get(key, 16, blocks=[0]) is None
        assert c.get(key) is None
        # packing actually saved budget (2 of 4 blocks resident)
        st_ = c.stats()
        assert 0 < st_.bytes_cached < a.nbytes
        assert st_.packed_bytes_saved == a.nbytes - st_.bytes_cached

    def test_union_widening_never_shrinks(self):
        c = TileCache(config=CacheConfig(budget_bytes=1 << 20,
                                         block_packed=True))
        key = ("v", 0, 0, 0)
        c.put(key, _masked(8, {0}), blocks=[0])
        # the scheduler's covering-miss re-decode: the disjoint union at
        # max depth replaces the entry ...
        u = _masked(8, {0, 3})
        c.put(key, u, blocks=[0, 3])
        assert c.coverage(key) == (8, frozenset({0, 3}))
        np.testing.assert_array_equal(c.get(key, blocks=[3]), u)
        np.testing.assert_array_equal(c.get(key, blocks=[0]), u)
        # ... and narrower or shallower puts are refused
        c.put(key, _masked(4, {1}), blocks=[1])
        c.put(key, _masked(4, {0, 3}), blocks=[0, 3])
        assert c.coverage(key) == (8, frozenset({0, 3}))

    def test_packed_serves_identical_to_unpacked(self):
        packed = TileCache(config=CacheConfig(budget_bytes=1 << 20,
                                              block_packed=True))
        plain = TileCache(config=CacheConfig(budget_bytes=1 << 20,
                                             block_packed=False))
        for tile, blocks in enumerate([{0}, {1, 2}, {0, 1, 2, 3}, None]):
            key = ("v", 0, 0, tile)
            a = _arr(8, tile) if blocks is None else _masked(8, blocks, tile)
            bl = None if blocks is None else sorted(blocks)
            packed.put(key, a, blocks=bl)
            plain.put(key, a, blocks=bl)
            for req in (None, [0], [1], [2, 3]):
                for nf in (None, 2, 8):
                    g1 = packed.get(key, nf, blocks=req)
                    g2 = plain.get(key, nf, blocks=req)
                    assert (g1 is None) == (g2 is None)
                    if g1 is not None:
                        np.testing.assert_array_equal(g1, g2)
        assert packed.stats().bytes_cached < plain.stats().bytes_cached

    def test_full_tile_entries_not_packed(self):
        c = TileCache(config=CacheConfig(budget_bytes=1 << 20,
                                         block_packed=True))
        a = _arr(8, 0)
        c.put(("v", 0, 0, 0), a)
        st_ = c.stats()
        assert st_.bytes_cached == a.nbytes
        assert st_.packed_bytes_saved == 0
        # full-tile serving stays a zero-copy prefix view
        assert c.get(("v", 0, 0, 0), 4).base is not None


# ======================================================= expected-reuse evict
class TestReuseEviction:
    def test_reused_entry_outlives_older_colder(self):
        a = _arr(4, 0)
        c = TileCache(config=CacheConfig(budget_bytes=3 * a.nbytes,
                                         eviction="reuse",
                                         block_packed=False))
        for t in range(3):
            c.put(("v", 0, 0, t), a)
        # tile 0 is the OLDEST but re-accessed twice; pure LRU would keep
        # it only by recency — reuse weighting keeps it by importance
        c.get(("v", 0, 0, 0))
        c.get(("v", 0, 0, 0))
        c.get(("v", 0, 0, 1))          # tile 1 re-accessed once
        c.get(("v", 0, 0, 2))
        c.get(("v", 0, 0, 1))
        # tiles now ordered [0, 2, 1] by recency; weights 2, 1, 2
        c.put(("v", 0, 0, 3), a)       # over budget: evict lowest weight
        assert ("v", 0, 0, 2) not in c
        assert all(("v", 0, 0, t) in c for t in (0, 1, 3))
        assert c.stats().evictions_by_reason == {"budget": 1}

    def test_zero_weight_ties_break_oldest_first(self):
        a = _arr(4, 0)
        c = TileCache(config=CacheConfig(budget_bytes=3 * a.nbytes,
                                         eviction="reuse",
                                         block_packed=False))
        for t in range(3):
            c.put(("v", 0, 0, t), a)
        c.put(("v", 0, 0, 3), a)
        assert ("v", 0, 0, 0) not in c     # all weight 0: LRU order


# ================================================================= prefetch
class TestPredictor:
    def test_monotone_progressions(self):
        p = WorkloadPredictor(depth=2)
        assert p.observe("v", 0) == ()
        assert p.observe("v", 1) == ()
        assert p.observe("v", 2) == (3, 4)       # stride +1
        assert p.observe("v", 2) == ()           # warm repeat: no evidence
        assert p.observe("v", 3) == (4, 5)
        q = WorkloadPredictor(depth=1)
        for sid, want in [(9, ()), (7, ()), (5, (3,)), (3, (1,))]:
            assert q.observe("w", sid) == want   # stride -2
        r = WorkloadPredictor(depth=2)
        for sid, want in [(0, ()), (5, ()), (1, ()), (8, ())]:
            assert r.observe("x", sid) == want   # random access: nothing

    def test_per_video_isolation(self):
        p = WorkloadPredictor(depth=1)
        for v, sid in [("a", 0), ("b", 10), ("a", 1), ("b", 20)]:
            assert p.observe(v, sid) == ()
        assert p.observe("a", 2) == (3,)
        assert p.observe("b", 30) == (40,)

    def test_prefetch_never_evicts_hotter_entry(self):
        a = _arr(4, 0)
        c = TileCache(config=CacheConfig(budget_bytes=2 * a.nbytes,
                                         eviction="reuse",
                                         block_packed=False))
        c.put(("v", 0, 0, 0), a)
        c.put(("v", 0, 0, 1), a)
        c.get(("v", 0, 0, 0))
        c.get(("v", 0, 0, 1))          # both entries now hot (uses > 0)
        assert not c.put(("v", 1, 0, 0), a, prefetch=True)
        assert ("v", 1, 0, 0) not in c           # dropped, not admitted
        assert ("v", 0, 0, 0) in c and ("v", 0, 0, 1) in c
        assert c.stats().prefetch_wasted == 1
        c.get(("v", 0, 0, 1))
        # a cold (never re-accessed) resident IS fair game for a prefetch
        c2 = TileCache(config=CacheConfig(budget_bytes=2 * a.nbytes,
                                          eviction="reuse",
                                          block_packed=False))
        c2.put(("v", 0, 0, 0), a)
        c2.put(("v", 0, 0, 1), a)
        c2.get(("v", 0, 0, 1))
        assert c2.put(("v", 1, 0, 0), a, prefetch=True)
        assert ("v", 0, 0, 0) not in c2          # the cold one went
        assert ("v", 0, 0, 1) in c2
        assert c2.stats().evictions_by_reason == {"prefetch": 1}

    def test_prefetch_hit_and_waste_accounting(self):
        a = _arr(4, 0)
        c = TileCache(config=CacheConfig(budget_bytes=1 << 20,
                                         eviction="reuse",
                                         block_packed=False))
        c.put(("v", 0, 0, 0), a, prefetch=True)
        c.put(("v", 0, 0, 1), a, prefetch=True)
        assert c.get(("v", 0, 0, 0)) is not None
        assert c.get(("v", 0, 0, 0)) is not None  # only the FIRST hit counts
        c.invalidate(video="v", sot_id=0, before_epoch=1)  # 1 never hit
        st_ = c.stats()
        assert st_.prefetch_hits == 1
        assert st_.prefetch_wasted == 1


# =============================================== bit-identity vs cache off
PREDICTIVE = CacheConfig(prefetch=True, prefetch_depth=2,
                         eviction="reuse", block_packed=True)


def _windows(store, n, w=32):
    return [store.scan("cam0").labels("car").frames(i * w, (i + 1) * w)
            for i in range(n)]


class TestBitIdentityVsCacheOff:
    def test_serial_sliding_windows(self, long_video):
        frames, dets = long_video
        pred = VideoStore(cache=PREDICTIVE)
        ctrl = VideoStore(cache=CacheConfig(budget_bytes=0))
        fill(pred, "cam0", frames, dets, sot_len=32)
        fill(ctrl, "cam0", frames, dets, sot_len=32)
        try:
            warm_misses = []
            for qp, qc in zip(_windows(pred, 8), _windows(ctrl, 8)):
                rp, rc = qp.execute(), qc.execute()
                assert_regions_equal(rp.regions, rc.regions)
                # a query is only ever charged for decodes that actually
                # ran on its behalf — never more than the cache-off cost
                assert rp.stats.pixels_decoded <= rc.stats.pixels_decoded
                st_ = pred.drain_prefetch(timeout=30)
                warm_misses.append(rp.stats.cache_misses)
            # once the predictor locks on, whole windows decode 0 tiles
            assert warm_misses[-1] == 0 and warm_misses[-2] == 0
            assert st_.prefetch_issued > 0 and st_.prefetch_hits > 0
            doc = pred.stats()["cache"]
            for k in ("prefetch_issued", "prefetch_hits", "prefetch_wasted",
                      "packed_bytes_saved", "evictions_by_reason"):
                assert k in doc
        finally:
            pred.close()
            ctrl.close()

    def test_accounting_sums_to_actual_decode_work(self, long_video):
        """Without prefetch, first-consumer charging must make per-query
        pixels_decoded sum EXACTLY to the store's decoded-pixel total —
        reuse eviction and block packing must not disturb it."""
        frames, dets = long_video
        store = VideoStore(cache=CacheConfig(eviction="reuse",
                                             block_packed=True))
        fill(store, "cam0", frames, dets, sot_len=32)
        try:
            for q in _windows(store, 6):
                q.execute()
            for q in _windows(store, 6):   # warm repeats
                q.execute()
            charged = sum(s.pixels_decoded for s in store.history)
            actual = store.video("cam0").store.pixels_decoded_total
            assert charged == actual
        finally:
            store.close()

    def test_execute_many_and_serve(self, long_video):
        frames, dets = long_video
        pred = VideoStore(cache=PREDICTIVE)
        ctrl = VideoStore(cache=CacheConfig(budget_bytes=0))
        fill(pred, "cam0", frames, dets, sot_len=32)
        fill(ctrl, "cam0", frames, dets, sot_len=32)
        try:
            rb = pred.execute_many(_windows(pred, 8))
            rs = [q.execute() for q in _windows(ctrl, 8)]
            for b, s in zip(rb, rs):
                assert_regions_equal(b.regions, s.regions)
            pred.drain_prefetch(timeout=30)
            with pred.serve() as session:
                futs = [session.submit(q) for q in _windows(pred, 8)]
                for f, s in zip(futs, rs):
                    assert_regions_equal(f.result().regions, s.regions)
        finally:
            pred.close()
            ctrl.close()

    def test_mid_batch_retile(self, long_video):
        """An inline policy re-tiling between plans of one batch must not
        let predictive caching leak pre-retile pixels."""
        frames, dets = long_video
        kw = dict(tuning=TuningConfig(mode="inline"))
        pred = VideoStore(cache=PREDICTIVE, **kw)
        ctrl = VideoStore(cache=CacheConfig(budget_bytes=0), **kw)
        for s in (pred, ctrl):
            fill(s, "cam0", frames[:128], dets[:128],
                 policy=RegretPolicy(eta=0.0), sot_len=32)
        try:
            queries = lambda s: [s.scan("cam0").labels(lb).frames(lo, lo + 32)
                                 for lb in ("car", "person")
                                 for lo in (0, 32, 64, 96)]
            rp = pred.execute_many(queries(pred))
            rc = ctrl.execute_many(queries(ctrl))
            for a, b in zip(rp, rc):
                assert_regions_equal(a.regions, b.regions)
            # the eager policy really retiled (epochs moved) ...
            assert any(rec.epoch > 0
                       for rec in pred.video("cam0").store.sots)
            pred.drain_prefetch(timeout=30)
            # ... and no stale-epoch entry survives, prefetched or not
            for key in list(pred.tile_cache._lru):
                video, sot_id, epoch, _ = key
                rec = pred.video(video).store.sots[sot_id]
                assert epoch == rec.epoch
        finally:
            pred.close()
            ctrl.close()

    def test_cross_process(self, tmp_path, long_video):
        frames, dets = long_video
        store = VideoStore(cache=PREDICTIVE)
        ctrl = VideoStore(cache=CacheConfig(budget_bytes=0))
        fill(store, "cam0", frames, dets, sot_len=32)
        fill(ctrl, "cam0", frames, dets, sot_len=32)
        sock = str(tmp_path / "tasm.sock")
        server = VideoStoreServer(store, path=sock, owns_store=False).start()
        client = RemoteVideoStore(sock)
        try:
            # the remote twin of the unified surface
            cfg = client.config()
            assert cfg["cache"] == store.cache_config
            assert cfg["tuning"] == store.tuning_config
            last = None
            for i in range(8):
                r = client.scan("cam0").labels("car") \
                          .frames(i * 32, (i + 1) * 32).execute()
                rc = ctrl.scan("cam0").labels("car") \
                         .frames(i * 32, (i + 1) * 32).execute()
                assert_regions_equal(r.regions, rc.regions)
                cs = client.drain_prefetch(timeout=30)
                last = r
            assert last.stats.cache_misses == 0
            assert cs.prefetch_hits > 0
            assert client.stats()["cache"]["prefetch_issued"] > 0
        finally:
            client.close()
            server.stop()
            store.close()
            ctrl.close()


# ========================================================== config surface
class TestConfigSurface:
    def test_deprecated_kwargs_map_1to1(self):
        cases = [
            (dict(tile_cache_bytes=123),
             lambda s: s.cache_config.budget_bytes == 123),
            (dict(tuning="inline"),
             lambda s: s.tuning_config.mode == "inline"),
            (dict(tuner_admission="gated"),
             lambda s: s.tuning_config.admission == "gated"),
            (dict(roi_decode=False), lambda s: s.roi_decode is False),
            (dict(decode_backend="batched"),
             lambda s: s.decode_backend == "batched"),
        ]
        for kwargs, check in cases:
            with pytest.warns(DeprecationWarning):
                s = VideoStore(**kwargs)
            try:
                assert check(s), kwargs
            finally:
                s.close()

    def test_alias_plus_config_is_an_error(self):
        with pytest.raises(ValueError):
            VideoStore(cache=CacheConfig(), tile_cache_bytes=0)
        with pytest.raises(ValueError):
            VideoStore(tuning=TuningConfig(), tuner_admission="gated")
        with pytest.raises(ValueError):
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                VideoStore(decode=DecodeConfig(), roi_decode=False)

    def test_env_precedence(self, monkeypatch):
        monkeypatch.setenv("REPRO_CACHE_EVICTION", "lru")
        monkeypatch.setenv("REPRO_CACHE_BYTES", "4096")
        assert CacheConfig().resolve().eviction == "lru"
        assert CacheConfig().resolve().budget_bytes == 4096
        # an explicit field beats the environment
        cfg = CacheConfig(budget_bytes=8192, eviction="reuse").resolve()
        assert (cfg.budget_bytes, cfg.eviction) == (8192, "reuse")
        monkeypatch.setenv("REPRO_DECODE_BACKEND", "batched")
        assert DecodeConfig().resolve().backend == "batched"
        assert DecodeConfig(backend="numpy").resolve().backend == "numpy"

    def test_docs_roundtrip(self):
        for cfg in (CacheConfig(budget_bytes=1, eviction="lru",
                                prefetch=True, prefetch_depth=3,
                                block_packed=False),
                    TuningConfig(mode="off", admission="gated", max_log=9),
                    DecodeConfig(backend="batched", roi=False,
                                 max_workers=2)):
            assert type(cfg).from_doc(cfg.to_doc()) == cfg

    def test_invalid_values_raise(self):
        with pytest.raises(ValueError):
            CacheConfig(eviction="fifo").resolve()
        with pytest.raises(ValueError):
            TuningConfig(mode="sometimes").resolve()
        with pytest.raises(ValueError):
            DecodeConfig(backend="torch").resolve()
        with pytest.raises(ValueError):
            TileCache(budget_bytes=1, config=CacheConfig())
