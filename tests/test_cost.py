"""Cost model: P/T accounting, calibration recovery, monotonicity."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.cost import (CostModel, calibrate, calibrate_io,
                             pixels_and_tiles, query_cost,
                             roi_pixels_and_tiles)
from repro.core.layout import single_tile_layout, uniform_layout

H, W = 192, 320
GOP = 16


def test_untiled_pixels_whole_gop():
    omega = single_tile_layout(H, W)
    bbf = {3: [(0, 0, 10, 10)]}  # one box on frame 3
    p, t = pixels_and_tiles(omega, bbf, gop=GOP, sot_frames=(0, GOP))
    # decode frames 0..3 of the only tile
    assert p == H * W * 4
    assert t == 1


def test_tiled_counts_only_touched_tiles():
    lay = uniform_layout(H, W, 2, 2)
    bbf = {0: [(0, 0, 10, 10)]}  # top-left corner only
    p, t = pixels_and_tiles(lay, bbf, gop=GOP, sot_frames=(0, GOP))
    assert t == 1
    assert p == lay.tile_pixels(0) * 1


def test_multi_gop_accounting():
    omega = single_tile_layout(H, W)
    bbf = {0: [(0, 0, 8, 8)], GOP + 4: [(0, 0, 8, 8)]}
    p, t = pixels_and_tiles(omega, bbf, gop=GOP, sot_frames=(0, 2 * GOP))
    assert t == 2  # the tile is opened in both GOPs
    assert p == H * W * 1 + H * W * 5


def test_calibrate_recovers_linear_model():
    rng = np.random.default_rng(0)
    beta, gamma = 2e-8, 3e-4
    rows = []
    for _ in range(200):
        p = rng.uniform(1e4, 1e7)
        t = rng.uniform(1, 30)
        noise = rng.normal(0, 1e-6)
        rows.append((p, t, beta * p + gamma * t + noise))
    m = calibrate(rows)
    assert abs(m.beta - beta) / beta < 0.05
    assert abs(m.gamma - gamma) / gamma < 0.05
    assert m.r_squared > 0.99


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(1, 4))
def test_cost_monotone_in_boxes(r, c):
    lay = uniform_layout(H, W, r, c)
    m = CostModel(beta=1e-8, gamma=1e-4)
    bbf1 = {0: [(0, 0, 16, 16)]}
    bbf2 = {0: [(0, 0, 16, 16), (100, 200, 150, 300)]}
    c1 = query_cost(lay, bbf1, m, gop=GOP, sot_frames=(0, GOP))
    c2 = query_cost(lay, bbf2, m, gop=GOP, sot_frames=(0, GOP))
    assert c2 >= c1


def test_tiling_never_increases_pixels():
    """P(L) <= P(omega) for any layout (tiles subset the frame)."""
    omega = single_tile_layout(H, W)
    bbf = {f: [(20, 30, 60, 90)] for f in range(GOP)}
    p_o, _ = pixels_and_tiles(omega, bbf, gop=GOP, sot_frames=(0, GOP))
    for r, c in [(2, 2), (3, 5), (4, 4)]:
        lay = uniform_layout(H, W, r, c)
        p_l, _ = pixels_and_tiles(lay, bbf, gop=GOP, sot_frames=(0, GOP))
        assert p_l <= p_o


def test_io_term_zero_for_full_tile_mask():
    """When the mask covers the whole tile, io_pixels == pixels and the
    three-term cost collapses to the two-term one — the granularities
    agree at the boundary."""
    m = CostModel(beta=1e-8, gamma=1e-4, io_per_pixel=5e-9)
    omega = single_tile_layout(H, W)
    bbf = {0: [(0, 0, H, W)]}  # whole frame -> full-tile block coverage
    p, t, iop, masks = roi_pixels_and_tiles(omega, bbf, gop=GOP,
                                            sot_frames=(0, GOP))
    assert masks == {0: None}
    assert iop == p
    assert m.cost(p, t, iop) == m.cost(p, t)


def test_io_term_charges_opened_not_decoded_gap():
    m = CostModel(beta=1e-8, gamma=1e-4, io_per_pixel=5e-9)
    omega = single_tile_layout(H, W)
    bbf = {0: [(0, 0, 8, 8)]}  # one 8x8 block of the full-frame tile
    p, t, iop, _ = roi_pixels_and_tiles(omega, bbf, gop=GOP,
                                        sot_frames=(0, GOP))
    assert p == 64 and iop == H * W  # one block gathered, whole tile opened
    assert m.cost(p, t, iop) == m.cost(p, t) + 5e-9 * (iop - p)
    # omitting io_pixels keeps the legacy two-term estimate
    assert m.cost(p, t) == 1e-8 * p + 1e-4 * t


def test_calibrate_io_recovers_residual_slope():
    """calibrate_io fits only the residual — beta/gamma are untouched and
    the planted io_per_pixel is recovered."""
    rng = np.random.default_rng(1)
    beta, gamma, io = 2e-8, 3e-4, 6e-9
    base = CostModel(beta=beta, gamma=gamma)
    rows = []
    for _ in range(200):
        p = rng.uniform(64, 1e4)
        t = rng.uniform(1, 10)
        iop = p + rng.uniform(1e4, 1e7)
        noise = rng.normal(0, 1e-6)
        rows.append((p, t, iop,
                     beta * p + gamma * t + io * (iop - p) + noise))
    m = calibrate_io(rows, base)
    assert m.beta == beta and m.gamma == gamma
    assert abs(m.io_per_pixel - io) / io < 0.05
    assert m.io_r_squared > 0.99


def test_calibrate_io_clamps_negative_slope_to_zero():
    base = CostModel(beta=1e-8, gamma=1e-4)
    # decodes FASTER than the two-term model predicts: residual negative
    rows = [(64.0, 1.0, 1e6, 0.0) for _ in range(10)]
    m = calibrate_io(rows, base)
    assert m.io_per_pixel == 0.0
