"""Tile-layout algebra: unit + hypothesis property tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.layout import (ALIGN, MIN_TILE, TileLayout,
                               coarse_grained_layout, fine_grained_layout,
                               single_tile_layout, uniform_layout)

H, W = 192, 320


def boxes_strategy(h=H, w=W, max_boxes=6):
    def make_box(data):
        y1 = data.draw(st.integers(0, h - 9))
        x1 = data.draw(st.integers(0, w - 9))
        y2 = data.draw(st.integers(y1 + 1, h))
        x2 = data.draw(st.integers(x1 + 1, w))
        return (y1, x1, y2, x2)

    return st.lists(st.builds(lambda: None), min_size=0, max_size=0)


box_st = st.tuples(
    st.integers(0, H - 9), st.integers(0, W - 9),
    st.integers(1, H), st.integers(1, W),
).map(lambda t: (min(t[0], t[2] - 1), min(t[1], t[3] - 1),
                 max(t[2], t[0] + 1), max(t[3], t[1] + 1)))


class TestBasics:
    def test_single_tile(self):
        lay = single_tile_layout(H, W)
        assert lay.n_tiles == 1
        assert lay.tile_rect(0) == (0, 0, H, W)
        assert lay.total_pixels() == H * W

    def test_uniform_sums(self):
        lay = uniform_layout(H, W, 3, 5)
        assert sum(lay.heights) == H
        assert sum(lay.widths) == W
        assert lay.n_tiles == 15

    def test_uniform_alignment(self):
        lay = uniform_layout(H, W, 3, 5)
        for b in lay.row_offsets()[1:-1]:
            assert b % ALIGN == 0
        for b in lay.col_offsets()[1:-1]:
            assert b % ALIGN == 0

    def test_tiles_intersecting_brute_force(self):
        lay = uniform_layout(H, W, 4, 4)
        box = (10, 20, 100, 200)
        got = set(lay.tiles_intersecting(box))
        expect = set()
        for i in range(lay.n_tiles):
            y1, x1, y2, x2 = lay.tile_rect(i)
            if y1 < box[2] and box[0] < y2 and x1 < box[3] and box[1] < x2:
                expect.add(i)
        assert got == expect

    def test_fine_isolates_separated_boxes(self):
        boxes = [(0, 0, 32, 32), (160, 280, 190, 318)]
        lay = fine_grained_layout(H, W, boxes)
        t0 = set(lay.tiles_intersecting(boxes[0]))
        t1 = set(lay.tiles_intersecting(boxes[1]))
        assert not (t0 & t1)

    def test_coarse_single_central_tile(self):
        boxes = [(64, 96, 96, 160), (80, 120, 120, 200)]
        lay = coarse_grained_layout(H, W, boxes)
        tiles = {t for b in boxes for t in lay.tiles_intersecting(b)}
        assert len(tiles) == 1  # everything inside one big tile

    def test_empty_boxes_is_omega(self):
        assert fine_grained_layout(H, W, []) == single_tile_layout(H, W)


@settings(max_examples=40, deadline=None)
@given(st.lists(box_st, min_size=1, max_size=6),
       st.sampled_from(["fine", "coarse"]))
def test_partition_invariants(boxes, granularity):
    from repro.core.layout import partition

    lay = partition(H, W, boxes, granularity=granularity)
    # grid sums to frame
    assert sum(lay.heights) == H and sum(lay.widths) == W
    # no boundary crosses any box
    for b in boxes:
        assert not lay.boundary_crosses(b), (lay, b)
    # min tile dims respected
    assert all(h >= MIN_TILE or lay.n_rows == 1 for h in lay.heights)
    assert all(w >= MIN_TILE or lay.n_cols == 1 for w in lay.widths)
    # every box covered by its intersecting tiles
    for b in boxes:
        ts = lay.tiles_intersecting(b)
        assert ts
        area = 0
        for t in ts:
            y1, x1, y2, x2 = lay.tile_rect(t)
            iy = max(0, min(y2, b[2]) - max(y1, b[0]))
            ix = max(0, min(x2, b[3]) - max(x1, b[1]))
            area += iy * ix
        assert area == (b[2] - b[0]) * (b[3] - b[1])


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 6), st.integers(1, 10))
def test_uniform_layouts_valid(r, c):
    lay = uniform_layout(H, W, r, c)
    assert sum(lay.heights) == H and sum(lay.widths) == W
    assert all(h > 0 for h in lay.heights)
    assert all(w > 0 for w in lay.widths)
