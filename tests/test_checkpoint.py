"""Checkpointing: atomicity, async, elastic restore, crash-recovery loop."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import CheckpointManager
from repro.train.elastic import LoopConfig, recoverable_train_loop


def make_state(seed=0):
    k = jax.random.key(seed)
    return {"w": jax.random.normal(k, (16, 8)),
            "opt": {"m": jnp.zeros((16, 8)), "step": jnp.int32(0)}}


def trees_equal(a, b):
    return all(np.allclose(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


class TestSaveRestore:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        state = make_state()
        mgr.save(7, state)
        got, extra = mgr.restore(make_state(seed=1))
        assert trees_equal(got, state)

    def test_latest_pointer(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        for s in (1, 5, 9):
            mgr.save(s, make_state(s))
        assert mgr.latest_step() == 9
        got, _ = mgr.restore(make_state())
        assert trees_equal(got, make_state(9))

    def test_gc_keeps_k(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        for s in range(5):
            mgr.save(s, make_state(s))
        assert mgr.list_steps() == [3, 4]

    def test_partial_write_is_invisible(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, make_state(1))
        # simulate a crash mid-save: a .tmp dir with garbage
        tmp = pathlib.Path(tmp_path) / "step_000000002.tmp"
        tmp.mkdir()
        (tmp / "shard_0.npz").write_bytes(b"garbage")
        assert mgr.latest_step() == 1
        got, _ = mgr.restore(make_state())
        assert trees_equal(got, make_state(1))

    def test_structure_mismatch_raises(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, make_state())
        with pytest.raises(ValueError, match="structure"):
            mgr.restore({"different": jnp.zeros((2,))})

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save_async(3, make_state(3))
        mgr.wait()
        got, _ = mgr.restore(make_state())
        assert trees_equal(got, make_state(3))


class TestRecoverableLoop:
    def test_loop_recovers_from_fault(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        state = {"x": jnp.float32(0.0)}

        def step_fn(s, batch):
            return {"x": s["x"] + 1.0}, {"x": s["x"]}

        faults = {"armed": True}

        def fault_hook(step):
            if step == 7 and faults["armed"]:
                faults["armed"] = False
                raise RuntimeError("simulated node failure")

        def batches():
            while True:
                yield {}

        final, steps, restarts = recoverable_train_loop(
            state, batches(), step_fn, ckpt=mgr,
            cfg=LoopConfig(total_steps=12, checkpoint_every=5,
                           checkpoint_async=False),
            fault_hook=fault_hook)
        assert restarts == 1
        assert steps == 12
        # deterministic step_fn: recovery from step-5 checkpoint continues to 12
        assert float(final["x"]) == 12.0

    def test_loop_raises_after_max_restarts(self, tmp_path):
        mgr = CheckpointManager(tmp_path)

        def step_fn(s, b):
            raise RuntimeError("always down")

        def batches():
            while True:
                yield {}

        with pytest.raises(RuntimeError):
            recoverable_train_loop(
                {"x": jnp.float32(0)}, batches(), step_fn, ckpt=mgr,
                cfg=LoopConfig(total_steps=3, max_restarts=2))
