"""Serving layer: epoch-keyed tile cache, merging scan scheduler, concurrent
sessions, and the scans-racing-a-retile invariants."""
import threading

import numpy as np
import pytest

from repro.codec.encode import EncoderConfig
from repro.core import (NoTilingPolicy, RegretPolicy, TileCache, VideoStore,
                        uniform_layout)
from repro.core.cost import CostModel

ENC = EncoderConfig(gop=16, qp=8)
MODEL = CostModel(beta=1.4e-8, gamma=1e-5)
MODEL.encode_per_pixel = 3.4e-8
MODEL.encode_per_tile = 1e-4


def fill(store, name, frames, dets, policy=None):
    store.add_video(name, encoder=ENC, policy=policy or NoTilingPolicy(),
                    cost_model=MODEL)
    store.ingest(name, frames)
    store.add_detections(name, {f: d for f, d in enumerate(dets)})


def assert_regions_equal(a, b):
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        assert ra[:-1] == rb[:-1]
        np.testing.assert_array_equal(ra[-1], rb[-1])


# ---------------------------------------------------------------- TileCache
class TestTileCache:
    def test_roundtrip_and_prefix_serving(self):
        c = TileCache(budget_bytes=1 << 20)
        arr = np.arange(16 * 4 * 4, dtype=np.float32).reshape(16, 4, 4)
        key = ("v", 0, 0, 0)
        assert c.get(key) is None
        c.put(key, arr)
        np.testing.assert_array_equal(c.get(key), arr)
        # prefix requests serve views of the cached decode
        np.testing.assert_array_equal(c.get(key, n_frames=8), arr[:8])
        # a deeper request than cached is a miss ...
        c2 = TileCache(budget_bytes=1 << 20)
        c2.put(key, arr[:8])
        assert c2.get(key, n_frames=16) is None
        # ... and the deeper decode replaces the shallower entry
        c2.put(key, arr)
        assert c2.get(key, n_frames=16).shape[0] == 16
        # a shallower put never shrinks an entry
        c2.put(key, arr[:4])
        assert c2.get(key, n_frames=16).shape[0] == 16

    def test_lru_eviction_respects_byte_budget(self):
        arr = np.zeros((4, 8, 8), dtype=np.float32)  # 1 KiB each
        c = TileCache(budget_bytes=3 * arr.nbytes)
        for i in range(3):
            c.put(("v", 0, 0, i), arr)
        c.get(("v", 0, 0, 0))               # tile 0 now most-recent
        c.put(("v", 0, 0, 3), arr)          # over budget: evict LRU (tile 1)
        assert ("v", 0, 0, 1) not in c
        assert all(("v", 0, 0, i) in c for i in (0, 2, 3))
        st = c.stats()
        assert st.evictions == 1 and st.bytes_cached == 3 * arr.nbytes
        # arrays larger than the whole budget are never cached
        big = np.zeros((64, 64, 64), dtype=np.float32)
        c.put(("v", 0, 0, 9), big)
        assert ("v", 0, 0, 9) not in c

    def test_epoch_invalidation(self):
        c = TileCache(budget_bytes=1 << 20)
        arr = np.zeros((4, 4, 4), dtype=np.float32)
        c.put(("v", 0, 0, 0), arr)
        c.put(("v", 0, 1, 0), arr)
        c.put(("v", 1, 0, 0), arr)
        c.put(("w", 0, 0, 0), arr)
        assert c.invalidate("v", 0, before_epoch=1) == 1
        assert ("v", 0, 0, 0) not in c and ("v", 0, 1, 0) in c
        assert c.invalidate(video="v") == 2
        assert len(c) == 1 and ("w", 0, 0, 0) in c

    def test_zero_budget_disables_cache(self):
        c = TileCache(budget_bytes=0)
        arr = np.zeros((4, 4, 4), dtype=np.float32)
        c.put(("v", 0, 0, 0), arr)
        assert c.get(("v", 0, 0, 0)) is None and len(c) == 0


# ------------------------------------------------------------ cached scans
class TestCachedScans:
    def test_repeat_scan_decodes_zero_tiles(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        q = store.scan("cam0").labels("car").frames(0, 32)
        r1 = q.execute()
        decoded_after_first = store.video("cam0").store.tiles_decoded_total
        assert r1.stats.cache_misses > 0
        r2 = q.execute()
        # identical repeat: every tile served from cache, zero decodes
        assert r2.stats.cache_misses == 0
        assert r2.stats.cache_hits == r1.stats.tiles_fetched
        assert r2.stats.cache_hit_rate == 1.0
        assert store.video("cam0").store.tiles_decoded_total == \
            decoded_after_first
        assert_regions_equal(r1.regions, r2.regions)

    def test_cache_disabled_decodes_every_time(self, small_video):
        frames, dets = small_video
        store = VideoStore(tile_cache_bytes=0)
        fill(store, "cam0", frames, dets)
        q = store.scan("cam0").labels("car").frames(0, 32)
        r1, r2 = q.execute(), q.execute()
        assert r1.stats.cache_misses > 0 and r2.stats.cache_misses > 0
        assert r2.stats.cache_hits == 0
        assert_regions_equal(r1.regions, r2.regions)

    def test_deeper_scan_after_shallow_redecodes(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        store.scan("cam0").labels("car").frames(0, 4).execute()
        r = store.scan("cam0").labels("car").frames(0, 32).execute()
        # cached 4-frame decodes cannot serve the 32-frame scan
        assert r.stats.cache_misses > 0
        for f, (y1, x1, y2, x2), px in r.regions:
            assert np.abs(px - frames[f, y1:y2, x1:x2]).mean() < 6.0

    def test_subset_scan_is_all_hits(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        store.scan("cam0").labels("car").frames(0, 32).execute()
        r = store.scan("cam0").labels("car").frames(0, 7).execute()
        # prefix of cached frame depth: served entirely from cache
        assert r.stats.cache_misses == 0 and r.stats.cache_hits > 0
        for f, (y1, x1, y2, x2), px in r.regions:
            assert np.abs(px - frames[f, y1:y2, x1:x2]).mean() < 6.0


# ------------------------------------------------------------ execute_many
class TestExecuteMany:
    def test_overlapping_batch_decodes_shared_tiles_once(self, small_video):
        frames, dets = small_video
        queries = [("car", (0, 32)), ("car", (0, 16)),
                   ("car", (8, 32)), ("person", (0, 32))]

        serial = VideoStore(tile_cache_bytes=0)  # cold, no reuse at all
        fill(serial, "cam0", frames, dets)
        serial_res = [serial.scan("cam0").labels(l).frames(*fr).execute()
                      for l, fr in queries]

        batch = VideoStore()
        fill(batch, "cam0", frames, dets)
        base = batch.video("cam0").store.tiles_decoded_total
        batch_res = batch.execute_many(
            [batch.scan("cam0").labels(l).frames(*fr) for l, fr in queries])

        # each shared (sot, tile) decoded exactly once: the batch decodes
        # the union of needed tiles, strictly less than the serial sum
        union = {(ss.sot_id, t)
                 for r in batch_res for ss in r.plan.sot_scans
                 for t in ss.tile_idxs}
        assert batch.video("cam0").store.tiles_decoded_total - base == \
            len(union)
        assert sum(r.stats.cache_misses for r in batch_res) == len(union)
        serial_decodes = sum(r.stats.cache_misses for r in serial_res)
        assert serial_decodes > len(union)
        # per-query regions bit-identical to N serial execute() calls
        for rs, rb in zip(serial_res, batch_res):
            assert_regions_equal(rs.regions, rb.regions)
        # per-query accounting covers exactly the tiles each query needed
        for r in batch_res:
            needed = sum(len(ss.tile_idxs) for ss in r.plan.sot_scans)
            assert r.stats.tiles_fetched == needed

    def test_batch_with_retiling_policy_matches_serial(self, small_video):
        frames, dets = small_video
        n = 10  # enough repeats to push RegretPolicy over its threshold

        # inline tuning on both: this test pins the synchronous mid-batch
        # retile semantics (background tuning is covered in test_tuner.py)
        serial = VideoStore(tile_cache_bytes=0, tuning="inline")
        fill(serial, "cam0", frames, dets, policy=RegretPolicy())
        serial_res = [
            serial.scan("cam0").labels("car").frames(0, 32).execute()
            for _ in range(n)]
        assert any(r.stats.retile_s > 0 for r in serial_res)  # it retiled

        batch = VideoStore(tuning="inline")
        fill(batch, "cam0", frames, dets, policy=RegretPolicy())
        batch_res = batch.execute_many(
            [batch.scan("cam0").labels("car").frames(0, 32)
             for _ in range(n)])

        # a mid-batch retile bumps the epoch; later queries re-fetch at the
        # new epoch, so the merged batch stays bit-identical to serial
        for rs, rb in zip(serial_res, batch_res):
            assert_regions_equal(rs.regions, rb.regions)
        layouts = lambda s: [(r.layout, r.epoch)
                             for r in s.video("cam0").store.sots]
        assert layouts(serial) == layouts(batch)

    def test_mixed_depth_batch_matches_serial(self, small_video):
        frames, dets = small_video
        H, W = frames.shape[1:]
        queries = [("car", (0, 5)), ("person", (0, 14)), ("car", (0, 16))]

        serial = VideoStore(tile_cache_bytes=0)
        fill(serial, "cam0", frames, dets)
        serial.retile("cam0", 0, uniform_layout(H, W, 2, 2))
        sres = [serial.scan("cam0").labels(l).frames(*fr).execute()
                for l, fr in queries]

        batch = VideoStore()
        fill(batch, "cam0", frames, dets)
        batch.retile("cam0", 0, uniform_layout(H, W, 2, 2))
        # one group, members needing different tiles at different frame
        # depths: the fetch decodes per-tile at that tile's deepest need
        bres = batch.execute_many(
            [batch.scan("cam0").labels(l).frames(*fr) for l, fr in queries])
        for rs, rb in zip(sres, bres):
            assert_regions_equal(rs.regions, rb.regions)

    def test_mixed_decode_false_plans(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        res = store.execute_many([
            store.scan("cam0").labels("car").frames(0, 16),
            store.scan("cam0").labels("car").frames(0, 16).decode(False)])
        assert res[0].regions and res[1].regions == []
        assert res[1].stats.tiles_fetched == 0
        assert res[1].stats.pixels_decoded > 0  # estimates still fill


# ------------------------------------------------------- retile invariants
class TestRetileRaces:
    def test_stale_plan_recomputes_against_new_layout(self, small_video):
        frames, dets = small_video
        H, W = frames.shape[1:]
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        plan = store.scan("cam0").labels("car").frames(0, 16).explain()
        store.retile("cam0", 0, uniform_layout(H, W, 2, 2))
        res = store.execute(plan)  # stale epoch: tiles recomputed
        assert res.stats.regions == plan.n_regions
        for f, (y1, x1, y2, x2), px in res.regions:
            assert np.abs(px - frames[f, y1:y2, x1:x2]).mean() < 6.0

    def test_cache_never_serves_pre_retile_pixels(self, small_video):
        frames, dets = small_video
        H, W = frames.shape[1:]
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        q = store.scan("cam0").labels("car").frames(0, 16)
        q.execute()  # warm the cache at epoch 0
        store.retile("cam0", 0, uniform_layout(H, W, 2, 2))
        # epoch-0 entries are purged, nothing cached at the new epoch
        assert all(k[2] != 0 for k in store.tile_cache._lru
                   if k[:2] == ("cam0", 0))
        r = q.execute()
        assert r.stats.cache_misses > 0  # re-decoded, not served stale
        # pixels must come from the new layout's encode: compare against a
        # control store retiled identically but never cached
        control = VideoStore(tile_cache_bytes=0)
        fill(control, "cam0", frames, dets)
        control.retile("cam0", 0, uniform_layout(H, W, 2, 2))
        assert_regions_equal(control.scan("cam0").labels("car")
                             .frames(0, 16).execute().regions, r.regions)

    def test_concurrent_scans_racing_retiles(self, small_video):
        frames, dets = small_video
        H, W = frames.shape[1:]
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        expected_regions = len(
            store.scan("cam0").labels("car").frames(0, 32).execute().regions)
        errors, results = [], []
        lock = threading.Lock()

        def scan_loop():
            try:
                for _ in range(6):
                    r = store.scan("cam0").labels("car").frames(0, 32) \
                             .execute()
                    with lock:
                        results.append(r)
            except Exception as e:  # pragma: no cover - failure path
                errors.append(e)

        def retile_loop():
            try:
                for i in range(4):
                    g = 2 + i % 2
                    store.retile("cam0", i % 2, uniform_layout(H, W, g, g))
            except Exception as e:  # pragma: no cover - failure path
                errors.append(e)

        threads = [threading.Thread(target=scan_loop) for _ in range(3)] \
            + [threading.Thread(target=retile_loop)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        assert len(results) == 18
        for r in results:  # every scan saw a consistent layout + pixels
            assert len(r.regions) == expected_regions
            for f, (y1, x1, y2, x2), px in r.regions:
                assert np.abs(px - frames[f, y1:y2, x1:x2]).mean() < 6.0


# ---------------------------------------------------------- serve sessions
class TestServingSession:
    def test_concurrent_submissions_merge_and_match_serial(self, small_video):
        frames, dets = small_video
        serial = VideoStore(tile_cache_bytes=0)
        fill(serial, "cam0", frames, dets)
        want = serial.scan("cam0").labels("car").frames(0, 32).execute()

        store = VideoStore()
        fill(store, "cam0", frames, dets)
        with store.serve() as session:
            futs = [session.submit(
                store.scan("cam0").labels("car").frames(0, 32))
                for _ in range(8)]
            results = [f.result(timeout=60) for f in futs]
        for r in results:
            assert_regions_equal(want.regions, r.regions)
        # across the whole session each tile was decoded at most once
        union = {(ss.sot_id, t) for ss in results[0].plan.sot_scans
                 for t in ss.tile_idxs}
        assert sum(r.stats.cache_misses for r in results) == len(union)

    def test_bad_query_fails_only_its_future(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        with store.serve() as session:
            bad = session.submit(store.scan("cam0").frames(0, 8))  # no labels
            good = session.submit(store.scan("cam0").labels("car"))
            with pytest.raises(ValueError, match="labels"):
                bad.result(timeout=60)
            assert good.result(timeout=60).regions

    def test_submit_after_close_raises(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        session = store.serve()
        session.close()
        with pytest.raises(RuntimeError, match="closed"):
            session.submit(store.scan("cam0").labels("car"))

    def test_cancelled_future_does_not_kill_dispatcher(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        with store.serve() as session:
            doomed = session.submit(store.scan("cam0").labels("car"))
            doomed.cancel()  # may or may not win the race with the dispatcher
            live = session.submit(store.scan("cam0").labels("car"))
            assert live.result(timeout=60).regions  # dispatcher still alive

    def test_store_close_releases_pool_and_flushes(self, small_video,
                                                   tmp_path):
        frames, dets = small_video
        with VideoStore(store_root=str(tmp_path)) as store:
            fill(store, "cam0", frames, dets)
            fill(store, "cam1", frames, dets)
            r1 = store.scan(["cam0", "cam1"]).labels("car").frames(0, 16) \
                      .execute()  # multi-group: spins up the pool
            assert store.scheduler._pool is not None
        assert store.scheduler._pool is None  # close() shut it down
        r2 = store.scan(["cam0", "cam1"]).labels("car").frames(0, 16) \
                  .execute()  # store stays usable after close
        assert len(r2.regions) == len(r1.regions)
