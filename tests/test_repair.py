"""Self-healing cluster data plane: node→node tile streaming, background
re-replication/rebalance, fault injection, per-RPC deadlines.

The contract under test: killing a replica permanently and running
``router.repair(node=...)`` restores the replication factor with reads
bit-identical to a single store throughout; the chunked copy path
survives byte-level faults (mid-stream disconnects, torn frames, slow and
hung links) by resuming — never by serving torn state; a foreground
retile racing the copy forces a re-stream, and the rebuilt replica never
serves the pre-retile generation; a destination that dies mid-copy leaves
zero torn state (staged chunks are either intact-and-reused or
discarded); and ``PlacementMap.save`` survives SIGKILL mid-save
(old-or-new, never torn).
"""
import os
import subprocess
import sys
import textwrap
import threading
import time

import numpy as np
import pytest

from repro.codec.encode import EncoderConfig
from repro.core import (ClusterRouter, NoTilingPolicy, PlacementMap,
                        RemoteVideoStore, VideoStore, VideoStoreServer,
                        uniform_layout, wire)
from repro.core.cost import CostModel
from repro.core.storage import tile_checksum

from faults import Fault, FaultProxy

ENC = EncoderConfig(gop=16, qp=8)
MODEL = CostModel(beta=1.4e-8, gamma=1e-5)
MODEL.encode_per_pixel = 3.4e-8
MODEL.encode_per_tile = 1e-4

NODES = ["n0", "n1", "n2"]


def assert_regions_equal(a, b):
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        assert ra[:-1] == rb[:-1]
        np.testing.assert_array_equal(ra[-1], rb[-1])


def fill(store, name, frames, dets):
    store.add_video(name, encoder=ENC, policy=NoTilingPolicy(),
                    cost_model=MODEL)
    store.ingest(name, frames)
    store.add_detections(name, {f: d for f, d in enumerate(dets)})


class Cluster:
    """3 nodes, K=2, one video ``cam0`` — with an optional FaultProxy
    wired in front of the repair source or destination.  Placement is
    computed up front so tests can choose *which* role gets the proxy
    before the router ever dials."""

    def __init__(self, tmp_path, small_video, *, proxy_role=None,
                 faults=(), timeout=None, dst_root=False,
                 health_interval=None):
        frames, dets = small_video
        pm = PlacementMap(NODES, replication=2,
                          path=str(tmp_path / "placement.json"))
        reps = pm.place("cam0")
        self.src, self.victim = reps[0], reps[1]
        self.dst = next(n for n in NODES if n not in reps)
        self.stores, self.servers, self.nodes = {}, {}, {}
        for n in NODES:
            root = str(tmp_path / f"store-{n}") \
                if (dst_root and n == self.dst) else None
            st = VideoStore(root)
            p = str(tmp_path / f"{n}.sock")
            self.stores[n] = st
            self.servers[n] = VideoStoreServer(st, path=p,
                                               owns_store=False).start()
            self.nodes[n] = p
        self.proxy = None
        if proxy_role is not None:
            behind = {"src": self.src, "dst": self.dst}[proxy_role]
            self.proxy = FaultProxy(self.nodes[behind])
            self.nodes = dict(self.nodes, **{behind: self.proxy.address})
        self.router = ClusterRouter(self.nodes, placement=pm,
                                    timeout=timeout,
                                    health_interval=health_interval)
        self.ref = VideoStore()
        fill(self.router, "cam0", frames, dets)
        fill(self.ref, "cam0", frames, dets)
        for f in faults:  # queued only now: fill traffic stays clean
            self.proxy.add_fault(f)

    def kill(self, name):
        self.servers.pop(name).stop()
        self.stores.pop(name).close()

    def q(self, store):
        return store.scan("cam0").labels("car").frames(0, 32).execute()

    def close(self):
        self.router.close()
        if self.proxy is not None:
            self.proxy.close()
        for s in self.servers.values():
            s.stop()
        for s in self.stores.values():
            s.close()
        self.ref.close()


# ============================================================= checksums
class TestTileChecksum:
    def _enc(self):
        rng = np.random.default_rng(0)
        return {"h": 96, "w": 160, "gop": 16, "qp": 8, "n_frames": 32,
                "size_bytes": 123.5,
                "kq": [rng.integers(0, 255, (6, 4), dtype=np.uint8)
                       for _ in range(2)],
                "pq": [rng.integers(0, 255, (6, 4), dtype=np.uint8)
                       for _ in range(2)]}

    def test_stable(self):
        a, b = self._enc(), self._enc()
        assert tile_checksum(a) == tile_checksum(b)

    def test_member_corruption_detected(self):
        a, b = self._enc(), self._enc()
        b["kq"][1] = b["kq"][1].copy()
        b["kq"][1][3, 2] ^= 0xFF
        assert tile_checksum(a) != tile_checksum(b)

    def test_meta_corruption_detected(self):
        a, b = self._enc(), self._enc()
        b["gop"] = 8
        assert tile_checksum(a) != tile_checksum(b)


# ===================================================== repair, no faults
class TestRepairBasics:
    def test_node_loss_repair_restores_replication(self, tmp_path,
                                                   small_video):
        c = Cluster(tmp_path, small_video)
        try:
            expect = c.q(c.ref)
            c.kill(c.victim)
            # reads fail over while under-replicated
            assert_regions_equal(expect.regions, c.q(c.router).regions)
            jobs = c.router.repair(node=c.victim)
            assert [j["video"] for j in jobs] == ["cam0"]
            status = c.router.drain_repair(timeout=60)
            assert [j["status"] for j in status["jobs"]] == ["done"]
            reps = c.router.placement.nodes_for("cam0")
            assert c.victim not in reps and c.dst in reps
            assert len(reps) == 2
            assert_regions_equal(expect.regions, c.q(c.router).regions)
            # the fresh replica really holds the bits: read it directly
            with RemoteVideoStore(c.nodes[c.dst]) as direct:
                assert_regions_equal(expect.regions,
                                     c.q(direct).regions)
        finally:
            c.close()

    def test_repair_is_idempotent_when_healthy(self, tmp_path,
                                               small_video):
        c = Cluster(tmp_path, small_video)
        try:
            assert c.router.repair() == []
        finally:
            c.close()

    def test_repair_without_any_live_source_fails_cleanly(self, tmp_path,
                                                          small_video):
        c = Cluster(tmp_path, small_video)
        try:
            c.kill(c.src)
            c.kill(c.victim)
            c.router.ping_nodes()  # notice the deaths
            c.router.repair(video="cam0")
            with pytest.raises(RuntimeError, match="no live replica"):
                c.router.drain_repair(timeout=60)
            status = c.router.repair_status()
            assert [j["status"] for j in status["jobs"]] == ["failed"]
        finally:
            c.close()


# ======================================================== fault injection
class TestCopyPathFaults:
    @pytest.mark.parametrize("cut", [150, 2500, 12000])
    def test_disconnect_mid_copy_resumes(self, tmp_path, small_video,
                                         cut):
        """The destination link is severed ``cut`` bytes in — twice —
        then relays cleanly: the copy resumes from staged chunks and the
        repaired replica is bit-identical."""
        c = Cluster(tmp_path, small_video, proxy_role="dst",
                    faults=[Fault(cut_after=cut), Fault(cut_after=cut)])
        try:
            expect = c.q(c.ref)
            c.kill(c.victim)
            c.router.repair(node=c.victim)
            status = c.router.drain_repair(timeout=120)
            (job,) = status["jobs"]
            assert job["status"] == "done"
            assert c.proxy.faults_fired == 2
            assert job["retries"] >= 1
            assert_regions_equal(expect.regions, c.q(c.router).regions)
            with RemoteVideoStore(c.nodes[c.dst]) as direct:
                assert_regions_equal(expect.regions, c.q(direct).regions)
        finally:
            c.close()

    def test_torn_export_reply_retried(self, tmp_path, small_video):
        """A byte flipped in the source's reply stream makes the frame
        undecodable — the chunk is re-exported on a fresh connection."""
        c = Cluster(tmp_path, small_video, proxy_role="src",
                    faults=[Fault(corrupt_at=600, direction="b2c")])
        try:
            expect = c.q(c.ref)
            c.kill(c.victim)
            c.router.repair(node=c.victim)
            status = c.router.drain_repair(timeout=120)
            (job,) = status["jobs"]
            assert job["status"] == "done"
            assert c.proxy.faults_fired == 1
            assert_regions_equal(expect.regions, c.q(c.router).regions)
        finally:
            c.close()

    def test_torn_upload_hits_deadline_then_resumes(self, tmp_path,
                                                    small_video):
        """A byte flipped in an upload leaves the request unanswerable
        (the node can't correlate an undecodable frame) — the per-RPC
        deadline severs the hang and the chunk is re-sent."""
        c = Cluster(tmp_path, small_video, proxy_role="dst", timeout=10.0,
                    faults=[Fault(corrupt_at=1500, direction="c2b")])
        try:
            expect = c.q(c.ref)
            c.kill(c.victim)
            t0 = time.monotonic()
            c.router.repair(node=c.victim)
            status = c.router.drain_repair(timeout=120)
            (job,) = status["jobs"]
            assert job["status"] == "done"
            assert c.proxy.faults_fired == 1
            assert time.monotonic() - t0 < 60
            assert_regions_equal(expect.regions, c.q(c.router).regions)
        finally:
            c.close()

    def test_slow_link_still_completes(self, tmp_path, small_video):
        c = Cluster(tmp_path, small_video, proxy_role="dst",
                    faults=[Fault(delay_s=0.05)])
        try:
            expect = c.q(c.ref)
            c.kill(c.victim)
            c.router.repair(node=c.victim)
            status = c.router.drain_repair(timeout=120)
            assert [j["status"] for j in status["jobs"]] == ["done"]
            assert_regions_equal(expect.regions, c.q(c.router).regions)
        finally:
            c.close()

    def test_exhausted_retries_fail_the_job_not_the_worker(
            self, tmp_path, small_video):
        """More consecutive faults than ``chunk_retries``: the job fails
        with a clean error, the destination holds no torn video, and a
        retried repair (faults exhausted) completes."""
        c = Cluster(tmp_path, small_video, proxy_role="dst",
                    faults=[Fault(cut_after=100) for _ in range(8)])
        try:
            expect = c.q(c.ref)
            c.kill(c.victim)
            c.router.repair(node=c.victim)
            with pytest.raises((wire.WireError, OSError)):
                c.router.drain_repair(timeout=120)
            # no torn state: dst never learned the video
            assert "cam0" not in c.stores[c.dst].videos()
            assert c.proxy.pending_faults() <= 3
            c.proxy.clear_faults()
            c.router.repair(node=c.victim)
            status = c.router.drain_repair(timeout=120)
            assert status["jobs"][-1]["status"] == "done"
            assert_regions_equal(expect.regions, c.q(c.router).regions)
        finally:
            c.close()


# ================================================== repair vs retile race
class TestRepairRetileRace:
    def test_mid_copy_retile_forces_restream(self, tmp_path, small_video):
        """A foreground retile lands while the copy streams: the worker
        re-streams the bumped SOT and the rebuilt replica serves the
        post-retile generation — never the stale one."""
        c = Cluster(tmp_path, small_video)
        retile_wanted = threading.Event()
        retile_done = threading.Event()
        src_store = c.stores[c.src]
        real = src_store.export_tile
        calls = [0]

        def hooked(name, sot_id, tile_idx):
            calls[0] += 1
            if calls[0] == 2:
                retile_wanted.set()
                assert retile_done.wait(timeout=30)
            return real(name, sot_id, tile_idx)

        src_store.export_tile = hooked
        try:
            c.kill(c.victim)
            c.router.repair(node=c.victim)
            assert retile_wanted.wait(timeout=30)
            c.router.retile("cam0", 0, uniform_layout(96, 160, 2, 2))
            c.ref.retile("cam0", 0, uniform_layout(96, 160, 2, 2))
            retile_done.set()
            status = c.router.drain_repair(timeout=120)
            (job,) = status["jobs"]
            assert job["status"] == "done"
            assert job["restreams"] >= 1
            expected = c.router.expected_epochs("cam0")
            assert expected[0] >= 1
            with RemoteVideoStore(c.nodes[c.dst]) as direct:
                have = direct.epochs("cam0")
                assert all(have[s] >= e for s, e in expected.items())
                assert_regions_equal(c.q(c.ref).regions,
                                     c.q(direct).regions)
            assert_regions_equal(c.q(c.ref).regions, c.q(c.router).regions)
        finally:
            src_store.export_tile = real
            c.close()


# ============================================= destination dies mid-copy
class TestDestinationRestart:
    def test_disk_staging_survives_destination_restart(self, tmp_path,
                                                       small_video):
        """The destination dies after staging the first chunk; a
        brand-new store process over the same root resumes from the
        intact staged chunk, commits, and cleans staging up."""
        c = Cluster(tmp_path, small_video, dst_root=True)
        dst_store = c.stores[c.dst]
        real = dst_store.stage_import_chunk
        calls = [0]

        def dying(*a, **kw):
            calls[0] += 1
            if calls[0] > 1:
                raise RuntimeError("injected destination crash")
            return real(*a, **kw)

        dst_store.stage_import_chunk = dying
        try:
            expect = c.q(c.ref)
            c.kill(c.victim)
            c.router.repair(node=c.victim)
            with pytest.raises(RuntimeError,
                               match="injected destination crash"):
                c.router.drain_repair(timeout=120)
            staging = tmp_path / f"store-{c.dst}" / ".import" / "cam0"
            staged_before = sorted(p.name for p in staging.glob("*.npz"))
            assert len(staged_before) == 1  # chunk 1 landed intact
            # no torn state: dst never learned the video
            assert "cam0" not in dst_store.videos()
            # "restart": a brand-new store process over the same root
            c.servers.pop(c.dst).stop()
            dst_store.close()
            st = VideoStore(str(tmp_path / f"store-{c.dst}"))
            c.stores[c.dst] = st
            c.servers[c.dst] = VideoStoreServer(
                st, path=str(tmp_path / f"{c.dst}.sock"),
                owns_store=False).start()
            before = c.router.repair_status()["stats"]["chunks_copied"]
            c.router.repair(node=c.victim)
            status = c.router.drain_repair(timeout=120)
            job2 = status["jobs"][-1]
            assert job2["status"] == "done"
            assert job2["chunks_done"] == job2["chunks_total"] >= 2
            # the staged chunk was reused: one fewer chunk went over the
            # wire than the manifest expects
            streamed = status["stats"]["chunks_copied"] - before
            assert streamed == job2["chunks_total"] - 1
            assert not staging.exists()  # staging discarded after commit
            assert_regions_equal(expect.regions, c.q(c.router).regions)
            with RemoteVideoStore(c.nodes[c.dst]) as direct:
                assert_regions_equal(expect.regions, c.q(direct).regions)
        finally:
            c.close()


# ===================================================== per-RPC deadlines
class TestClientDeadline:
    def test_hung_node_raises_within_deadline(self, tmp_path):
        srv = VideoStoreServer(VideoStore(),
                               path=str(tmp_path / "n.sock")).start()
        proxy = FaultProxy(str(tmp_path / "n.sock"),
                           faults=[Fault(stall_s=60, direction="b2c")])
        try:
            # transport="socket": skip shm negotiation so ping is the
            # first RPC on the wire and hits the deadline itself
            with RemoteVideoStore(proxy.address, retries=0, timeout=0.5,
                                  transport="socket") as c:
                t0 = time.monotonic()
                with pytest.raises(wire.ConnectionClosed, match="deadline"):
                    c.ping()
                assert time.monotonic() - t0 < 5
        finally:
            proxy.close()
            srv.stop()

    def test_no_deadline_by_default(self, tmp_path):
        srv = VideoStoreServer(VideoStore(),
                               path=str(tmp_path / "n.sock")).start()
        try:
            with RemoteVideoStore(str(tmp_path / "n.sock"),
                                  retries=0) as c:
                assert c._timeout is None
                c.ping()
        finally:
            srv.stop()


# ==================================================== router health loop
class TestHealthLoop:
    def test_downed_node_revived_in_background(self, tmp_path):
        p = str(tmp_path / "n0.sock")
        srv = VideoStoreServer(VideoStore(), path=p).start()
        router = ClusterRouter({"n0": p}, health_interval=0.05)
        try:
            assert router._health_thread is not None
            srv.stop()
            router._mark_down("n0")
            assert "n0" in router._down
            srv = VideoStoreServer(VideoStore(), path=p).start()
            deadline = time.monotonic() + 10
            while time.monotonic() < deadline:
                with router._lock:
                    if "n0" not in router._down:
                        break
                time.sleep(0.02)
            assert "n0" not in router._down
        finally:
            router.close()
            srv.stop()

    def test_no_thread_without_interval(self, tmp_path):
        p = str(tmp_path / "n0.sock")
        srv = VideoStoreServer(VideoStore(), path=p).start()
        router = ClusterRouter({"n0": p})
        try:
            assert router._health_thread is None
        finally:
            router.close()
            srv.stop()


# ============================================== join + rebalance movement
class TestJoinAndRebalance:
    def test_join_fresh_node_and_rebalance_moves_data(self, tmp_path,
                                                      small_video):
        frames, dets = small_video
        nodes, servers = {}, []
        for i in range(2):
            p = str(tmp_path / f"n{i}.sock")
            servers.append(VideoStoreServer(VideoStore(), path=p).start())
            nodes[f"n{i}"] = p
        router = ClusterRouter(nodes, replication=1)
        ref = VideoStore()
        for v in ("cam0", "cam1", "cam2", "cam3"):
            fill(router, v, frames, dets)
            fill(ref, v, frames, dets)
        try:
            p2 = str(tmp_path / "n2.sock")
            servers.append(VideoStoreServer(VideoStore(), path=p2).start())
            out = router.join_node("n2", p2)
            assert out["alive"] and "n2" in router.placement.nodes
            doc = router.rebalance(apply=True)
            moved = [j["video"] for j in doc["jobs"]] + doc["flipped"]
            assert moved, "a fresh node should attract some videos"
            status = router.drain_repair(timeout=120)
            assert all(j["status"] == "done" for j in status["jobs"])
            for v in moved:  # each video now fronted by its planned owner
                assert router.placement.primary(v) == doc["moves"][v][1]
            assert any(doc["moves"][v][1] == "n2" for v in moved)
            for v in ("cam0", "cam1", "cam2", "cam3"):
                a = ref.scan(v).labels("car").frames(0, 32).execute()
                b = router.scan(v).labels("car").frames(0, 32).execute()
                assert_regions_equal(a.regions, b.regions)
        finally:
            router.close()
            for s in servers:
                s.stop()
            ref.close()

    def test_join_conflicting_address_rejected(self, tmp_path):
        p = str(tmp_path / "n0.sock")
        srv = VideoStoreServer(VideoStore(), path=p).start()
        router = ClusterRouter({"n0": p})
        try:
            with pytest.raises(ValueError, match="already registered"):
                router.join_node("n0", "/elsewhere.sock")
        finally:
            router.close()
            srv.stop()


# ================================================= placement durability
class TestPlacementDurability:
    SAVER = textwrap.dedent("""\
        import sys
        sys.path.insert(0, {src!r})
        from repro.core import PlacementMap
        pm = PlacementMap(["n0", "n1", "n2"], replication=2, path={path!r})
        state = lambda i: {{f"cam{{j}}": ["n0", "n1"] if i % 2 == 0
                           else ["n1", "n2"] for j in range(64)}}
        pm.assignments = state(0)
        pm.save()   # a valid generation exists before the kill window
        print("ready", flush=True)
        i = 0
        while True:
            i += 1
            pm.assignments = state(i)
            pm.save()
    """)

    def test_sigkill_mid_save_leaves_old_or_new(self, tmp_path):
        src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
        path = str(tmp_path / "placement.json")
        code = self.SAVER.format(src=os.path.abspath(src), path=path)
        for attempt in range(5):
            proc = subprocess.Popen([sys.executable, "-c", code],
                                    stdout=subprocess.PIPE)
            assert proc.stdout.readline().strip() == b"ready"
            time.sleep(0.05 + 0.037 * attempt)  # vary the kill point
            proc.kill()
            proc.wait(timeout=30)
            # never torn: the file parses and is one of the two states
            pm = PlacementMap.load(path)
            reps = {tuple(r) for r in pm.assignments.values()}
            assert reps <= {("n0", "n1"), ("n1", "n2")}
            assert len(reps) == 1, "half-written generation visible"
            assert len(pm.assignments) == 64
