"""Minimal stand-in for the ``hypothesis`` package.

The tier-1 suite property-tests several modules with hypothesis, but the
container does not ship it.  Importing this module installs a tiny
API-compatible shim into ``sys.modules`` — *only when the real package is
absent* — covering exactly the strategy surface the suite uses:

    given, settings, assume, note, HealthCheck
    st.integers / booleans / floats / sampled_from / tuples / lists /
    builds / just / none, plus Strategy.map / .filter

Drawing is pseudo-random but deterministic per test (seeded from the test's
qualified name), with no shrinking: a failing example is re-raised with the
drawn values attached.  If the real hypothesis is installed, this module is
a no-op and the real package wins.
"""
from __future__ import annotations

import random
import sys
import types


class _Unsatisfied(Exception):
    """Raised by assume(False); the example is skipped."""


def assume(condition) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


def note(_msg) -> None:
    pass


class HealthCheck:
    all = staticmethod(lambda: [])
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"
    data_too_large = "data_too_large"


class Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)

    def map(self, fn) -> "Strategy":
        return Strategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred) -> "Strategy":
        def draw(rng):
            for _ in range(200):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise _Unsatisfied()
        return Strategy(draw)


def integers(min_value=None, max_value=None) -> Strategy:
    lo = -(2 ** 31) if min_value is None else min_value
    hi = 2 ** 31 if max_value is None else max_value
    return Strategy(lambda rng: rng.randint(lo, hi))


def floats(min_value=0.0, max_value=1.0, **_kw) -> Strategy:
    return Strategy(lambda rng: rng.uniform(min_value, max_value))


def booleans() -> Strategy:
    return Strategy(lambda rng: rng.random() < 0.5)


def sampled_from(seq) -> Strategy:
    seq = list(seq)
    return Strategy(lambda rng: seq[rng.randrange(len(seq))])


def just(value) -> Strategy:
    return Strategy(lambda rng: value)


def none() -> Strategy:
    return just(None)


def tuples(*strategies) -> Strategy:
    return Strategy(lambda rng: tuple(s.example(rng) for s in strategies))


def lists(elements: Strategy, *, min_size: int = 0,
          max_size=None, unique=False) -> Strategy:
    hi = min_size + 10 if max_size is None else max_size

    def draw(rng):
        n = rng.randint(min_size, hi)
        out = []
        for _ in range(n):
            for _attempt in range(200):
                v = elements.example(rng)
                if not unique or v not in out:
                    out.append(v)
                    break
        return out
    return Strategy(draw)


def builds(fn, *strategies, **kw_strategies) -> Strategy:
    return Strategy(lambda rng: fn(
        *(s.example(rng) for s in strategies),
        **{k: s.example(rng) for k, s in kw_strategies.items()}))


class settings:
    """Decorator storing run options on the test (order-independent with
    @given — whichever wraps last, options are found at call time)."""

    def __init__(self, **kw):
        self.kw = kw

    def __call__(self, fn):
        merged = dict(getattr(fn, "_compat_settings", {}))
        merged.update(self.kw)
        fn._compat_settings = merged
        return fn


def given(*strategies, **kw_strategies):
    def decorate(fn):
        def wrapper():
            cfg = getattr(wrapper, "_compat_settings", {})
            n = cfg.get("max_examples", 25)
            rng = random.Random(
                int.from_bytes(fn.__qualname__.encode(), "little") % (2 ** 32))
            ran = 0
            for i in range(n * 4):
                if ran >= n:
                    break
                try:
                    args = [s.example(rng) for s in strategies]
                    kwargs = {k: s.example(rng)
                              for k, s in kw_strategies.items()}
                except _Unsatisfied:
                    continue
                try:
                    fn(*args, **kwargs)
                    ran += 1
                except _Unsatisfied:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i} for {fn.__name__}: "
                        f"args={args!r} kwargs={kwargs!r}: {e}") from e
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._compat_settings = dict(getattr(fn, "_compat_settings", {}))
        wrapper.is_hypothesis_compat = True
        return wrapper
    return decorate


def install() -> None:
    """Register the shim as ``hypothesis`` + ``hypothesis.strategies`` if
    the real package is missing."""
    try:
        import hypothesis  # noqa: F401  (real package present: no-op)
        return
    except ModuleNotFoundError:
        pass
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "just",
                 "none", "tuples", "lists", "builds"):
        setattr(st, name, globals()[name])
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.note = note
    mod.HealthCheck = HealthCheck
    mod.strategies = st
    mod.__version__ = "0.0-compat"
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
