"""Multi-device tests.  Each runs in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count set, because the main pytest
process must keep seeing 1 device (smoke tests)."""
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def run_sub(code: str, devices: int = 8, timeout: int = 480) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_sharded_train_step_runs():
    """FSDP+TP train step on a 2x4 host mesh: runs, loss finite, params
    sharded as specified."""
    run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import get_config, reduce_config
    from repro.distributed import sharding as shd
    from repro.distributed.ctx import TRAIN_RULES_1POD, use_sharding
    from repro.models import zoo
    from repro.train.optimizer import init_opt_state
    from repro.train.train_step import AdamWConfig, make_train_step

    cfg = reduce_config(get_config("olmo-1b"))
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    params = zoo.init_model(cfg, jax.random.key(0))
    p_shard = shd.param_shardings(params, cfg, mesh, mode="train")
    params = jax.device_put(params, p_shard)
    opt = init_opt_state(params)
    o_shard = {"m": p_shard, "v": p_shard, "step": NamedSharding(mesh, P())}
    opt = jax.device_put(opt, o_shard)
    batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
             "targets": jnp.zeros((8, 32), jnp.int32)}
    batch = jax.device_put(batch, shd.batch_shardings(batch, mesh))
    step = make_train_step(cfg, AdamWConfig())
    with use_sharding(TRAIN_RULES_1POD, mesh):
        jstep = jax.jit(step, in_shardings=(p_shard, o_shard,
                                            shd.batch_shardings(batch, mesh)),
                        donate_argnums=(0, 1))
        params, opt, m = jstep(params, opt, batch)
    assert np.isfinite(float(m["loss"])), m
    # spot-check a sharded leaf
    w = params["layers"]["mlp"]["gate"]["w"]
    assert len(w.sharding.device_set) == 8
    print("OK", float(m["loss"]))
    """)


def test_moe_dist_equals_local():
    run_sub("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import get_config, reduce_config
    from repro.models.moe import init_moe, moe_apply
    from repro.distributed.ctx import ShardingRules, use_sharding

    cfg = reduce_config(get_config("qwen3-moe-30b-a3b"))
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=100.0))  # no drops: exact equality regime
    p = init_moe(jax.random.key(3), cfg)
    x = jax.random.normal(jax.random.key(4), (4, 16, cfg.d_model))
    out_local = moe_apply(p, x, cfg)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = ShardingRules(rules={"batch": "data", "experts": "model"})
    with use_sharding(rules, mesh):
        xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
        ps = jax.device_put(p, NamedSharding(mesh, P()))
        out_dist = jax.jit(lambda pp, xx: moe_apply(pp, xx, cfg))(ps, xs)
    np.testing.assert_allclose(np.asarray(out_local, np.float32),
                               np.asarray(out_dist, np.float32), atol=3e-2)
    print("OK")
    """)


def test_compressed_grad_sync_converges():
    """int8 error-feedback DP grad sync: quadratic converges ~like fp32."""
    run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.compression import make_dp_compressed_grad_fn

    mesh = jax.make_mesh((8,), ("data",))
    target = jnp.arange(32.0) / 32.0

    def loss_fn(params, batch):
        pred = batch @ params["w"]
        return jnp.mean((pred - batch @ target) ** 2)

    grad_fn = jax.jit(make_dp_compressed_grad_fn(loss_fn, mesh))
    params = {"w": jnp.zeros((32,))}
    residuals = {"w": jnp.zeros((32,))}
    rng = np.random.default_rng(0)
    losses = []
    for i in range(60):
        batch = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
        loss, grads, residuals = grad_fn(params, batch, residuals)
        params = jax.tree.map(lambda p, g: p - 0.3 * g, params, grads)
        losses.append(float(loss))
    assert losses[-1] < 1e-3 * losses[0], (losses[0], losses[-1])
    print("OK", losses[0], losses[-1])
    """)


def test_checkpoint_elastic_reshard():
    """Save on an 8-device mesh, restore onto a 4-device mesh (node loss)."""
    run_sub("""
    import tempfile
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.train.checkpoint import CheckpointManager

    devs = jax.devices()
    mesh8 = Mesh(np.array(devs).reshape(8), ("data",))
    mesh4 = Mesh(np.array(devs[:4]).reshape(4), ("data",))
    state = {"w": jax.device_put(jnp.arange(64.0).reshape(8, 8),
                                 NamedSharding(mesh8, P("data", None)))}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, state)
        target = {"w": jnp.zeros((8, 8))}
        shardings = {"w": NamedSharding(mesh4, P("data", None))}
        got, _ = mgr.restore(target, shardings=shardings)
        assert len(got["w"].sharding.device_set) == 4
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.arange(64.0).reshape(8, 8))
    print("OK")
    """)


def test_decode_step_sharded():
    """TP serving decode on a host mesh with kv-head sharding + cache donation."""
    run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import get_config, make_serve_config, reduce_config
    from repro.distributed import sharding as shd
    from repro.distributed.ctx import SERVE_RULES_1POD, use_sharding
    from repro.models import zoo
    from repro.serve.serve_step import make_decode_step

    cfg = reduce_config(get_config("qwen2-72b"))
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    scfg = make_serve_config(cfg, 2)
    params = zoo.init_model(scfg, jax.random.key(0))
    params = jax.device_put(params, shd.param_shardings(params, scfg, mesh,
                                                        mode="serve"))
    caches = zoo.init_cache(scfg, 4, 32)
    caches = jax.device_put(caches, shd.cache_shardings(caches, scfg, mesh))
    batch = {"tokens": jnp.zeros((4, 1), jnp.int32)}
    step = make_decode_step(scfg)
    with use_sharding(SERVE_RULES_1POD, mesh):
        jd = jax.jit(step, donate_argnums=(1,))
        logits, caches = jd(params, caches, batch, jnp.int32(3))
    assert logits.shape == (4, 1, scfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    print("OK")
    """)
