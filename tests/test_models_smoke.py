"""Per-architecture smoke tests (deliverable f): a REDUCED config of each
family runs one forward/train step on CPU — shapes checked, no NaNs — and one
decode step; prefill logits must agree with the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config, reduce_config
from repro.models import zoo

ARCHS = list(ARCH_IDS)


def fake_batch(cfg, B=2, S=64, seed=0):
    key = jax.random.key(seed)
    batch = {}
    if cfg.frontend == "patch":
        n_img = min(cfg.frontend_tokens, S // 4)
        batch["patch_embeds"] = jax.random.normal(key, (B, n_img, cfg.frontend_dim))
        batch["tokens"] = jax.random.randint(key, (B, S - n_img), 0, cfg.vocab)
        batch["targets"] = jax.random.randint(key, (B, S - n_img), 0, cfg.vocab)
    elif cfg.is_encdec:
        batch["frames"] = jax.random.normal(key, (B, S // 4, cfg.d_model))
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
        batch["targets"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
        batch["targets"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    return batch


@pytest.fixture(scope="module")
def models():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = reduce_config(get_config(arch))
            params = zoo.init_model(cfg, jax.random.key(42))
            cache[arch] = (cfg, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_loss_finite(models, arch):
    cfg, params = models(arch)
    batch = fake_batch(cfg)
    loss, metrics = jax.jit(lambda p, b: zoo.loss_fn(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss))
    assert 0.0 < float(loss) < 20.0


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_updates_params(models, arch):
    from repro.train.train_step import AdamWConfig, make_train_step
    from repro.train.optimizer import init_opt_state

    cfg, params = models(arch)
    step = make_train_step(cfg, AdamWConfig(lr=1e-3))
    opt = init_opt_state(params)
    batch = fake_batch(cfg)
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_opt["step"]) == 1
    # at least one leaf changed
    changed = jax.tree.map(
        lambda a, b: bool(np.any(np.asarray(a) != np.asarray(b))),
        params, new_params)
    assert any(jax.tree.leaves(changed))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(models, arch):
    cfg, params = models(arch)
    B, max_len = 2, 64
    caches = zoo.init_cache(cfg, B, max_len)
    batch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    if cfg.is_encdec:
        batch["enc_out"] = jnp.zeros((B, 16, cfg.d_model))
    logits, caches = jax.jit(
        lambda p, b, c: zoo.decode_step(p, cfg, b, c, cache_index=jnp.int32(5))
    )(params, batch, caches)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ["smollm-135m", "qwen2-72b", "olmo-1b",
                                  "falcon-mamba-7b", "zamba2-1.2b",
                                  "deepseek-v2-lite-16b"])
def test_prefill_matches_forward(models, arch):
    """Prefill through the cache path must agree with the plain forward on
    the last position's logits (validates every cache plumbing branch)."""
    cfg, params = models(arch)
    B, S = 2, 32
    batch = fake_batch(cfg, B=B, S=S)
    h = zoo.forward(params, cfg, batch, remat=False)
    want = zoo.logits_fn(params, cfg, h[:, -1:])
    caches = zoo.init_cache(cfg, B, S)
    got, _ = zoo.decode_step(params, cfg, {"tokens": batch["tokens"]}, caches,
                             cache_index=jnp.int32(0))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=0.15, rtol=0.05)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_sizes(arch):
    """The FULL configs carry the published sizes (spot checks)."""
    cfg = get_config(arch)
    expected = {
        "deepseek-v2-lite-16b": (27, 2048, 102400),
        "qwen3-moe-30b-a3b": (48, 2048, 151936),
        "internvl2-26b": (48, 6144, 92553),
        "olmo-1b": (16, 2048, 50304),
        "qwen2-72b": (80, 8192, 152064),
        "smollm-135m": (30, 576, 49152),
        "yi-34b": (60, 7168, 64000),
        "falcon-mamba-7b": (64, 4096, 65024),
        "seamless-m4t-medium": (12, 1024, 256206),
        "zamba2-1.2b": (38, 2048, 32000),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.vocab) == expected


@pytest.mark.parametrize("arch,approx_b", [
    ("smollm-135m", 0.135), ("olmo-1b", 1.2), ("qwen2-72b", 72.7),
    ("yi-34b", 34.4), ("falcon-mamba-7b", 7.3),
    ("deepseek-v2-lite-16b", 15.7), ("qwen3-moe-30b-a3b", 30.5),
])
def test_param_counts_match_published(arch, approx_b):
    """eval_shape param count within 10% of the published model size."""
    cfg = get_config(arch)
    n = cfg.param_count() / 1e9
    assert abs(n - approx_b) / approx_b < 0.10, n
