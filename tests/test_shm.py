"""Zero-copy serving: shared-memory transport, lease lifecycle, stats.

The contract under test: transport negotiation picks shm only when the
client genuinely shares /dev/shm with the server (and honours explicit
``transport=``/``$REPRO_TRANSPORT`` overrides, raising on bogus values);
shm and npz replies are bit-identical to in-process ``execute()``; every
lease is released — on result GC, on client ``close()``, and when a
client is SIGKILLed mid-lease — so the server's segment pool drains to
zero; and the serving layer stamps ``marshal_s``/``payload_bytes``/
``transport`` into the reply stats.
"""
import gc
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.codec.encode import EncoderConfig
from repro.core import (NoTilingPolicy, RemoteVideoStore, VideoStore,
                        VideoStoreServer)
from repro.core import wire
from repro.core.cost import CostModel
from repro.core.shm import (SegmentPool, resolve_transport, shm_available,
                            attach_segment)

pytestmark = pytest.mark.skipif(not shm_available(),
                                reason="no POSIX shared memory on host")

ENC = EncoderConfig(gop=16, qp=8)
MODEL = CostModel(beta=1.4e-8, gamma=1e-5)


def fill(store, name, frames, dets):
    store.add_video(name, encoder=ENC, policy=NoTilingPolicy(),
                    cost_model=MODEL)
    store.ingest(name, frames)
    store.add_detections(name, {f: d for f, d in enumerate(dets)})


def assert_regions_equal(a, b):
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        assert ra[:-1] == rb[:-1]
        np.testing.assert_array_equal(ra[-1], rb[-1])


def wait_until(cond, timeout=20.0, what="condition"):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if cond():
            return
        time.sleep(0.02)
    raise AssertionError(f"timed out waiting for {what}")


@pytest.fixture
def served_shm(tmp_path, small_video):
    """Unix-socket server with the shm transport enabled (auto), seeded
    store kept open in-process for bit-identity comparisons."""
    frames, dets = small_video
    store = VideoStore()
    fill(store, "cam0", frames, dets)
    sock = str(tmp_path / "tasm.sock")
    server = VideoStoreServer(store, path=sock, owns_store=False).start()
    yield store, server, sock
    server.stop()
    store.close()


def pool_stats(server):
    return server._shm_pool.stats()


# ----------------------------------------------------- SegmentPool units
class TestSegmentPool:
    def test_write_release_accounting(self):
        pool = SegmentPool(max_bytes=1 << 20)
        a = np.arange(100, dtype=np.int64)
        b = np.zeros((3, 4), dtype=np.uint8)
        doc = pool.write([a, b], owner="conn")
        assert doc is not None and len(doc["items"]) == 2
        st = pool.stats()
        assert st["segments"] == 1 and st["bytes"] >= a.nbytes + b.nbytes
        # the descriptor round-trips bit-identically through a mapping
        seg = attach_segment(doc["seg"])
        try:
            for src, (off, shape, dtype) in zip((a, b), doc["items"]):
                got = np.frombuffer(seg.buf, dtype=np.dtype(dtype),
                                    count=int(np.prod(shape)) or 0,
                                    offset=off).reshape(shape).copy()
                np.testing.assert_array_equal(got, src)
        finally:
            seg.close()
        assert pool.release([doc["seg"]]) == 1
        assert pool.stats() == {"segments": 0, "bytes": 0}
        # double release is a no-op, not an error
        assert pool.release([doc["seg"]]) == 0
        pool.close()

    def test_owner_filtering(self):
        pool = SegmentPool()
        owner_a, owner_b = object(), object()
        doc = pool.write([np.ones(8)], owner=owner_a)
        # a neighbour cannot release someone else's lease
        assert pool.release([doc["seg"]], owner=owner_b) == 0
        assert pool.stats()["segments"] == 1
        assert pool.release([doc["seg"]], owner=owner_a) == 1
        pool.close()

    def test_release_owner_and_sweep(self):
        pool = SegmentPool()
        live, dead = object(), object()
        pool.write([np.ones(4)], owner=live)
        pool.write([np.ones(4)], owner=dead)
        pool.write([np.ones(4)], owner=dead)
        assert pool.release_owner(dead) == 2
        assert pool.stats()["segments"] == 1
        # sweep reclaims anything whose owner fell out of the live set
        assert pool.sweep(live_owners=[]) == 1
        assert pool.stats() == {"segments": 0, "bytes": 0}
        pool.close()

    def test_budget_overflow_falls_back(self):
        pool = SegmentPool(max_bytes=128)
        assert pool.write([np.zeros(1024, dtype=np.uint8)]) is None
        small = pool.write([np.zeros(16, dtype=np.uint8)])
        assert small is not None  # within budget still works
        pool.close()

    def test_closed_pool_declines(self):
        pool = SegmentPool()
        doc = pool.write([np.ones(4)])
        pool.close()
        assert pool.stats() == {"segments": 0, "bytes": 0}
        assert pool.write([np.ones(4)]) is None
        assert doc is not None  # close() after write unlinked it already

    def test_probe_verify(self):
        pool = SegmentPool()
        name, nbytes = pool.probe(owner="c")
        seg = attach_segment(name)
        try:
            nonce = bytes(seg.buf[:nbytes])
        finally:
            seg.close()
        assert pool.verify(name, "deadbeef") is False
        assert pool.verify(name, "not-hex") is False
        assert pool.verify(name, nonce.hex()) is True
        pool.close()


# -------------------------------------------------- transport negotiation
class TestNegotiation:
    def test_unix_auto_negotiates_shm(self, served_shm):
        _, server, sock = served_shm
        assert server.transport == "auto"
        with RemoteVideoStore(sock) as cli:
            assert cli.transport == "shm"
            assert cli.ping()["transport"] == "shm"

    def test_socket_server_declines(self, served_shm, tmp_path):
        store, _, _ = served_shm
        sock2 = str(tmp_path / "npz.sock")
        with VideoStoreServer(store, path=sock2, owns_store=False,
                              transport="socket").start():
            with RemoteVideoStore(sock2) as cli:
                assert cli.transport == "npz"
                assert cli.ping()["transport"] == "npz"
            # a client that REQUIRES shm fails fast against it
            with pytest.raises(RuntimeError, match="shm"):
                RemoteVideoStore(sock2, transport="shm")

    def test_client_socket_mode_skips_negotiation(self, served_shm):
        _, _, sock = served_shm
        with RemoteVideoStore(sock, transport="socket") as cli:
            assert cli.transport == "npz"

    def test_tcp_auto_silently_npz(self, served_shm):
        store, _, _ = served_shm
        with VideoStoreServer(store, host="127.0.0.1", port=0,
                              owns_store=False).start() as tcp:
            host, port = tcp.address
            with RemoteVideoStore(host=host, port=port) as cli:
                assert cli.transport == "npz"
                ref = store.scan("cam0").labels("car").frames(0, 16) \
                    .execute()
                got = cli.scan("cam0").labels("car").frames(0, 16) \
                    .execute()
                assert_regions_equal(ref.regions, got.regions)

    def test_invalid_transport_values_raise(self, served_shm, monkeypatch):
        _, _, sock = served_shm
        with pytest.raises(ValueError, match="auto|shm|socket"):
            RemoteVideoStore(sock, transport="carrier-pigeon")
        with pytest.raises(ValueError, match="auto|shm|socket"):
            VideoStoreServer(VideoStore(), path="/tmp/x.sock",
                             transport="bogus")
        monkeypatch.setenv("REPRO_TRANSPORT", "bogus")
        with pytest.raises(ValueError, match="REPRO_TRANSPORT"):
            resolve_transport(None)
        # explicit value still wins over a bogus env override
        assert resolve_transport("shm") == "shm"

    def test_resolve_transport_env(self, monkeypatch):
        monkeypatch.delenv("REPRO_TRANSPORT", raising=False)
        assert resolve_transport(None) == "auto"
        monkeypatch.setenv("REPRO_TRANSPORT", "socket")
        assert resolve_transport(None) == "socket"
        assert resolve_transport("auto") == "auto"

    def test_serve_cli_rejects_bogus_transport(self):
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                        "scripts"))
        try:
            import tasm_serve
        finally:
            sys.path.pop(0)
        with pytest.raises(SystemExit):
            tasm_serve.parse_args(["--socket", "/tmp/x.sock",
                                   "--transport", "carrier-pigeon"])

    def test_wire_frame_with_shm_needs_reader(self):
        payload = wire.dumps(
            {"v": np.arange(6).reshape(2, 3)},
            segment_writer=lambda arrays: {"seg": "fake", "items":
                                           [[0, [2, 3], "int64"]]})
        with pytest.raises(wire.WireError, match="shm reader"):
            wire.loads(payload)


# ---------------------------------------------------- interop + identity
class TestInterop:
    def test_shm_and_npz_clients_bit_identical(self, served_shm):
        store, server, sock = served_shm
        ref = store.scan("cam0").labels("car").frames(0, 32).execute()
        with RemoteVideoStore(sock) as shm_cli, \
                RemoteVideoStore(sock, transport="socket") as npz_cli:
            assert (shm_cli.transport, npz_cli.transport) == ("shm", "npz")
            a = shm_cli.scan("cam0").labels("car").frames(0, 32).execute()
            b = npz_cli.scan("cam0").labels("car").frames(0, 32).execute()
            assert_regions_equal(ref.regions, a.regions)
            assert_regions_equal(ref.regions, b.regions)
            # both transports show up in the server's marshalling stats
            by_t = shm_cli.stats()["marshalling"]["by_transport"]
            assert by_t.get("shm", 0) >= 1 and by_t.get("npz", 0) >= 1

    def test_shm_views_are_read_only(self, served_shm):
        _, _, sock = served_shm
        with RemoteVideoStore(sock) as cli:
            got = cli.scan("cam0").labels("car").frames(0, 32).execute()
            assert got.regions, "workload should produce regions"
            px = got.regions[0][-1]
            assert px.flags.writeable is False
            with pytest.raises(ValueError):
                px[...] = 0

    def test_stats_stamped_on_served_replies(self, served_shm):
        store, _, sock = served_shm
        ref = store.scan("cam0").labels("car").frames(0, 32).execute()
        assert ref.stats.transport == ""  # in-process: no serving layer
        with RemoteVideoStore(sock) as cli:
            got = cli.scan("cam0").labels("car").frames(0, 32).execute()
            assert got.stats.transport == "shm"
            assert got.stats.payload_bytes > 0
            assert got.stats.marshal_s >= 0.0
            est = cli.stats()["marshalling"]
            assert est["payload_bytes"] >= got.stats.payload_bytes


# ------------------------------------------------------- lease lifecycle
class TestLeases:
    def test_gc_of_result_releases_segments(self, served_shm):
        _, server, sock = served_shm
        with RemoteVideoStore(sock) as cli:
            got = cli.scan("cam0").labels("car").frames(0, 32).execute()
            assert got.regions
            assert pool_stats(server)["segments"] >= 1
            del got
            gc.collect()
            wait_until(lambda: pool_stats(server)["segments"] == 0,
                       what="pool to drain after result GC")
            # the connection keeps working after the lease cycle
            again = cli.scan("cam0").labels("car").frames(0, 32).execute()
            assert again.regions

    def test_client_close_flushes_leases(self, served_shm):
        _, server, sock = served_shm
        cli = RemoteVideoStore(sock)
        got = cli.scan("cam0").labels("car").frames(0, 32).execute()
        assert got.regions and pool_stats(server)["segments"] >= 1
        cli.close()
        wait_until(lambda: pool_stats(server)["segments"] == 0,
                   what="pool to drain on client close")
        # views survive the unlink (POSIX mmap semantics): still readable
        assert int(np.asarray(got.regions[0][-1]).sum()) >= 0

    def test_sigkilled_client_leases_are_reclaimed(self, served_shm,
                                                   tmp_path):
        """A client killed with its leases outstanding must not leak
        segments: the connection-drop release + sweep reclaim them."""
        _, server, sock = served_shm
        marker = str(tmp_path / "holding")
        prog = (
            "import sys, time\n"
            "from repro.core import RemoteVideoStore\n"
            "sock, marker = sys.argv[1], sys.argv[2]\n"
            "cli = RemoteVideoStore(sock)\n"
            "r = cli.scan('cam0').labels('car').frames(0, 32).execute()\n"
            "assert cli.transport == 'shm', cli.transport\n"
            "assert r.regions\n"
            "open(marker, 'w').write(str(len(r.regions)))\n"
            "time.sleep(300)  # hold the lease until SIGKILL\n")
        env = dict(os.environ, PYTHONPATH=os.pathsep.join(sys.path))
        proc = subprocess.Popen([sys.executable, "-c", prog, sock, marker],
                                env=env)
        try:
            wait_until(lambda: os.path.exists(marker) or
                       proc.poll() is not None, timeout=120,
                       what="client to take its lease")
            assert proc.poll() is None, "client died before holding lease"
            assert pool_stats(server)["segments"] >= 1
            proc.send_signal(signal.SIGKILL)
            proc.wait(timeout=30)
            wait_until(lambda: pool_stats(server)["segments"] == 0,
                       what="server to reclaim orphaned leases")
        finally:
            if proc.poll() is None:
                proc.kill()
                proc.wait(timeout=30)

    def test_execute_many_over_shm(self, served_shm):
        store, server, sock = served_shm
        mk = lambda s: [s.scan("cam0").labels("car").frames(0, 32),
                        s.scan("cam0").labels("person").frames(0, 16)]
        ref = [q.execute() for q in mk(store)]
        with RemoteVideoStore(sock) as cli:
            got = cli.execute_many(mk(cli))
            for r, g in zip(ref, got):
                assert_regions_equal(r.regions, g.regions)
            del got, g  # the loop var pins the last result's lease too
            gc.collect()
            wait_until(lambda: pool_stats(server)["segments"] == 0,
                       what="pool to drain after execute_many GC")
