"""Data pipeline: prefetch, straggler substitution, TASM-fed batches."""
import time

import numpy as np

from repro.train.data import (PrefetchPipeline, synthetic_token_batches,
                              tasm_region_batches)


def test_synthetic_batches_shift():
    it = synthetic_token_batches(100, 2, 8, n_batches=3)
    b = next(it)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_prefetch_passthrough():
    src = iter(range(10))
    pipe = PrefetchPipeline(src, depth=2, deadline_s=5.0)
    got = list(pipe)
    assert got == list(range(10))
    assert pipe.stats.stall_substitutions == 0


def test_straggler_substitution():
    def slow_source():
        yield "a"
        yield "b"
        time.sleep(0.6)  # straggling shard
        yield "c"

    pipe = PrefetchPipeline(slow_source(), depth=2, deadline_s=0.15)
    got = [next(pipe) for _ in range(4)]
    # the stall was papered over with a repeat of the last ready batch
    assert got[0] == "a" and "b" in got
    assert pipe.stats.stall_substitutions >= 1


def test_tasm_region_batches(small_video):
    from repro.codec.encode import EncoderConfig
    from repro.core import VideoStore

    frames, dets = small_video
    store = VideoStore()
    store.add_video("v", encoder=EncoderConfig(gop=16, qp=8))
    store.ingest("v", frames)
    store.add_detections("v", {f: d for f, d in enumerate(dets)})
    it = tasm_region_batches(store, ["car", "person"], batch=4, crop=16)
    b = next(it)
    assert b["pixels"].shape == (4, 16, 16)
    assert b["labels"].shape == (4,)
    assert np.isfinite(b["pixels"]).all()
