"""Property tests on policy/detector invariants (hypothesis)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.codec.encode import EncoderConfig
from repro.core import RegretPolicy, VideoStore
from repro.core.cost import CostModel, pixels_and_tiles
from repro.core.detector import DetectorConfig, detect
from repro.core.layout import partition, single_tile_layout
from repro.core.policies import _alpha_ok, QueryInfo
from repro.core.storage import SOTRecord

H, W, GOP = 192, 320, 16
MODEL = CostModel(beta=1.4e-8, gamma=1e-5)


def _qi(boxes_by_frame):
    rec = SOTRecord(0, 0, GOP, single_tile_layout(H, W))
    return QueryInfo("v", ("car",), (0, GOP), boxes_by_frame, rec)


box_st = st.tuples(
    st.integers(0, H - 16), st.integers(0, W - 16),
).map(lambda t: (t[0], t[1], min(t[0] + 24, H), min(t[1] + 32, W)))


@settings(max_examples=30, deadline=None)
@given(st.lists(box_st, min_size=1, max_size=5))
def test_alpha_rule_blocks_only_nonreducing_layouts(boxes):
    """If _alpha_ok accepts a layout, it must decode < alpha * omega pixels."""
    bbf = {0: boxes}
    qi = _qi(bbf)
    lay = partition(H, W, boxes)
    omega = single_tile_layout(H, W)
    p_l, _ = pixels_and_tiles(lay, bbf, gop=GOP, sot_frames=(0, GOP))
    p_o, _ = pixels_and_tiles(omega, bbf, gop=GOP, sot_frames=(0, GOP))
    assert (p_l < 0.8 * p_o) == _alpha_ok(lay, qi, GOP, 0.8)


@settings(max_examples=20, deadline=None)
@given(st.lists(box_st, min_size=1, max_size=4))
def test_partition_pixels_never_exceed_omega(boxes):
    bbf = {f: boxes for f in range(4)}
    omega = single_tile_layout(H, W)
    p_o, _ = pixels_and_tiles(omega, bbf, gop=GOP, sot_frames=(0, GOP))
    for gran in ("fine", "coarse"):
        lay = partition(H, W, boxes, granularity=gran)
        p_l, _ = pixels_and_tiles(lay, bbf, gop=GOP, sot_frames=(0, GOP))
        assert p_l <= p_o


def test_regret_never_adopts_vetoed_layout(small_video):
    """Alpha-vetoed (SOT, layout) pairs must never be adopted."""
    frames, dets = small_video
    pol = RegretPolicy(eta=0.0)  # eager: adopt as soon as regret > 0
    store = VideoStore(tuning="inline")  # adoption must happen in the scan
    store.add_video("v", encoder=EncoderConfig(gop=16, qp=8), policy=pol,
                    cost_model=MODEL)
    store.ingest("v", frames)
    store.add_detections("v", {f: d for f, d in enumerate(dets)})
    for _ in range(6):
        store.scan("v").labels("car").frames(0, 32).execute()
    for key in pol.vetoed:
        sot_id, labelset = key
        rec = store.video("v").store.sots[sot_id]
        boxes = [b for f in range(rec.frame_start, rec.frame_end)
                 for l, b in [(l, b) for l, b in dets[f]] if l in labelset]
        cand = partition(*frames.shape[1:], boxes)
        assert rec.layout != cand or cand.n_tiles == 1


class TestDetector:
    def test_full_detects_everything(self, small_video):
        frames, dets = small_video
        found, secs = detect(frames, dets, DetectorConfig(kind="full"))
        n_gt = sum(len(d) for d in dets)
        n_found = sum(len(v) for v in found.values())
        assert n_found == n_gt
        assert secs > 0

    def test_tiny_misses_objects(self, small_video):
        frames, dets = small_video
        found, _ = detect(frames, dets, DetectorConfig(kind="tiny", seed=1))
        n_gt = sum(len(d) for d in dets)
        n_found = sum(len(v) for v in found.values())
        assert n_found < n_gt * 0.8

    def test_strided_cheaper_and_propagates(self, small_video):
        frames, dets = small_video
        full, s_full = detect(frames, dets, DetectorConfig(kind="full"))
        strided, s_str = detect(frames, dets,
                                DetectorConfig(kind="strided", stride=5))
        assert s_str < s_full / 3
        # every frame still has (propagated) detections
        assert set(strided) == set(full)

    def test_bgsub_finds_motion(self, small_video):
        frames, dets = small_video
        found, secs = detect(frames, dets, DetectorConfig(kind="bgsub"))
        assert len(found) > len(frames) // 2
        assert all(l == "object" for v in found.values() for l, _ in v)
