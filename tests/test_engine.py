"""VideoStore engine: catalog, query builder, plan/execute split, manifest
persistence, what-if interface, estimation-only scans."""
import json

import numpy as np
import pytest

from repro.codec.encode import EncoderConfig
from repro.core import (IngestStats, NoTilingPolicy, PretileAllPolicy,
                        RegretPolicy, VideoStore, uniform_layout)
from repro.core.cost import CostModel
from repro.core.layout import partition

ENC = EncoderConfig(gop=16, qp=8)
MODEL = CostModel(beta=1.4e-8, gamma=1e-5)
MODEL.encode_per_pixel = 3.4e-8
MODEL.encode_per_tile = 1e-4


def fill(store, name, frames, dets, policy=None):
    store.add_video(name, encoder=ENC, policy=policy or NoTilingPolicy(),
                    cost_model=MODEL)
    store.ingest(name, frames)
    store.add_detections(name, {f: d for f, d in enumerate(dets)})


class TestCatalog:
    def test_catalog_management(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        fill(store, "cam1", frames, dets)
        assert store.videos() == ["cam0", "cam1"]
        assert "cam0" in store and len(store) == 2
        with pytest.raises(ValueError):
            store.add_video("cam0")
        with pytest.raises(KeyError):
            store.video("nope")

    def test_per_video_configuration(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "cam0", frames, dets, policy=RegretPolicy())
        fill(store, "cam1", frames, dets, policy=NoTilingPolicy())
        assert store.video("cam0").policy.name == "incremental_regret"
        assert store.video("cam1").policy.name == "not_tiled"

    def test_auto_register_on_ingest(self, small_video):
        frames, _ = small_video
        store = VideoStore()
        st = store.ingest("cam0", frames, encoder=ENC, cost_model=MODEL)
        assert isinstance(st, IngestStats)
        assert "cam0" in store and st.encode_s > 0 and st.pretile_s == 0.0

    def test_ingest_rejects_config_for_existing_video(self, small_video):
        frames, _ = small_video
        store = VideoStore()
        store.add_video("cam0", encoder=ENC, cost_model=MODEL)
        with pytest.raises(ValueError, match="already configured"):
            store.ingest("cam0", frames, encoder=EncoderConfig(gop=32))

    def test_default_policy_not_shared_across_videos(self, small_video):
        frames, dets = small_video
        store = VideoStore(default_encoder=ENC,
                           default_cost_model=MODEL,
                           default_policy=RegretPolicy(),
                           tuning="inline")  # policies see scans synchronously
        for name in ("cam0", "cam1"):
            store.ingest(name, frames)
            store.add_detections(name, {f: d for f, d in enumerate(dets)})
        p0, p1 = store.video("cam0").policy, store.video("cam1").policy
        assert p0 is not p1 and p0.name == p1.name == "incremental_regret"
        store.scan("cam0").labels("car").frames(0, 16).execute()
        assert p0.seen and not p1.seen  # cam1's policy saw nothing


class TestQueryBuilder:
    def test_builder_is_immutable_and_forkable(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        base = store.scan("cam0").labels("car")
        early = base.frames(0, 8)
        late = base.frames(8, 16)
        r_early, r_late = early.execute(), late.execute()
        assert all(f < 8 for f, _, _ in r_early.regions)
        assert all(8 <= f < 16 for f, _, _ in r_late.regions)

    def test_requires_labels(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        with pytest.raises(ValueError, match="labels"):
            store.scan("cam0").frames(0, 8).execute()

    def test_bad_range_and_limit(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        with pytest.raises(ValueError):
            store.scan("cam0").frames(8, 8)
        with pytest.raises(ValueError):
            store.scan("cam0").limit(-1)

    def test_limit_truncates_regions_deterministically(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        full = store.scan("cam0").labels("car").frames(0, 32).execute()
        lim = store.scan("cam0").labels("car").frames(0, 32).limit(3).execute()
        assert len(lim.regions) == 3
        for (f1, b1, p1), (f2, b2, p2) in zip(full.regions, lim.regions):
            assert f1 == f2 and b1 == b2
            np.testing.assert_array_equal(p1, p2)

    def test_all_labels_scan(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        res = store.scan("cam0").labels().frames(0, 16).execute()
        per_label = sum(
            len(store.scan("cam0").labels(l).frames(0, 16).execute().regions)
            for l in ("car", "person"))
        assert len(res.regions) == per_label

    def test_all_labels_scan_drives_policies(self, small_video):
        frames, dets = small_video
        store = VideoStore(tuning="inline")
        pol = RegretPolicy()
        fill(store, "cam0", frames, dets, policy=pol)
        store.scan("cam0").labels().frames(0, 16).execute()
        # the resolved label set must reach the policy, not the () sentinel
        assert pol.seen == {"car", "person"}

    def test_cnf_conjunction(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        res = store.scan("cam0").labels([["car"], ["person"]]).execute()
        # conjunction intersects boxes: strictly fewer regions than union
        union = store.scan("cam0").labels("car", "person").execute()
        assert len(res.regions) <= len(union.regions)


class TestPlanExecute:
    def test_explain_reports_without_decoding(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        plan = store.scan("cam0").labels("car").frames(0, 32).explain()
        assert len(plan.sot_scans) == 2  # 32 frames / 16-frame SOTs
        assert plan.est_pixels > 0 and plan.est_tiles >= 2
        assert plan.est_cost_s > 0
        text = plan.describe()
        assert "SCAN cam0" in text and "sot=" in text
        # explain is pure: no history, no decode counters
        assert store.history == [] and store.video("cam0").history == []

    def test_estimates_match_what_if(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        plan = store.scan("cam0").labels("car").frames(0, 32).explain()
        # plan estimates charge what the engine actually decodes (ROI
        # blocks); what_if's default "tile" granularity models a standard
        # full-tile decoder for layout decisions
        assert plan.est_cost_s == pytest.approx(
            store.what_if("cam0", "car", {}, (0, 32), granularity="block"))
        assert plan.est_cost_s <= store.what_if("cam0", "car", {}, (0, 32))
        # with ROI decode off, plans estimate full-tile decode again
        full = VideoStore(roi_decode=False)
        fill(full, "cam0", frames, dets)
        fplan = full.scan("cam0").labels("car").frames(0, 32).explain()
        assert fplan.est_cost_s == pytest.approx(
            full.what_if("cam0", "car", {}, (0, 32)))
        with pytest.raises(ValueError, match="granularity"):
            store.what_if("cam0", "car", {}, (0, 32), granularity="roi")

    def test_decode_false_estimation_only(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        res = store.scan("cam0").labels("car").frames(0, 32) \
                   .decode(False).execute()
        assert res.regions == []
        assert res.stats.pixels_decoded > 0 and res.stats.tiles_decoded > 0
        assert res.stats.decode_s == 0.0
        # estimation-only scans still drive incremental policies
        store2 = VideoStore(tuning="inline")
        fill(store2, "cam0", frames, dets, policy=RegretPolicy())
        for _ in range(8):
            store2.scan("cam0").labels("car").frames(0, 16) \
                  .decode(False).execute()
        assert any(r.layout.n_tiles > 1
                   for r in store2.video("cam0").store.sots[:1])

    def test_stale_epoch_replans_tiles(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        plan = store.scan("cam0").labels("car").frames(0, 16).explain()
        H, W = frames.shape[1:]
        store.video("cam0").store.retile(0, uniform_layout(H, W, 2, 2))
        res = store.execute(plan)  # plan now stale: epoch bumped
        assert res.stats.regions == plan.n_regions
        for f, box, px in res.regions:
            y1, x1, y2, x2 = box
            assert np.abs(px - frames[f, y1:y2, x1:x2]).mean() < 6.0

    def test_cross_video_scan(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        fill(store, "cam1", frames, dets)
        res = store.scan(["cam0", "cam1"]).labels("car").frames(0, 16) \
                   .execute()
        assert res.regions and len(res.regions[0]) == 4  # video-tagged
        assert set(res.regions_by_video) == {"cam0", "cam1"}
        n0 = len(res.regions_by_video["cam0"])
        n1 = len(res.regions_by_video["cam1"])
        assert n0 == n1 and n0 + n1 == len(res.regions)

    def test_what_if_prefers_tiled_layouts(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "cam0", frames, dets)
        boxes = [b for d in dets[:16] for _, b in d]
        H, W = frames.shape[1:]
        fine = partition(H, W, boxes)
        cur = store.what_if("cam0", "car", {}, (0, 16))
        alt = store.what_if("cam0", "car", {0: fine}, (0, 16))
        assert 0 < alt < cur


class TestManifest:
    def test_reopen_serves_scans_without_reingest(self, small_video,
                                                  tmp_path):
        frames, dets = small_video
        store = VideoStore(store_root=str(tmp_path), tuning="inline")
        fill(store, "cam0", frames, dets, policy=RegretPolicy())
        for _ in range(8):  # trigger re-tiling so layouts have epoch > 0
            store.scan("cam0").labels("car").frames(0, 32).execute()
        res1 = store.scan("cam0").labels("car").frames(0, 32).execute()
        layouts1 = [(r.layout, r.epoch)
                    for r in store.video("cam0").store.sots]
        bytes1 = store.storage_bytes()
        del store

        store2 = VideoStore(store_root=str(tmp_path), tuning="inline")
        assert store2.videos() == ["cam0"]
        entry = store2.video("cam0")
        assert entry.policy.name == "incremental_regret"
        assert entry.encoder == ENC
        assert entry.cost_model.beta == MODEL.beta
        assert [(r.layout, r.epoch) for r in entry.store.sots] == layouts1
        assert store2.storage_bytes() == bytes1
        res2 = store2.scan("cam0").labels("car").frames(0, 32).execute()
        assert len(res2.regions) == len(res1.regions)
        for (f1, b1, p1), (f2, b2, p2) in zip(res1.regions, res2.regions):
            assert f1 == f2 and b1 == b2
            np.testing.assert_array_equal(p1, p2)

    def test_manifest_is_versioned_and_sharded(self, small_video, tmp_path):
        frames, dets = small_video
        store = VideoStore(store_root=str(tmp_path))
        fill(store, "cam0", frames, dets)
        cat = json.loads((tmp_path / "catalog.json").read_text())
        assert cat["version"] == 3 and cat["videos"] == ["cam0"]
        v = json.loads((tmp_path / "cam0" / "manifest.json").read_text())
        assert v["version"] == 3 and v["name"] == "cam0"
        assert "policy_state" in v  # v3: policy runtime state persisted
        assert v["encoder"]["gop"] == 16 and v["sot_len"] == 16
        assert len(v["sots"]) == len(frames) // 16
        assert v["index"]  # semantic-index entries persisted

    def test_mutation_rewrites_only_the_touched_shard(self, small_video,
                                                      tmp_path):
        frames, dets = small_video
        store = VideoStore(store_root=str(tmp_path))
        fill(store, "cam0", frames, dets)
        fill(store, "cam1", frames, dets)
        other = tmp_path / "cam0" / "manifest.json"
        before = other.stat().st_mtime_ns
        store.add_metadata("cam1", 0, "bus", 1, 1, 9, 9)
        assert other.stat().st_mtime_ns == before  # cam0 shard untouched
        v1 = json.loads((tmp_path / "cam1" / "manifest.json").read_text())
        assert any(lbl == "bus" for _, lbl, _, _ in v1["index"])

    def test_add_metadata_survives_reopen(self, small_video, tmp_path):
        frames, dets = small_video
        store = VideoStore(store_root=str(tmp_path))
        fill(store, "cam0", frames, dets)
        store.add_metadata("cam0", 3, "bicycle", 10, 20, 30, 40)
        del store
        reopened = VideoStore(store_root=str(tmp_path))
        boxes = reopened.video("cam0").index.boxes_for_label("cam0", "bicycle")
        assert boxes == {3: [(20, 10, 40, 30)]}  # ADDMETADATA is durable

    def test_v1_monolithic_manifest_migrates(self, small_video, tmp_path):
        frames, dets = small_video
        store = VideoStore(store_root=str(tmp_path))
        fill(store, "cam0", frames, dets)
        fill(store, "cam1", frames, dets, policy=PretileAllPolicy())
        res1 = store.scan("cam0").labels("car").frames(0, 32).execute()
        del store
        # rewrite the on-disk state in the v1 monolithic format
        videos = {}
        for name in ("cam0", "cam1"):
            shard = tmp_path / name / "manifest.json"
            doc = json.loads(shard.read_text())
            doc.pop("version"), doc.pop("name")
            videos[name] = doc
            shard.unlink()
        (tmp_path / "catalog.json").unlink()
        (tmp_path / "manifest.json").write_text(
            json.dumps({"version": 1, "videos": videos}))

        store2 = VideoStore(store_root=str(tmp_path))  # migrates on open
        assert store2.videos() == ["cam0", "cam1"]
        assert (tmp_path / "catalog.json").exists()
        assert (tmp_path / "cam0" / "manifest.json").exists()
        assert not (tmp_path / "manifest.json").exists()
        assert (tmp_path / "manifest.json.v1.bak").exists()
        res2 = store2.scan("cam0").labels("car").frames(0, 32).execute()
        assert len(res2.regions) == len(res1.regions)  # no re-ingest
        for (f1, b1, p1), (f2, b2, p2) in zip(res1.regions, res2.regions):
            assert f1 == f2 and b1 == b2
            np.testing.assert_array_equal(p1, p2)

    def test_multi_video_manifest(self, small_video, tmp_path):
        frames, dets = small_video
        store = VideoStore(store_root=str(tmp_path))
        fill(store, "cam0", frames, dets)
        fill(store, "cam1", frames, dets, policy=PretileAllPolicy())
        del store
        store2 = VideoStore(store_root=str(tmp_path))
        assert store2.videos() == ["cam0", "cam1"]
        assert store2.video("cam1").policy.name == "pretile_all"
        r = store2.scan(["cam0", "cam1"]).labels("car").frames(0, 16) \
                  .execute()
        assert len(r.regions_by_video["cam0"]) > 0

    def test_drop_video_removes_data(self, small_video, tmp_path):
        frames, dets = small_video
        store = VideoStore(store_root=str(tmp_path))
        fill(store, "cam0", frames, dets)
        assert (tmp_path / "cam0").exists()
        store.drop_video("cam0")
        assert not (tmp_path / "cam0").exists()
        assert "cam0" not in VideoStore(store_root=str(tmp_path))


class TestIngestContract:
    def test_policy_path_counts_pretile_separately(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        store.add_video("v", encoder=ENC, policy=PretileAllPolicy(),
                        cost_model=MODEL)
        store.add_detections("v", {f: d for f, d in enumerate(dets)})
        st = store.ingest("v", frames)
        assert st.encode_s > 0 and st.pretile_s > 0

    def test_initial_layouts_path_has_zero_pretile(self, small_video):
        frames, dets = small_video
        H, W = frames.shape[1:]
        boxes = [b for d in dets[:16] for _, b in d]
        store = VideoStore()
        store.add_video("v", encoder=ENC, cost_model=MODEL)
        st = store.ingest("v", frames,
                          initial_layouts={0: partition(H, W, boxes)})
        assert st.encode_s > 0 and st.pretile_s == 0.0
        assert store.video("v").store.sots[0].layout.n_tiles > 1


class TestReingestGuard:
    def test_second_ingest_of_same_video_rejected(self, small_video):
        frames, _ = small_video
        store = VideoStore()
        store.add_video("v", encoder=ENC, cost_model=MODEL)
        store.ingest("v", frames)
        with pytest.raises(ValueError, match="already has ingested"):
            store.ingest("v", frames)
