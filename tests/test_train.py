"""Training: loss goes down, grad-accumulation equivalence, lr schedule."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduce_config
from repro.models import zoo
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state, lr_schedule
from repro.train.train_step import make_train_step


@pytest.fixture(scope="module")
def tiny():
    cfg = reduce_config(get_config("smollm-135m"))
    params = zoo.init_model(cfg, jax.random.key(0))
    return cfg, params


def batches(cfg, n, B=4, S=32, seed=0):
    rng = np.random.default_rng(seed)
    for _ in range(n):
        toks = rng.integers(0, cfg.vocab, size=(B, S + 1), dtype=np.int32)
        yield {"tokens": jnp.asarray(toks[:, :-1]),
               "targets": jnp.asarray(toks[:, 1:])}


def test_loss_decreases_on_fixed_batch(tiny):
    cfg, params = tiny
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=3e-3, warmup_steps=2,
                                                    total_steps=30)))
    opt = init_opt_state(params)
    batch = next(batches(cfg, 1))
    losses = []
    for _ in range(25):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]


def test_grad_accumulation_matches_full_batch(tiny):
    cfg, params = tiny
    opt = init_opt_state(params)
    batch = next(batches(cfg, 1, B=8))
    s1 = make_train_step(cfg, AdamWConfig(lr=1e-3), microbatches=1)
    s4 = make_train_step(cfg, AdamWConfig(lr=1e-3), microbatches=4)
    p1, _, m1 = jax.jit(s1)(params, opt, batch)
    p4, _, m4 = jax.jit(s4)(params, opt, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-2
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-3)


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in (0, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2]          # warmup
    assert lrs[2] >= lrs[3] >= lrs[4]        # decay
    assert lrs[4] >= 0.1 * 1e-3 * 0.99       # floor


def test_clip_norm_applied():
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.full((4, 4), 1e6)}
    opt = init_opt_state(params)
    _, _, m = adamw_update(grads, opt, params, AdamWConfig(clip_norm=1.0))
    assert float(m["grad_norm"]) > 1e6  # reported pre-clip


def test_greedy_generate_runs(tiny):
    from repro.serve.serve_step import greedy_generate

    cfg, params = tiny
    prompt = jnp.zeros((2, 4), jnp.int32)
    out = greedy_generate(params, cfg, prompt, max_new=5)
    assert out.shape == (2, 5)
    assert np.asarray(out).max() < cfg.vocab
