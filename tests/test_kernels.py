"""Pallas kernels vs their jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dct.ops import dct_quant_op
from repro.kernels.dct.ref import dct_quant_ref
from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.idct.ops import idct_dequant_op
from repro.kernels.idct.ref import idct_dequant_ref
from repro.kernels.sad.ops import frame_motion_blocks, sad_search_op
from repro.kernels.sad.ref import sad_search_ref


@pytest.mark.parametrize("n", [8, 64, 100, 500])
@pytest.mark.parametrize("qp,intra", [(4, True), (8, False), (16, True)])
def test_dct_kernel_sweep(n, qp, intra):
    x = jax.random.normal(jax.random.key(n), (n, 8, 8)) * 60
    got = dct_quant_op(x, qp=qp, intra=intra, interpret=True)
    want = dct_quant_ref(x, qp, intra)
    # round() at exact .5 boundaries may differ by 1 ulp of the int grid
    assert (np.asarray(got) == np.asarray(want)).mean() > 0.999


@pytest.mark.parametrize("n", [8, 77, 256])
@pytest.mark.parametrize("qp,intra", [(8, True), (12, False)])
def test_idct_kernel_sweep(n, qp, intra):
    q = jax.random.randint(jax.random.key(n), (n, 8, 8), -300, 300)
    q = q.astype(jnp.int16)
    got = idct_dequant_op(q, qp=qp, intra=intra, interpret=True)
    want = idct_dequant_ref(q, qp, intra)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-5)


def test_dct_idct_roundtrip_via_kernels():
    x = jax.random.normal(jax.random.key(0), (64, 8, 8)) * 50
    q = dct_quant_op(x, qp=2, intra=True, interpret=True)
    y = idct_dequant_op(q, qp=2, intra=True, interpret=True)
    # random gaussian blocks are worst-case for transform coding: bound the
    # mean error by half the largest quant step at qp=2
    assert float(jnp.abs(y - x).mean()) < 4.0


@pytest.mark.parametrize("b,r", [(8, 4), (16, 8)])
def test_sad_kernel_sweep(b, r):
    n = 32
    cur = jax.random.normal(jax.random.key(1), (n, b, b)) * 25
    win = jax.random.normal(jax.random.key(2), (n, b + 2 * r, b + 2 * r)) * 25
    dy, dx, sad = sad_search_op(cur, win, interpret=True)
    rdy, rdx, rsad = sad_search_ref(cur, win)
    np.testing.assert_allclose(np.asarray(sad), np.asarray(rsad), rtol=1e-5)
    assert (np.asarray(dy) == np.asarray(rdy)).all()
    assert (np.asarray(dx) == np.asarray(rdx)).all()


def test_sad_finds_planted_motion():
    """Plant a known shift and verify the kernel recovers it."""
    rng = np.random.default_rng(0)
    ref = rng.uniform(0, 255, (64, 64)).astype(np.float32)
    cur = np.roll(ref, shift=(3, -2), axis=(0, 1))
    blocks, windows = frame_motion_blocks(cur, ref, b=16, r=8)
    dy, dx, sad = sad_search_op(jnp.asarray(blocks), jnp.asarray(windows),
                                interpret=True)
    # cur[y, x] == ref[y-3, x+2]  =>  best match at displacement (r-3, r+2)
    inner = [5, 6, 9, 10]
    assert all(int(dy[i]) == 8 - 3 for i in inner)
    assert all(int(dx[i]) == 8 + 2 for i in inner)


@pytest.mark.parametrize("s,h,kv,d", [(128, 4, 4, 32), (256, 4, 2, 64),
                                      (256, 8, 1, 32)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(s, h, kv, d, causal, dtype):
    b = 2
    q = jax.random.normal(jax.random.key(3), (b, h, s, d), dtype)
    k = jax.random.normal(jax.random.key(4), (b, kv, s, d), dtype)
    v = jax.random.normal(jax.random.key(5), (b, kv, s, d), dtype)
    got = flash_attention_op(q, k, v, causal=causal, bq=64, bkv=64,
                             interpret=True)
    want = attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_flash_attention_block_shapes():
    """Non-default block shapes must not change the result."""
    b, h, kv, s, d = 1, 2, 2, 256, 32
    q = jax.random.normal(jax.random.key(6), (b, h, s, d))
    k = jax.random.normal(jax.random.key(7), (b, kv, s, d))
    v = jax.random.normal(jax.random.key(8), (b, kv, s, d))
    a = flash_attention_op(q, k, v, bq=128, bkv=32, interpret=True)
    bb = flash_attention_op(q, k, v, bq=32, bkv=128, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bb), atol=1e-5)
