"""ROI-restricted block decode: bit-identity with full-decode-then-crop
across every execution path, cache block-coverage/superset serving, lazy
per-GOP tile reads, and block-granular accounting."""
import zipfile

import numpy as np
import pytest

from repro.codec.encode import EncoderConfig, decode_tile, encode_tile
from repro.core import (NoTilingPolicy, RegretPolicy, TileCache, VideoStore,
                        uniform_layout)
from repro.core.cost import CostModel
from repro.core.layout import TileLayout, block_coverage

ENC = EncoderConfig(gop=16, qp=8)
MODEL = CostModel(beta=1.4e-8, gamma=1e-5)
MODEL.encode_per_pixel = 3.4e-8
MODEL.encode_per_tile = 1e-4


def fill(store, name, frames, dets, policy=None):
    store.add_video(name, encoder=ENC, policy=policy or NoTilingPolicy(),
                    cost_model=MODEL)
    store.ingest(name, frames)
    store.add_detections(name, {f: d for f, d in enumerate(dets)})


def assert_regions_equal(a, b):
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        assert ra[:-1] == rb[:-1]
        np.testing.assert_array_equal(ra[-1], rb[-1])


def random_boxes(rng, H, W, n):
    """n random (possibly tiny, possibly unaligned) boxes inside HxW."""
    boxes = []
    for _ in range(n):
        h = int(rng.integers(4, 49))
        w = int(rng.integers(4, 57))
        y1 = int(rng.integers(0, H - h))
        x1 = int(rng.integers(0, W - w))
        boxes.append((y1, x1, y1 + h, x1 + w))
    return boxes


# ------------------------------------------------------------------- codec
class TestCodecBlocks:
    def test_random_block_subsets_bit_identical(self, sparse_video):
        video = sparse_video[0][:32, :48, :64]
        enc = encode_tile(np.ascontiguousarray(video), ENC)
        full = decode_tile(enc)
        nb_r, nb_c = 48 // 8, 64 // 8
        v_full = full.reshape(-1, nb_r, 8, nb_c, 8)
        rng = np.random.default_rng(0)
        for _ in range(20):
            k = int(rng.integers(1, nb_r * nb_c + 1))
            blocks = sorted(rng.choice(nb_r * nb_c, size=k, replace=False))
            roi = decode_tile(enc, blocks=blocks)
            v_roi = roi.reshape(-1, nb_r, 8, nb_c, 8)
            rs, cs = np.divmod(np.asarray(blocks), nb_c)
            np.testing.assert_array_equal(v_roi[:, rs, :, cs],
                                          v_full[:, rs, :, cs])
            # unselected blocks are exactly zero, never stale content
            hole = np.ones((nb_r, nb_c), bool)
            hole[rs, cs] = False
            hr, hc = np.where(hole)
            assert not v_roi[:, hr, :, hc].any()

    def test_blocks_with_gop_subsets_and_partial_frames(self, sparse_video):
        video = sparse_video[0][:32, :48, :64]
        enc = encode_tile(np.ascontiguousarray(video), ENC)
        ref = decode_tile(enc, gop_indices=[1], frames_within=7)
        roi = decode_tile(enc, gop_indices=[1], frames_within=7,
                          blocks=[0, 13, 40])
        v_ref = ref.reshape(7, 6, 8, 8, 8)
        v_roi = roi.reshape(7, 6, 8, 8, 8)
        rs, cs = np.divmod(np.asarray([0, 13, 40]), 8)
        np.testing.assert_array_equal(v_roi[:, rs, :, cs],
                                      v_ref[:, rs, :, cs])

    def test_empty_and_full_masks(self, sparse_video):
        video = sparse_video[0][:16, :32, :32]
        enc = encode_tile(np.ascontiguousarray(video), ENC)
        assert not decode_tile(enc, blocks=[]).any()
        np.testing.assert_array_equal(
            decode_tile(enc, blocks=range(16)), decode_tile(enc))


# ---------------------------------------------------------- block coverage
class TestBlockCoverage:
    def test_masks_cover_exactly_intersected_blocks(self):
        lay = uniform_layout(96, 160, 2, 2)
        boxes = {0: [(10, 12, 30, 41)]}
        cov = block_coverage(lay, boxes)
        for t, mask in cov.items():
            ty1, tx1, ty2, tx2 = lay.tile_rect(t)
            nbx = (tx2 - tx1) // 8
            assert mask is not None
            for b in mask:
                r, c = divmod(b, nbx)
                by1, bx1 = ty1 + r * 8, tx1 + c * 8
                # every selected block overlaps the box
                assert by1 < 30 and by1 + 8 > 10
                assert bx1 < 41 and bx1 + 8 > 12
        # total selected blocks == blocks of the 8-aligned box superset
        n_sel = sum(len(m) for m in cov.values())
        assert n_sel == ((32 - 8) // 8) * ((48 - 8) // 8)

    def test_coverage_agrees_with_blocks_intersecting(self, small_video):
        # block_coverage's vectorized bitmap marking and the per-box
        # blocks_intersecting helper are two spellings of one geometry:
        # pin them to each other over random layouts and boxes
        H, W = 96, 160
        rng = np.random.default_rng(3)
        for _ in range(10):
            lay = uniform_layout(H, W, int(rng.integers(1, 4)),
                                 int(rng.integers(1, 4)))
            boxes = {f: random_boxes(rng, H, W, 2) for f in range(3)}
            cov = block_coverage(lay, boxes)
            want: dict = {}
            for bs in boxes.values():
                for box in bs:
                    for t in lay.tiles_intersecting(box):
                        want.setdefault(t, set()).update(
                            lay.blocks_intersecting(t, box))
            want = {t: s for t, s in want.items() if s}
            assert set(cov) == set(want)
            for t, mask in cov.items():
                full = set(range(lay.tile_blocks(t)))
                assert (full if mask is None else set(mask)) == want[t]

    def test_full_coverage_normalizes_to_none(self):
        lay = TileLayout((32,), (32,))
        cov = block_coverage(lay, {0: [(0, 0, 32, 32)]})
        assert cov == {0: None}


# --------------------------------------------- engine-level bit-identity
class TestRoiBitIdentity:
    def _stores(self, frames, dets, extra, **roi_kw):
        """(full-tile control, ROI store) over identical content."""
        control = VideoStore(tile_cache_bytes=0, roi_decode=False)
        fill(control, "v", frames, dets)
        roi = VideoStore(**roi_kw)
        fill(roi, "v", frames, dets)
        for store in (control, roi):
            for label, by_frame in extra.items():
                store.add_detections(
                    "v", {f: [(label, b) for b in boxes]
                          for f, boxes in by_frame.items()})
        return control, roi

    def test_random_layouts_rois_and_ranges(self, small_video):
        frames, dets = small_video
        H, W = frames.shape[1:]
        rng = np.random.default_rng(7)
        # synthetic ROI labels with random boxes on random frames
        extra = {}
        for i in range(4):
            by_frame = {}
            for f in sorted(rng.choice(32, size=10, replace=False)):
                by_frame[int(f)] = random_boxes(rng, H, W,
                                                int(rng.integers(1, 3)))
            extra[f"roi{i}"] = by_frame
        control, roi = self._stores(frames, dets, extra)
        # random per-SOT layouts, identical on both stores
        for sot_id in (0, 1):
            r, c = int(rng.integers(1, 4)), int(rng.integers(1, 4))
            lay = uniform_layout(H, W, r, c)
            control.retile("v", sot_id, lay)
            roi.retile("v", sot_id, lay)
        labels = ["car", "person"] + [f"roi{i}" for i in range(4)]
        for trial in range(12):
            label = labels[int(rng.integers(0, len(labels)))]
            lo = int(rng.integers(0, 31))
            hi = int(rng.integers(lo + 1, 33))
            rc = control.scan("v").labels(label).frames(lo, hi).execute()
            rr = roi.scan("v").labels(label).frames(lo, hi).execute()
            assert_regions_equal(rc.regions, rr.regions)

    def test_execute_many_and_serve_match_serial_full(self, small_video):
        frames, dets = small_video
        H, W = frames.shape[1:]
        rng = np.random.default_rng(11)
        extra = {"roi0": {f: random_boxes(rng, H, W, 2) for f in range(32)}}
        control, roi = self._stores(frames, dets, extra)
        queries = [("roi0", (0, 9)), ("car", (0, 32)), ("roi0", (4, 20)),
                   ("person", (8, 32)), ("roi0", (0, 32))]
        want = [control.scan("v").labels(l).frames(*fr).execute().regions
                for l, fr in queries]
        got = roi.execute_many([roi.scan("v").labels(l).frames(*fr)
                                for l, fr in queries])
        for w, g in zip(want, got):
            assert_regions_equal(w, g.regions)
        with roi.serve() as session:
            futs = [session.submit(roi.scan("v").labels(l).frames(*fr))
                    for l, fr in queries]
            for w, fut in zip(want, futs):
                assert_regions_equal(w, fut.result(timeout=60).regions)

    def test_mid_batch_retile_matches_serial_full(self, small_video):
        frames, dets = small_video
        n = 10  # pushes RegretPolicy over its threshold mid-batch
        control = VideoStore(tile_cache_bytes=0, roi_decode=False,
                             tuning="inline")
        fill(control, "v", frames, dets, policy=RegretPolicy())
        want = [control.scan("v").labels("car").frames(0, 32).execute()
                for _ in range(n)]
        assert any(r.stats.retile_s > 0 for r in want)  # it retiled

        roi = VideoStore(tuning="inline")
        fill(roi, "v", frames, dets, policy=RegretPolicy())
        got = roi.execute_many([roi.scan("v").labels("car").frames(0, 32)
                                for _ in range(n)])
        for w, g in zip(want, got):
            assert_regions_equal(w.regions, g.regions)
        layouts = lambda s: [(r.layout, r.epoch)
                             for r in s.video("v").store.sots]
        assert layouts(control) == layouts(roi)

    def test_stale_roi_plan_recomputes_masks(self, small_video):
        frames, dets = small_video
        H, W = frames.shape[1:]
        store = VideoStore()
        fill(store, "v", frames, dets)
        plan = store.scan("v").labels("car").frames(0, 16).explain()
        assert any(ss.blocks_by_tile for ss in plan.sot_scans)
        store.retile("v", 0, uniform_layout(H, W, 2, 2))
        res = store.execute(plan)   # stale epoch: masks recomputed
        control = VideoStore(tile_cache_bytes=0, roi_decode=False)
        fill(control, "v", frames, dets)
        control.retile("v", 0, uniform_layout(H, W, 2, 2))
        assert_regions_equal(
            control.scan("v").labels("car").frames(0, 16).execute().regions,
            res.regions)


# ------------------------------------------------------- cache coverage
class TestCacheCoverage:
    def test_unit_block_coverage_semantics(self):
        c = TileCache(budget_bytes=1 << 20)
        arr = np.arange(8 * 16 * 16, dtype=np.float32).reshape(8, 16, 16)
        key = ("v", 0, 0, 0)
        c.put(key, arr, blocks=[0, 1])
        # subset of the mask hits; superset/full/disjoint miss
        assert c.get(key, blocks=[0]) is not None
        assert c.get(key, blocks=[0, 1]) is not None
        assert c.get(key, blocks=[0, 2]) is None
        assert c.get(key) is None                 # full-tile request
        assert c.coverage(key) == (8, frozenset([0, 1]))
        # a narrower put never clobbers wider coverage
        c.put(key, arr, blocks=[3])
        assert c.get(key, blocks=[0]) is not None
        # the union decode replaces it and serves everyone
        c.put(key, arr, blocks=[0, 1, 2, 3])
        assert c.get(key, blocks=[0, 2]) is not None
        # full-tile entries serve any mask
        c.put(key, arr)
        assert c.get(key) is not None
        assert c.get(key, blocks=[2]) is not None
        # ... and are not replaced by partial re-decodes
        c.put(key, arr, blocks=[0])
        assert c.get(key) is not None

    def test_full_tile_entry_serves_sub_roi_without_decode(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "v", frames, dets)
        store.add_detections("v", {0: [("roi", (8, 8, 40, 40))]})
        # warm a FULL-tile entry (runtime toggle: plans lowered while the
        # flag is off decode whole tiles), then serve sub-ROI scans from it
        store.roi_decode = False
        store.scan("v").labels("car").frames(0, 16).execute()
        store.roi_decode = True
        decoded = store.video("v").store.tiles_decoded_total
        r = store.scan("v").labels("roi").frames(0, 16).execute()
        assert store.video("v").store.tiles_decoded_total == decoded
        assert r.stats.cache_misses == 0 and r.stats.pixels_decoded == 0
        assert r.regions  # it did serve pixels, from the covering entry

    def test_repeat_roi_scan_decodes_zero_tiles(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "v", frames, dets)
        store.add_detections("v", {f: [("roi", (16, 24, 48, 72))]
                                   for f in range(16)})
        q = store.scan("v").labels("roi").frames(0, 16)
        r1 = q.execute()
        assert r1.stats.cache_misses > 0 and r1.stats.pixels_decoded > 0
        decoded = store.video("v").store.tiles_decoded_total
        r2 = q.execute()
        assert store.video("v").store.tiles_decoded_total == decoded
        assert r2.stats.cache_misses == 0 and r2.stats.pixels_decoded == 0
        assert_regions_equal(r1.regions, r2.regions)

    def test_disjoint_roi_unions_masks(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "v", frames, dets)
        store.add_detections("v", {0: [("a", (0, 0, 16, 16))],
                                   1: [("b", (64, 96, 88, 144))]})
        ra = store.scan("v").labels("a").frames(0, 16).execute()
        assert ra.stats.cache_misses == 1
        # disjoint ROI in the same tile: miss, re-decode unions the masks
        rb = store.scan("v").labels("b").frames(0, 16).execute()
        assert rb.stats.cache_misses == 1
        decoded = store.video("v").store.tiles_decoded_total
        # now BOTH ROIs are covered by the union entry
        ra2 = store.scan("v").labels("a").frames(0, 16).execute()
        rb2 = store.scan("v").labels("b").frames(0, 16).execute()
        assert store.video("v").store.tiles_decoded_total == decoded
        assert ra2.stats.cache_misses == rb2.stats.cache_misses == 0
        assert_regions_equal(ra.regions, ra2.regions)
        assert_regions_equal(rb.regions, rb2.regions)

    def test_covered_pixels_match_uncached_control(self, small_video):
        # superset-serving never returns pixels outside the covering entry:
        # every region served out of an ROI entry equals a cold decode
        frames, dets = small_video
        store = VideoStore()
        fill(store, "v", frames, dets)
        store.add_detections("v", {f: [("wide", (8, 8, 56, 120)),
                                       ("sub", (16, 16, 40, 70))]
                                   for f in range(8)})
        store.scan("v").labels("wide").frames(0, 8).execute()  # warm ROI
        served = store.scan("v").labels("sub").frames(0, 8).execute()
        assert served.stats.cache_misses == 0
        control = VideoStore(tile_cache_bytes=0, roi_decode=False)
        fill(control, "v", frames, dets)
        control.add_detections("v", {f: [("sub", (16, 16, 40, 70))]
                                     for f in range(8)})
        assert_regions_equal(
            control.scan("v").labels("sub").frames(0, 8).execute().regions,
            served.regions)


# ------------------------------------------------------ block accounting
class TestBlockAccounting:
    def test_cold_solo_scan_estimate_equals_actual(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "v", frames, dets)
        q = store.scan("v").labels("car").frames(0, 16)
        plan = q.explain()
        base = store.video("v").store.pixels_decoded_total
        res = q.execute()
        actual = store.video("v").store.pixels_decoded_total - base
        assert res.stats.pixels_decoded == actual == plan.est_pixels > 0

    def test_roi_shrinks_estimates_vs_full(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "v", frames, dets)
        full = VideoStore(roi_decode=False)
        fill(full, "v", frames, dets)
        p_roi = store.scan("v").labels("car").frames(0, 16).explain()
        p_full = full.scan("v").labels("car").frames(0, 16).explain()
        assert 0 < p_roi.est_pixels < p_full.est_pixels
        assert p_roi.est_tiles == p_full.est_tiles


# ---------------------------------------------------- lazy per-GOP reads
class TestLazyTileReads:
    def test_per_gop_members_and_prefix_read(self, sparse_video, tmp_path):
        frames, dets = sparse_video
        store = VideoStore(store_root=str(tmp_path))
        store.add_video("v", encoder=ENC, cost_model=MODEL, sot_len=64)
        store.ingest("v", frames)
        store.add_detections("v", {f: d for f, d in enumerate(dets)})
        path = tmp_path / "v" / "frames_0-63" / "tile0.npz"
        names = set(zipfile.ZipFile(path).namelist())
        assert {"kq_0.npy", "pq_0.npy", "kq_3.npy", "pq_3.npy"} <= names
        assert "kq.npy" not in names
        ts = store.video("v").store
        # a 1-frame prefix read materializes only GOP 0
        enc = ts._read_tile(ts.sots[0], 0, n_gops=1)
        assert len(enc["kq"]) == 1 and len(enc["pq"]) == 1
        # prefix decode equals the prefix of a full decode
        full = ts.decode_tiles(0, [0])[0]
        part = ts.decode_tiles(0, [0], n_frames=20)[0]
        np.testing.assert_array_equal(part, full[:20])

    def test_legacy_single_member_format_still_reads(self, small_video,
                                                     tmp_path):
        frames, dets = small_video
        store = VideoStore(store_root=str(tmp_path))
        store.add_video("v", encoder=ENC, cost_model=MODEL)
        store.ingest("v", frames)
        ts = store.video("v").store
        want = ts.decode_tiles(0, [0])[0]
        # rewrite tile 0 of SOT 0 in the pre-PR layout (one member per array)
        path = tmp_path / "v" / "frames_0-15" / "tile0.npz"
        enc = encode_tile(np.ascontiguousarray(frames[:16]), ENC)
        np.savez_compressed(path, kq=enc["kq"], pq=enc["pq"],
                            meta=np.array([enc["h"], enc["w"], enc["gop"],
                                           enc["qp"], enc["n_frames"]]),
                            size=np.array([enc["size_bytes"]]))
        got = ts.decode_tiles(0, [0])[0]
        np.testing.assert_array_equal(want, got)
        roi = ts.decode_tiles(0, [0], blocks={0: (0, 5)})[0]
        v_w, v_r = (a.reshape(16, 12, 8, 20, 8) for a in (want, roi))
        rs, cs = np.divmod(np.asarray([0, 5]), 20)
        np.testing.assert_array_equal(v_r[:, rs, :, cs], v_w[:, rs, :, cs])

    def test_in_memory_prefix_read_slices(self, small_video):
        frames, dets = small_video
        store = VideoStore()
        fill(store, "v", frames, dets)
        ts = store.video("v").store
        enc = ts._read_tile(ts.sots[0], 0, n_gops=1)
        assert len(enc["kq"]) == 1
