"""Tests for the beyond-paper extensions: ring attention, continuous
batching, spatial grid index."""
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")

box_st = st.tuples(
    st.integers(0, 160), st.integers(0, 280),
    st.integers(8, 48), st.integers(8, 48),
).map(lambda t: (t[0], t[1], min(t[0] + t[2], 192), min(t[1] + t[3], 320)))


@settings(max_examples=50, deadline=None)
@given(st.lists(box_st, max_size=8), st.lists(box_st, max_size=8),
       st.sampled_from([16, 64, 128]))
def test_spatial_grid_matches_bruteforce(a, b, cell):
    from repro.core.spatial_index import (brute_force_intersections,
                                          conjunctive_intersections)

    assert conjunctive_intersections(a, b, cell=cell) == \
        brute_force_intersections(a, b)


def test_ring_attention_matches_reference():
    """Ring attention on a 4-way host ring == single-device attention."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.distributed.ring_attention import ring_attention, ring_attention_ref

mesh = jax.make_mesh((2, 4), ("data", "model"))
B, S, KV, G, D = 2, 64, 2, 2, 16
q = jax.random.normal(jax.random.key(0), (B, S, KV, G, D))
k = jax.random.normal(jax.random.key(1), (B, S, KV, D))
v = jax.random.normal(jax.random.key(2), (B, S, KV, D))
for causal in (True, False):
    want = ring_attention_ref(q, k, v, causal=causal)
    qd = jax.device_put(q, NamedSharding(mesh, P("data", "model", None, None, None)))
    kd = jax.device_put(k, NamedSharding(mesh, P("data", "model", None, None)))
    vd = jax.device_put(v, NamedSharding(mesh, P("data", "model", None, None)))
    got = jax.jit(lambda a, b, c: ring_attention(
        a, b, c, mesh=mesh, causal=causal))(qd, kd, vd)
    err = float(jnp.abs(got - want).max())
    assert err < 3e-5, (causal, err)
print("RING OK")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "RING OK" in out.stdout


def test_continuous_batcher_serves_all():
    import dataclasses

    import jax

    from repro.configs.base import get_config, reduce_config
    from repro.models import zoo
    from repro.serve.batching import ContinuousBatcher

    cfg = reduce_config(get_config("smollm-135m"))
    params = zoo.init_model(cfg, jax.random.key(0))
    b = ContinuousBatcher(cfg, params, slots=3, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [b.submit(rng.integers(0, cfg.vocab, int(rng.integers(4, 12)))
                     .astype(np.int32), max_new=int(rng.integers(3, 8)))
            for _ in range(7)]
    stats = b.run_until_drained()
    assert stats["requests"] == 7
    for r in b.finished:
        assert len(r.out_tokens) >= r.max_new
        assert r.first_token_at is not None and r.done_at is not None
    # waves of 3 slots: at least ceil(7/3)=3 admission waves happened
    assert stats["ticks"] > 3
