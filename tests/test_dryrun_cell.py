"""Deliverable (e) in CI: one full dry-run cell — lower + compile on the
512-host-device production mesh — in a subprocess (slow: ~1 min)."""
import os
import pathlib
import subprocess
import sys

import pytest

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


@pytest.mark.parametrize("arch,shape", [("smollm-135m", "train_4k")])
def test_dryrun_cell_compiles(arch, shape, tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.configs.base import get_config, get_shape
from repro.distributed.ctx import TRAIN_RULES_1POD
from repro.launch.dryrun import run_cell
from repro.launch.mesh import make_production_mesh

mesh = make_production_mesh()
row = run_cell("{arch}", "{shape}", mesh, "16x16", TRAIN_RULES_1POD)
assert row["status"] == "ok", row.get("error")
assert row["fits_hbm"], row["memory"]
assert row["roofline"]["hlo_flops"] > 1e14
assert row["collectives"]["total_bytes"] > 0
print("CELL OK", row["roofline"]["dominant"])
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "CELL OK" in out.stdout
