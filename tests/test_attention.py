"""Model-level attention: chunked == naive, cache paths, MLA absorbed decode."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_config, reduce_config
from repro.models.attention import (attention_apply, grouped_attention,
                                    init_attention, init_mla_attention,
                                    mla_apply)


def _qkv(key, B, S, KV, G, D, Skv=None):
    Skv = Skv or S
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, KV, G, D))
    k = jax.random.normal(ks[1], (B, Skv, KV, D))
    v = jax.random.normal(ks[2], (B, Skv, KV, D))
    return q, k, v


@settings(max_examples=12, deadline=None)
@given(st.sampled_from([64, 128]), st.sampled_from([1, 2]),
       st.sampled_from([1, 3]), st.booleans(),
       st.sampled_from([16, 32, 64]))
def test_chunked_equals_naive(S, KV, G, causal, chunk):
    q, k, v = _qkv(jax.random.key(0), 2, S, KV, G, 16)
    pos = jnp.arange(S)
    a = grouped_attention(q, k, v, causal=causal, q_pos=pos, kv_pos=pos,
                          impl="naive")
    b = grouped_attention(q, k, v, causal=causal, q_pos=pos, kv_pos=pos,
                          impl="chunked", q_chunk=chunk, kv_chunk=chunk)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


def test_kv_len_masking():
    S = 32
    q, k, v = _qkv(jax.random.key(1), 1, 1, 2, 2, 16, Skv=S)
    pos = jnp.asarray([10])
    kv_pos = jnp.arange(S)
    out_full = grouped_attention(q, k, v, causal=False, q_pos=pos,
                                 kv_pos=kv_pos, impl="naive", kv_len=11)
    # zeroing cache beyond kv_len must not change the output
    k2 = k.at[:, 11:].set(99.0)
    v2 = v.at[:, 11:].set(-99.0)
    out_masked = grouped_attention(q, k2, v2, causal=False, q_pos=pos,
                                   kv_pos=kv_pos, impl="naive", kv_len=11)
    np.testing.assert_allclose(np.asarray(out_full), np.asarray(out_masked),
                               atol=1e-6)


def _gqa_cfg():
    return reduce_config(get_config("qwen2-72b"))


class TestCachePaths:
    def test_prefill_then_decode_matches_full(self):
        """Prefill S tokens into a cache, decode one more: logits must match
        attention over the full S+1 sequence."""
        cfg = _gqa_cfg()
        p = init_attention(jax.random.key(0), cfg)
        B, S = 2, 16
        x_full = jax.random.normal(jax.random.key(1), (B, S + 1, cfg.d_model),
                                   jnp.float32)
        # full pass
        full, _ = attention_apply(p, x_full, cfg, causal=True)
        # prefill + decode
        cache = {
            "k": jnp.zeros((B, S + 1, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
            "v": jnp.zeros((B, S + 1, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
        }
        _, cache = attention_apply(p, x_full[:, :S], cfg, causal=True,
                                   kv_cache=cache, cache_index=jnp.int32(0),
                                   cache_len=jnp.int32(S))
        out_1, _ = attention_apply(p, x_full[:, S:], cfg, causal=False,
                                   kv_cache=cache, cache_index=jnp.int32(S),
                                   cache_len=jnp.int32(S + 1))
        np.testing.assert_allclose(np.asarray(out_1, np.float32),
                                   np.asarray(full[:, S:], np.float32),
                                   atol=3e-2)

    def test_kv_repeat_equivalence(self):
        """kv_repeat must not change attention outputs."""
        cfg = _gqa_cfg()
        p = init_attention(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(2), (2, 8, cfg.d_model))
        base, _ = attention_apply(p, x, cfg, causal=True)
        cfg2 = dataclasses.replace(cfg, kv_repeat=2)
        rep, _ = attention_apply(p, x, cfg2, causal=True)
        np.testing.assert_allclose(np.asarray(base, np.float32),
                                   np.asarray(rep, np.float32), atol=2e-2)


class TestMLA:
    def test_absorbed_decode_matches_full(self):
        cfg = reduce_config(get_config("deepseek-v2-lite-16b"))
        p = init_mla_attention(jax.random.key(0), cfg)
        B, S = 2, 12
        x = jax.random.normal(jax.random.key(1), (B, S + 1, cfg.d_model))
        full, _ = mla_apply(p, x, cfg, causal=True)
        m = cfg.mla
        cache = {
            "c_kv": jnp.zeros((B, S + 1, m.kv_lora_rank), jnp.bfloat16),
            "k_rope": jnp.zeros((B, S + 1, m.qk_rope_head_dim), jnp.bfloat16),
        }
        _, cache = mla_apply(p, x[:, :S], cfg, causal=True, kv_cache=cache,
                             cache_index=jnp.int32(0), cache_len=jnp.int32(S))
        out1, _ = mla_apply(p, x[:, S:], cfg, causal=False, kv_cache=cache,
                            cache_index=jnp.int32(S), cache_len=jnp.int32(S + 1))
        np.testing.assert_allclose(np.asarray(out1, np.float32),
                                   np.asarray(full[:, S:], np.float32),
                                   atol=4e-2)


class TestMLAQuantCache:
    def test_int8_latent_matches_bf16(self):
        """int8-quantized latent cache decode tracks the bf16 path."""
        import dataclasses

        cfg = reduce_config(get_config("deepseek-v2-lite-16b"))
        p = init_mla_attention(jax.random.key(0), cfg)
        B, S = 2, 12
        x = jax.random.normal(jax.random.key(1), (B, S + 1, cfg.d_model))
        m = cfg.mla

        def run(quant):
            cache = {"c_kv": jnp.zeros((B, S + 1, m.kv_lora_rank), jnp.bfloat16),
                     "k_rope": jnp.zeros((B, S + 1, m.qk_rope_head_dim),
                                         jnp.bfloat16)}
            if quant:
                cache["c_kv"] = jnp.zeros((B, S + 1, m.kv_lora_rank), jnp.int8)
                cache["c_kv_scale"] = jnp.zeros((B, S + 1), jnp.bfloat16)
            _, cache = mla_apply(p, x[:, :S], cfg, causal=True, kv_cache=cache,
                                 cache_index=jnp.int32(0), cache_len=jnp.int32(S))
            out, _ = mla_apply(p, x[:, S:], cfg, causal=False, kv_cache=cache,
                               cache_index=jnp.int32(S),
                               cache_len=jnp.int32(S + 1))
            return np.asarray(out, np.float32)

        a, b = run(False), run(True)
        rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
        assert rel < 0.05, rel


class TestGQAQuantCache:
    def test_int8_kv_matches_bf16(self):
        cfg = _gqa_cfg()
        p = init_attention(jax.random.key(0), cfg)
        B, S = 2, 16
        x = jax.random.normal(jax.random.key(2), (B, S + 1, cfg.d_model))

        def run(quant):
            kv = cfg.n_kv_heads
            cache = {"k": jnp.zeros((B, S + 1, kv, cfg.head_dim), jnp.bfloat16),
                     "v": jnp.zeros((B, S + 1, kv, cfg.head_dim), jnp.bfloat16)}
            if quant:
                cache = {
                    "k": jnp.zeros((B, S + 1, kv, cfg.head_dim), jnp.int8),
                    "v": jnp.zeros((B, S + 1, kv, cfg.head_dim), jnp.int8),
                    "k_scale": jnp.zeros((B, S + 1, kv), jnp.bfloat16),
                    "v_scale": jnp.zeros((B, S + 1, kv), jnp.bfloat16),
                }
            _, cache = attention_apply(p, x[:, :S], cfg, causal=True,
                                       kv_cache=cache, cache_index=jnp.int32(0),
                                       cache_len=jnp.int32(S))
            out, _ = attention_apply(p, x[:, S:], cfg, causal=False,
                                     kv_cache=cache, cache_index=jnp.int32(S),
                                     cache_len=jnp.int32(S + 1))
            return np.asarray(out, np.float32)

        a, b = run(False), run(True)
        rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
        assert rel < 0.05, rel
