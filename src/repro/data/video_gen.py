"""Procedural Visual-Road-style video generator.

Produces (frames [T, H, W] float32 luma in [0,255], detections) with exact
ground-truth bounding boxes.  Object classes, counts and sizes are seeded and
configurable, so the paper's sparse (<20% frame coverage) and dense (>=20%)
regimes are reproducible on CPU at any resolution.  A panning camera and
textured background keep the codec honest (residuals are non-trivial).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

BBox = tuple[int, int, int, int]  # (y1, x1, y2, x2), half-open


@dataclass
class ObjectSpec:
    label: str
    count: int
    size: tuple[int, int]          # (h, w) nominal
    speed: float = 2.0             # px/frame
    intensity: float = 200.0


@dataclass
class VideoSpec:
    height: int = 192
    width: int = 320
    n_frames: int = 256
    seed: int = 0
    camera_pan: float = 0.0        # background px/frame
    objects: list[ObjectSpec] = field(default_factory=lambda: [
        ObjectSpec("car", 3, (28, 44), 2.5, 210.0),
        ObjectSpec("person", 4, (30, 14), 1.2, 240.0),
    ])

    @property
    def shape(self):
        return (self.n_frames, self.height, self.width)


# Preset regimes used throughout the benchmarks (Table 1 analogues)
def sparse_spec(seed=0, n_frames=256, height=192, width=320) -> VideoSpec:
    return VideoSpec(height=height, width=width, n_frames=n_frames, seed=seed)


def dense_spec(seed=0, n_frames=256, height=192, width=320) -> VideoSpec:
    return VideoSpec(
        height=height, width=width, n_frames=n_frames, seed=seed,
        objects=[
            ObjectSpec("car", 6, (44, 72), 2.0, 210.0),
            ObjectSpec("person", 8, (48, 22), 1.5, 240.0),
            ObjectSpec("boat", 2, (52, 88), 1.0, 180.0),
        ])


def multiclass_spec(seed=0, n_frames=256, height=192, width=320) -> VideoSpec:
    spec = sparse_spec(seed, n_frames, height, width)
    spec.objects = spec.objects + [ObjectSpec("traffic_light", 1, (18, 8), 0.3, 250.0)]
    return spec


def generate(spec: VideoSpec):
    """Returns (frames [T,H,W] float32, detections: list per frame of
    (label, bbox))."""
    rng = np.random.default_rng(spec.seed)
    T, H, W = spec.n_frames, spec.height, spec.width

    # textured background, wide enough to pan over.  Noise is smoothed with a
    # separable box blur: real video backgrounds are spatially correlated —
    # white noise would be uncodeable and sink PSNR for any codec.
    pan_total = int(abs(spec.camera_pan) * T) + W + 8
    noise = rng.normal(0.0, 14.0, size=(H + 8, pan_total + 8))
    k = np.ones(9) / 9.0
    noise = np.apply_along_axis(lambda r: np.convolve(r, k, mode="same"), 1, noise)
    noise = np.apply_along_axis(lambda c: np.convolve(c, k, mode="same"), 0, noise)
    bg_base = 110.0 + 3.0 * noise[4:H + 4, 4:pan_total + 4]
    yy = np.linspace(0, 6 * np.pi, H)[:, None]
    xx = np.linspace(0, 6 * np.pi * pan_total / W, pan_total)[None, :]
    bg_base = bg_base + 25 * np.sin(yy) * np.cos(xx)
    bg_base = np.clip(bg_base, 0, 255).astype(np.float32)

    # object trajectories: linear with bounce, randomized phase
    objs = []
    for ospec in spec.objects:
        for i in range(ospec.count):
            h = max(8, int(ospec.size[0] * rng.uniform(0.8, 1.25)))
            w = max(8, int(ospec.size[1] * rng.uniform(0.8, 1.25)))
            y0 = rng.uniform(0, max(H - h, 1))
            x0 = rng.uniform(0, max(W - w, 1))
            ang = rng.uniform(0, 2 * np.pi)
            vy = ospec.speed * np.sin(ang)
            vx = ospec.speed * np.cos(ang)
            tex = rng.normal(ospec.intensity, 4.0, size=(h, w)).astype(np.float32)
            tex[::4] -= 12.0  # horizontal banding: structured, codeable texture
            tex = np.clip(tex, 0, 255)
            objs.append(dict(label=ospec.label, h=h, w=w, y=y0, x=x0,
                             vy=vy, vx=vx, tex=tex))

    frames = np.empty((T, H, W), dtype=np.float32)
    detections: list[list[tuple[str, BBox]]] = []
    for t in range(T):
        off = int(abs(spec.camera_pan) * t)
        frame = bg_base[:, off:off + W].copy()
        dets: list[tuple[str, BBox]] = []
        for o in objs:
            # integrate & bounce
            o["y"] += o["vy"]
            o["x"] += o["vx"]
            if o["y"] < 0 or o["y"] + o["h"] > H:
                o["vy"] = -o["vy"]
                o["y"] = np.clip(o["y"], 0, H - o["h"])
            if o["x"] < 0 or o["x"] + o["w"] > W:
                o["vx"] = -o["vx"]
                o["x"] = np.clip(o["x"], 0, W - o["w"])
            y, x = int(o["y"]), int(o["x"])
            frame[y:y + o["h"], x:x + o["w"]] = o["tex"]
            dets.append((o["label"], (y, x, y + o["h"], x + o["w"])))
        frames[t] = frame
        detections.append(dets)
    return frames, detections


def coverage(detections, height: int, width: int) -> float:
    """Mean fraction of frame area covered by objects (Table-1 statistic)."""
    fracs = []
    for dets in detections:
        m = np.zeros((height, width), dtype=bool)
        for _, (y1, x1, y2, x2) in dets:
            m[y1:y2, x1:x2] = True
        fracs.append(m.mean())
    return float(np.mean(fracs))
