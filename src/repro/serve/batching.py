"""Continuous batching scheduler (vLLM-style, simplified).

Requests arrive with different prompt lengths and token budgets; the
scheduler keeps a fixed number of slots, prefills new requests into free
slots, decodes all active slots in lock-step, and retires finished ones.
Each slot owns a region of the shared (layer-stacked) KV cache; position
bookkeeping is per-slot.  This is the serving loop a real deployment would
drive; `examples/continuous_batching.py` exercises it.

Simplifications vs production (documented): wave admission (all slots must
drain before the next wave — zoo.decode_step shares one cache index across
rows), greedy sampling, no prefix sharing.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import zoo


@dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [S0] int32
    max_new: int
    out_tokens: list = field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None


@dataclass
class _Slot:
    request: Optional[Request] = None
    pos: int = 0  # next cache index for this slot


class ContinuousBatcher:
    def __init__(self, cfg: ArchConfig, params, *, slots: int = 4,
                 max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.slots = [_Slot() for _ in range(slots)]
        self.caches = zoo.init_cache(cfg, slots, max_len)
        self.queue: list[Request] = []
        self.finished: list[Request] = []

        n = slots

        def decode(params, caches, tokens, positions):
            """One lock-step decode for all slots; per-slot positions."""
            logits, new_caches = zoo.decode_step(
                params, cfg, {"tokens": tokens}, caches,
                cache_index=positions.min())
            return jnp.argmax(logits[:, -1], axis=-1), new_caches

        # NOTE: per-slot cache_index requires per-slot dynamic_update_slice;
        # zoo.decode_step uses one index for the whole batch, so this batcher
        # keeps slots position-aligned by padding prompts to a common length
        # per admission wave (documented simplification).
        self._decode = jax.jit(decode, donate_argnums=(1,))

    # -------------------------------------------------------------- intake
    def submit(self, prompt: np.ndarray, max_new: int) -> Request:
        req = Request(len(self.queue) + len(self.finished), np.asarray(prompt),
                      max_new, submitted_at=time.perf_counter())
        self.queue.append(req)
        return req

    def _admit_wave(self):
        """Admit a wave of requests, padded to one prompt length.

        Admission requires ALL slots free: zoo.decode_step advances every
        cache row with one shared index, so slots must stay position-aligned.
        Early finishers idle their slot until the wave drains (iteration-level
        batching). True continuous admission needs per-slot cache indices
        (batched dynamic_update_slice) — future work, noted in DESIGN.md.
        """
        if any(s.request is not None for s in self.slots):
            return
        free = [s for s in self.slots if s.request is None]
        if not free or not self.queue:
            return
        wave = [self.queue.pop(0) for _ in range(min(len(free), len(self.queue)))]
        pad_to = max(len(r.prompt) for r in wave)
        toks = np.zeros((len(self.slots), pad_to), np.int32)
        active_rows = []
        for slot, req in zip(free, wave):
            slot.request = req
            slot.pos = pad_to
            row = self.slots.index(slot)
            toks[row, -len(req.prompt):] = req.prompt
            active_rows.append(row)
        logits, self.caches = zoo.decode_step(
            self.params, self.cfg, {"tokens": jnp.asarray(toks)},
            self.caches, cache_index=jnp.int32(0))
        first = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        now = time.perf_counter()
        for slot in free:
            if slot.request is None:
                continue
            slot.request.out_tokens.append(int(first[self.slots.index(slot)]))
            slot.request.first_token_at = now
        self._base_pos = pad_to

    # -------------------------------------------------------------- stepping
    def step(self) -> int:
        """One scheduler tick: admit, decode one token for active slots,
        retire finished.  Returns number of active slots."""
        self._admit_wave()
        active = [i for i, s in enumerate(self.slots) if s.request is not None]
        if not active:
            return 0
        toks = np.zeros((len(self.slots), 1), np.int32)
        for i in active:
            toks[i, 0] = self.slots[i].request.out_tokens[-1]
        pos = min(self.slots[i].pos for i in active)
        nxt, self.caches = self._decode(self.params, self.caches,
                                        jnp.asarray(toks), jnp.int32(pos))
        nxt = np.asarray(nxt)
        now = time.perf_counter()
        for i in active:
            slot = self.slots[i]
            slot.request.out_tokens.append(int(nxt[i]))
            slot.pos += 1
            done = (len(slot.request.out_tokens) >= slot.request.max_new
                    or slot.pos >= self.max_len - 1)
            if done:
                slot.request.done_at = now
                self.finished.append(slot.request)
                slot.request = None
                slot.pos = 0
        return len(active)

    def run_until_drained(self, max_ticks: int = 10_000) -> dict:
        t0 = time.perf_counter()
        ticks = tokens = 0
        while (self.queue or any(s.request for s in self.slots)) \
                and ticks < max_ticks:
            tokens += self.step()
            ticks += 1
        dt = time.perf_counter() - t0
        lat = [r.done_at - r.submitted_at for r in self.finished if r.done_at]
        ttft = [r.first_token_at - r.submitted_at for r in self.finished
                if r.first_token_at]
        return {
            "requests": len(self.finished),
            "ticks": ticks,
            "tokens": tokens,
            "tok_per_s": tokens / dt if dt else 0.0,
            "mean_latency_s": float(np.mean(lat)) if lat else 0.0,
            "mean_ttft_s": float(np.mean(ttft)) if ttft else 0.0,
        }
