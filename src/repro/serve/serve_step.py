"""Serving steps: prefill (fill KV caches for a full prompt, return last-token
logits) and decode (one token against the cache).

Both lower through the same zoo.decode_step machinery — prefill is simply the
S=prompt_len case with cache_index=0, which writes all S cache rows in one
dynamic_update_slice and runs the chunked causal attention path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import zoo


def make_prefill_step(cfg: ArchConfig, max_len: int):
    """prefill(params, batch) -> (last_logits [B,1,V], caches)."""

    def prefill(params, batch):
        B = batch["tokens"].shape[0]
        caches = zoo.init_cache(cfg, B, max_len)
        enc_out = None
        if cfg.is_encdec:
            enc_out = zoo.encode_frames(params, cfg, batch["frames"])
        logits, caches = zoo.decode_step(params, cfg, batch, caches,
                                         cache_index=jnp.int32(0),
                                         enc_out=enc_out)
        return logits, caches

    return prefill


def make_decode_step(cfg: ArchConfig):
    """decode(params, caches, batch, index) -> (logits [B,1,V], caches)."""

    def decode(params, caches, batch, index):
        return zoo.decode_step(params, cfg, batch, caches, cache_index=index)

    return decode


def greedy_generate(params, cfg: ArchConfig, prompt: jnp.ndarray, *,
                    max_new: int, max_len: Optional[int] = None,
                    enc_out=None):
    """Host-loop greedy decoding for examples/tests (jitted per-step)."""
    B, S0 = prompt.shape
    max_len = max_len or (S0 + max_new)
    caches = zoo.init_cache(cfg, B, max_len)
    batch = {"tokens": prompt}
    if enc_out is not None:
        batch["enc_out"] = enc_out

    step = jax.jit(
        lambda p, b, c, i: zoo.decode_step(p, cfg, b, c, cache_index=i))
    logits, caches = step(params, batch, caches, jnp.int32(0))
    out = [jnp.argmax(logits[:, -1], axis=-1)]
    idx = S0
    for _ in range(max_new - 1):
        b = {"tokens": out[-1][:, None]}
        if enc_out is not None:
            b["enc_out"] = enc_out
        logits, caches = step(params, b, caches, jnp.int32(idx))
        out.append(jnp.argmax(logits[:, -1], axis=-1))
        idx += 1
    return jnp.stack(out, axis=1)
