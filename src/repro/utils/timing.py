"""Wall-clock timing helpers for benchmarks (CPU host measurements)."""
from __future__ import annotations

import time
from typing import Any, Callable

import jax


class Timer:
    """Context-manager timer: ``with Timer() as t: ...; t.seconds``."""

    def __enter__(self) -> "Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.seconds = time.perf_counter() - self._t0
        self.us = self.seconds * 1e6


def time_call(fn: Callable[[], Any], warmup: int = 1, iters: int = 3) -> float:
    """Median wall-clock microseconds per call; blocks on JAX outputs."""

    def run() -> float:
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) * 1e6

    for _ in range(warmup):
        run()
    times = sorted(run() for _ in range(iters))
    return times[len(times) // 2]
