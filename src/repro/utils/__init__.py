from repro.utils.tree import (
    tree_size_bytes,
    tree_param_count,
    tree_map_with_name,
    flatten_names,
)
from repro.utils.timing import Timer, time_call
