"""Pytree helpers used across the framework."""
from __future__ import annotations

from typing import Any, Callable

import jax
import numpy as np


def _path_to_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_map_with_name(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """Map ``fn(name, leaf)`` over a pytree, where name is the '/'-joined path."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: fn(_path_to_name(path), leaf), tree
    )


def flatten_names(tree: Any) -> list[tuple[str, Any]]:
    """Return [(name, leaf)] for every leaf in the tree."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(_path_to_name(path), leaf) for path, leaf in flat]


def tree_size_bytes(tree: Any) -> int:
    """Total bytes across all array leaves (works on ShapeDtypeStructs too)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    return total


def tree_param_count(tree: Any) -> int:
    """Total number of scalar parameters across all array leaves."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "shape"):
            total += int(np.prod(leaf.shape))
    return total
