"""Version compatibility shims for jax APIs the repo uses.

``shard_map`` graduated from ``jax.experimental.shard_map`` (kwarg
``check_rep``) to ``jax.shard_map`` (kwarg ``check_vma``).  The container
pins jax 0.4.37, which only has the experimental spelling; newer jax only
documents the graduated one.  Call sites use :func:`shard_map` below with
the new-style ``check_vma`` kwarg and run on either version.
"""
from __future__ import annotations

import jax

try:
    from jax import shard_map as _shard_map_new  # jax >= 0.5
    _HAS_NEW = True
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map_old
    _HAS_NEW = False


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    if _HAS_NEW:
        return _shard_map_new(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=check_vma)
    return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)
