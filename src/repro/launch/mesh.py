"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax use,
while smoke tests and benchmarks must keep seeing 1 device.

Topology: a TPU v5e pod is modelled as a 16x16 = 256-chip 2D slice with
(data, model) axes; the multi-pod mesh adds a leading 'pod' axis over DCN.
``pods`` generalises beyond 2 — nothing is hard-coded to the dry-run size.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, pods: int = 2):
    shape = (pods, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2, pods: int = 0):
    """Small mesh over host devices for tests (requires host-device flag)."""
    if pods:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


# Hardware constants for roofline terms (TPU v5e):
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (~per chip per direction)
HBM_PER_CHIP = 16e9           # bytes
