"""Serving launcher: batched prefill + decode driver.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --reduced --batch 8 --prompt-len 32 --max-new 32 [--mesh 2,2]

Uses the same serve_step the 512-chip dry-run lowers; on a mesh it applies
the TP serve shardings (KV-head replication / seq-sharded / int8 cache per
flags).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, make_serve_config, reduce_config
from repro.distributed import sharding as shd
from repro.distributed.ctx import SERVE_RULES_1POD, use_sharding
from repro.models import zoo
from repro.serve.serve_step import make_decode_step, make_prefill_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--mesh", default="", help="e.g. 2,2 for (data,model)")
    ap.add_argument("--kv-quant", action="store_true", help="int8 KV cache")
    ap.add_argument("--kv-shard", default="heads", choices=["heads", "seq"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    mesh = None
    model_axis = 1
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split(","))
        mesh = jax.make_mesh(dims, ("data", "model")[: len(dims)])
        model_axis = mesh.shape.get("model", 1)
    cfg = make_serve_config(cfg, model_axis)
    cfg = dataclasses.replace(cfg, kv_cache_quant=args.kv_quant,
                              kv_cache_shard=args.kv_shard)
    print(f"serving {cfg.name}: kv_repeat={cfg.kv_repeat} "
          f"quant={cfg.kv_cache_quant} shard={cfg.kv_cache_shard}")

    params = zoo.init_model(cfg, jax.random.key(0))
    max_len = args.prompt_len + args.max_new + 8
    caches = zoo.init_cache(cfg, args.batch, max_len)
    if mesh is not None:
        params = jax.device_put(
            params, shd.param_shardings(params, cfg, mesh, mode="serve"))
        caches = jax.device_put(caches, shd.cache_shardings(caches, cfg, mesh))

    prefill = make_prefill_step(cfg, max_len)
    decode = make_decode_step(cfg)

    def run():
        prompts = jax.random.randint(jax.random.key(1),
                                     (args.batch, args.prompt_len), 0, cfg.vocab)
        jp = jax.jit(lambda p, b: zoo.decode_step(
            p, cfg, b, caches, cache_index=jnp.int32(0)))
        jd = jax.jit(decode, donate_argnums=(1,))
        t0 = time.time()
        logits, c = jp(params, {"tokens": prompts})
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0
        tok = jnp.argmax(logits[:, -1:], -1)
        t0 = time.time()
        for i in range(args.max_new):
            logits, c = jd(params, c, {"tokens": tok},
                           jnp.int32(args.prompt_len + i))
            tok = jnp.argmax(logits[:, -1:], -1)
        jax.block_until_ready(logits)
        t_dec = time.time() - t0
        print(f"prefill {args.batch}x{args.prompt_len} in {t_prefill:.2f}s; "
              f"decode {args.max_new} steps: "
              f"{args.batch * args.max_new / t_dec:.0f} tok/s")

    if mesh is not None:
        with use_sharding(SERVE_RULES_1POD, mesh):
            run()
    else:
        run()


if __name__ == "__main__":
    main()
