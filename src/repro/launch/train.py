"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 100 --batch 8 --seq 128 [--reduced] [--mesh data,model] \
        [--checkpoint-dir ckpt] [--resume]

On a real TPU slice this runs under `jax.distributed.initialize()` (one
process per host); on CPU it runs single-device (use --reduced).  The loop is
the fault-tolerant one from repro/train/elastic.py: async checkpoints,
crash-restart, straggler-tolerant prefetch.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduce_config
from repro.distributed import sharding as shd
from repro.distributed.ctx import TRAIN_RULES_1POD, dp_rules, use_sharding
from repro.models import zoo
from repro.train.checkpoint import CheckpointManager
from repro.train.data import PrefetchPipeline, synthetic_token_batches
from repro.train.elastic import LoopConfig, recoverable_train_loop
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-size config (CPU)")
    ap.add_argument("--mesh", default="", help="e.g. 2,4 for (data,model)")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    print(f"arch={cfg.name} params={cfg.param_count() / 1e6:.1f}M "
          f"devices={jax.device_count()}")

    mesh = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split(","))
        mesh = jax.make_mesh(dims, ("data", "model")[: len(dims)])

    params = zoo.init_model(cfg, jax.random.key(0))
    opt = init_opt_state(params)
    if mesh is not None:
        mode = shd.choose_policy(cfg, mesh, "train")
        p_shard = shd.param_shardings(params, cfg, mesh, mode=mode)
        params = jax.device_put(params, p_shard)
        opt = jax.device_put(opt, {
            "m": p_shard, "v": p_shard, "master": p_shard,
            "step": jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec())}
            if "master" in opt else
            {"m": p_shard, "v": p_shard,
             "step": jax.sharding.NamedSharding(
                 mesh, jax.sharding.PartitionSpec())})
        rules = (dp_rules(tuple(mesh.axis_names)) if mode == "dp_train"
                 else TRAIN_RULES_1POD)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                          total_steps=args.steps)
    raw = make_train_step(cfg, opt_cfg, microbatches=args.microbatches)

    def jit_step():
        if mesh is None:
            return jax.jit(raw)
        return jax.jit(raw)

    step = jit_step()

    def step_fn(state, batch):
        params, opt = state
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if mesh is not None:
            batch = jax.device_put(batch, shd.batch_shardings(batch, mesh))
            with use_sharding(rules, mesh):
                params, opt, metrics = step(params, opt, batch)
        else:
            params, opt, metrics = step(params, opt, batch)
        return (params, opt), metrics

    pipe = PrefetchPipeline(
        synthetic_token_batches(cfg.vocab, args.batch, args.seq,
                                n_batches=args.steps * 2),
        depth=4, deadline_s=10.0)

    import tempfile

    ckdir = args.checkpoint_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    ckpt = CheckpointManager(ckdir, keep=2)
    state = (params, opt)
    start = 0
    if args.resume and ckpt.latest_step() is not None:
        state, extra = ckpt.restore(state)
        start = extra.get("step", 0)
        print(f"resumed from step {start}")

    def on_metrics(s, m):
        if s % 10 == 0 or s == args.steps:
            print(f"step {s:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m.get('grad_norm', 0)):.2f}", flush=True)

    state, steps, restarts = recoverable_train_loop(
        state, pipe, step_fn, ckpt=ckpt,
        cfg=LoopConfig(total_steps=args.steps,
                       checkpoint_every=args.checkpoint_every),
        start_step=start, on_metrics=on_metrics)
    print(f"done: {steps} steps, restarts={restarts}, checkpoints in {ckdir}")


if __name__ == "__main__":
    main()
