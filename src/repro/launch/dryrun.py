import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input-shape x mesh) cell this lowers + compiles the
real train/prefill/decode step against ShapeDtypeStruct stand-ins on the
production mesh (16x16 single-pod / 2x16x16 multi-pod), records
``memory_analysis()`` / ``cost_analysis()`` / the parsed collective schedule,
and appends a JSON row to ``results/dryrun/<mesh>.jsonl``.

The two XLA_FLAGS lines above MUST stay the first statements in this module:
jax locks the device count at first backend initialisation.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --mesh single --arch all
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi  --arch qwen2-72b \
        --shape train_4k
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
import numpy as np

from repro.configs.base import (ARCH_IDS, SHAPES, ArchConfig, ShapeSpec,
                                get_config, get_shape, make_serve_config)
from repro.distributed import sharding as shd
from repro.distributed.ctx import (SERVE_RULES, SERVE_RULES_1POD, TRAIN_RULES,
                                   TRAIN_RULES_1POD, use_sharding)
from repro.launch import analytic_cost as ac
from repro.launch import roofline as rl
from repro.launch.mesh import HBM_PER_CHIP, make_production_mesh
from repro.models import zoo
from repro.train.optimizer import init_opt_state
from repro.train.train_step import AdamWConfig, make_train_step

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


# --------------------------------------------------------------------------
# Memory-driven microbatch choice (napkin model, see DESIGN.md)
# --------------------------------------------------------------------------
def choose_microbatches(cfg: ArchConfig, shape: ShapeSpec, mesh) -> int:
    if shape.kind != "train":
        return 1
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    tp = mesh.shape.get("model", 1)
    b_loc = max(shape.global_batch // dp, 1)
    seq_fac = tp if shape.seq_len % tp == 0 else 1
    # residual carry per layer, sequence-sharded; 2 bytes bf16
    carry = b_loc * shape.seq_len * cfg.d_model * 2 / seq_fac
    total_layers = cfg.n_layers + cfg.enc_layers
    budget = 4e9  # leave room for params/opt/workspace out of 16 GB
    need = carry * total_layers / budget
    micro = 1
    while micro < need and micro < b_loc:
        micro *= 2
    return micro


# --------------------------------------------------------------------------
# Cell runners
# --------------------------------------------------------------------------
def _lower_train(cfg: ArchConfig, shape: ShapeSpec, mesh, rules):
    if "f32w" not in os.environ.get("REPRO_VARIANT", ""):
        # bf16 params + fp32 master in the optimizer (SS Perf): FSDP gathers
        # and gradient syncs move 2-byte elements
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    mode = shd.choose_policy(cfg, mesh, "train")
    if mode == "dp_train":
        from repro.distributed.ctx import dp_rules

        rules = dp_rules(tuple(mesh.axis_names))
    micro = choose_microbatches(cfg, shape, mesh)
    step = make_train_step(cfg, AdamWConfig(), microbatches=micro)
    params_s = jax.eval_shape(lambda: zoo.init_model(cfg, jax.random.key(0)))
    opt_s = jax.eval_shape(init_opt_state, params_s)
    batch_s = zoo.input_specs(cfg, shape)

    p_shard = shd.param_shardings(params_s, cfg, mesh, mode=mode)
    o_shard = {"m": p_shard, "v": p_shard,
               "step": NamedSharding(mesh, P())}
    if "master" in opt_s:
        o_shard["master"] = p_shard
    b_shard = shd.batch_shardings(batch_s, mesh, rules)

    with use_sharding(rules, mesh):
        jitted = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_s, opt_s, batch_s)
        flops = ac.count_flops(step, params_s, opt_s, batch_s)
    return lowered, {"microbatches": micro, "flops_global": flops,
                     "cache_bytes": 0.0, "policy": mode}


def _lower_prefill(cfg: ArchConfig, shape: ShapeSpec, mesh, rules):
    from repro.serve.serve_step import make_prefill_step

    scfg = make_serve_config(cfg, mesh.shape.get("model", 1))
    scfg = dataclasses.replace(
        scfg, q_chunk=max(scfg.q_chunk, shape.seq_len // 16),
        kv_chunk=max(scfg.kv_chunk, shape.seq_len // 32))
    step = make_prefill_step(scfg, shape.seq_len)
    params_s = jax.eval_shape(lambda: zoo.init_model(scfg, jax.random.key(0)))
    batch_s = zoo.input_specs(scfg, shape)
    p_shard = shd.param_shardings(params_s, scfg, mesh, mode="serve")
    b_shard = shd.batch_shardings(batch_s, mesh)
    caches_s = zoo.init_cache_specs(scfg, shape.global_batch, shape.seq_len)
    from repro.utils.tree import tree_size_bytes
    with use_sharding(rules, mesh):
        jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
        lowered = jitted.lower(params_s, batch_s)
        flops = ac.count_flops(step, params_s, batch_s)
    return lowered, {"kv_repeat": scfg.kv_repeat, "flops_global": flops,
                     "cache_bytes": float(tree_size_bytes(caches_s))}


def _lower_decode(cfg: ArchConfig, shape: ShapeSpec, mesh, rules):
    from repro.serve.serve_step import make_decode_step

    scfg = make_serve_config(cfg, mesh.shape.get("model", 1))
    variant = os.environ.get("REPRO_VARIANT", "")
    if "plainkv" not in variant:
        scfg = dataclasses.replace(
            scfg, **shd.choose_serve_cache_policy(scfg, mesh))
    step = make_decode_step(scfg)
    params_s = jax.eval_shape(lambda: zoo.init_model(scfg, jax.random.key(0)))
    batch_s = zoo.input_specs(scfg, shape)
    caches_s = zoo.init_cache_specs(scfg, shape.global_batch, shape.seq_len)
    idx_s = jax.ShapeDtypeStruct((), jnp.int32)
    p_shard = shd.param_shardings(params_s, scfg, mesh, mode="serve")
    b_shard = shd.batch_shardings(batch_s, mesh)
    c_shard = shd.cache_shardings(caches_s, scfg, mesh)
    i_shard = NamedSharding(mesh, P())
    from repro.utils.tree import tree_size_bytes
    with use_sharding(rules, mesh):
        jitted = jax.jit(step, in_shardings=(p_shard, c_shard, b_shard, i_shard),
                         donate_argnums=(1,))
        lowered = jitted.lower(params_s, caches_s, batch_s, idx_s)
        flops = ac.count_flops(step, params_s, caches_s, batch_s, idx_s)
    return lowered, {"kv_repeat": scfg.kv_repeat, "flops_global": flops,
                     "cache_bytes": float(tree_size_bytes(caches_s))}


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str, rules) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    row: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "chips": int(np.prod(list(mesh.shape.values())))}
    if not cfg.supports_shape(shape):
        row["status"] = "skipped"
        row["reason"] = "full-attention arch; long_500k needs sub-quadratic context"
        return row
    t0 = time.time()
    try:
        if shape.kind == "train":
            lowered, extra = _lower_train(cfg, shape, mesh, rules)
        elif shape.kind == "prefill":
            lowered, extra = _lower_prefill(cfg, shape, mesh, rules)
        else:
            lowered, extra = _lower_decode(cfg, shape, mesh, rules)
        row.update(extra)
        row["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        row["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        row["memory"] = _memory_dict(mem, row["chips"])
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # jax <= 0.4: one dict per program
            cost = cost[0] if cost else {}
        row["cost"] = {k: float(v) for k, v in cost.items()
                       if isinstance(v, (int, float)) and k in (
                           "flops", "bytes accessed", "transcendentals",
                           "utilization operand 0 {}")}
        hlo = compiled.as_text()
        coll = rl.collective_bytes_from_hlo(hlo)
        row["collectives"] = coll

        mode = shape.kind
        bytes_model = ac.hbm_bytes_per_chip(
            cfg, shape, mesh, mode=mode,
            microbatches=row.get("microbatches", 1),
            cache_bytes_total=row.get("cache_bytes", 0.0))
        row["hbm_model"] = bytes_model
        terms = rl.derive_terms(
            arch=arch, shape=shape_name, mesh_name=mesh_name,
            chips=row["chips"], flops_global=row["flops_global"],
            hbm_bytes_chip=bytes_model["total"], coll=coll,
            model_flops=rl.model_flops_estimate(cfg, shape),
            bytes_per_device=row["memory"].get("total_device_bytes", 0.0))
        row["roofline"] = terms.as_dict()
        fits = row["memory"].get("total_device_bytes", 0) <= HBM_PER_CHIP
        row["fits_hbm"] = bool(fits)
        row["status"] = "ok"
    except Exception as e:  # noqa: BLE001 - record the failure in the table
        row["status"] = "error"
        row["error"] = f"{type(e).__name__}: {e}"
        row["traceback"] = traceback.format_exc()[-4000:]
    return row


def _memory_dict(mem, chips: int) -> dict:
    """Per-device footprint.  On the host-platform backend ``argument_size``
    is per-device while ``temp_size`` aggregates across all participating
    devices (verified against analytic shard sizes), so temp is divided by
    the chip count."""
    out = {}
    for attr in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "temp_size_in_bytes",
                 "alias_size_in_bytes", "peak_memory_in_bytes"):
        if hasattr(mem, attr):
            try:
                out[attr] = float(getattr(mem, attr))
            except Exception:  # noqa: BLE001
                pass
    args = out.get("argument_size_in_bytes", 0.0)
    temp = out.get("temp_size_in_bytes", 0.0)
    outb = out.get("output_size_in_bytes", 0.0)
    alias = out.get("alias_size_in_bytes", 0.0)
    out["total_device_bytes"] = args + temp / max(chips, 1) + max(outb - alias, 0.0)
    return out


# --------------------------------------------------------------------------
# main
# --------------------------------------------------------------------------
def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    args = ap.parse_args()

    multi = args.mesh == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    mesh_name = "2x16x16" if multi else "16x16"
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out_path = RESULTS_DIR / f"{mesh_name.replace('x', '_')}.jsonl"
    done = set()
    if out_path.exists() and not args.force:
        for line in out_path.read_text().splitlines():
            try:
                r = json.loads(line)
                if r.get("status") in ("ok", "skipped"):
                    done.add((r["arch"], r["shape"]))
            except json.JSONDecodeError:
                pass

    n_ok = n_err = 0
    for arch in archs:
        for shape_name in shapes:
            if (arch, shape_name) in done and not args.force:
                print(f"[cached] {arch} x {shape_name}", flush=True)
                continue
            print(f"[run] {arch} x {shape_name} on {mesh_name}", flush=True)
            rules_train = TRAIN_RULES if multi else TRAIN_RULES_1POD
            rules_serve = SERVE_RULES if multi else SERVE_RULES_1POD
            shape = get_shape(shape_name)
            rules = rules_train if shape.kind == "train" else rules_serve
            row = run_cell(arch, shape_name, mesh, mesh_name, rules)
            with out_path.open("a") as f:
                row_out = {k: v for k, v in row.items() if k != "traceback"}
                f.write(json.dumps(row_out) + "\n")
            if row["status"] == "error":
                n_err += 1
                print(f"  ERROR: {row['error']}", flush=True)
                tb = row.get("traceback", "")
                if tb:
                    (RESULTS_DIR / f"err_{arch}_{shape_name}_{mesh_name}.txt"
                     ).write_text(tb)
            else:
                n_ok += 1
                if row["status"] == "ok":
                    r = row["roofline"]
                    print(f"  ok: dominant={r['dominant']} "
                          f"compute={r['compute_s']:.3e}s "
                          f"memory={r['memory_s']:.3e}s "
                          f"coll={r['collective_s']:.3e}s "
                          f"dev_bytes={row['memory'].get('total_device_bytes', 0)/1e9:.2f}GB "
                          f"(lower {row.get('lower_s')}s compile {row.get('compile_s')}s)",
                          flush=True)
                else:
                    print(f"  skipped: {row.get('reason')}", flush=True)
    print(f"DONE ok={n_ok} err={n_err}", flush=True)


if __name__ == "__main__":
    main()
