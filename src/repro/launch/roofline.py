"""Roofline-term extraction from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

FLOPs come from an exact jaxpr walk (``analytic_cost.count_flops``) because
XLA's HloCostAnalysis counts while-loop bodies once (verified in tests).
Collective bytes are parsed from the post-SPMD optimized HLO with a
computation-graph walk that multiplies while-loop bodies by their trip count.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass

import numpy as np

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# bytes-on-the-wire multiplier applied to the RESULT buffer size (ring model):
#   all-gather: result V -> each chip receives V*(n-1)/n ~ V
#   all-reduce: ~2V (reduce-scatter + all-gather phases)
#   reduce-scatter: result V (the scattered shard) -> wire ~ V*(n-1) global,
#     per-chip ~V*(n-1)/n*... we use operand-size when parseable, else V.
_MULT = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
         "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# computation headers have nested parens in the param list and no " = "
_COMP_HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\)[^/]*?condition=%?([\w\.\-]+)[^/]*?body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CALL_RE = re.compile(r"(?:fusion|call|custom-call)\(.*?(?:calls|to_apply)=%?([\w\.\-]+)")


def _shape_bytes(shape_str: str) -> int:
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def _parse_computations(hlo_text: str):
    """Split optimized HLO text into named computations with their lines."""
    comps: dict[str, list[str]] = {}
    current = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR_RE.match(line)
        if m and "{" in line and " = " not in line:
            current = m.group(1)
            comps[current] = []
            continue
        if current is not None:
            if line.strip() == "}":
                current = None
            else:
                comps[current].append(line)
    return comps


def _line_collective(line: str):
    """Returns (kind, result_bytes) if this line is a collective op."""
    for kind in _COLL_KINDS:
        token = f" {kind}(" if not kind.endswith("start") else None
        if f" {kind}(" in line or f" {kind}-start(" in line:
            # result shape is the first shape after '='
            eq = line.split("=", 1)
            if len(eq) != 2:
                return None
            m = _SHAPE_RE.search(eq[1])
            if not m:
                return None
            # tuple results: sum all shapes before the op name
            head = eq[1].split(kind)[0]
            total = sum(_shape_bytes(s.group(0)) for s in _SHAPE_RE.finditer(head))
            return kind, total
    return None


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Computation-graph walk: multiply while bodies by their trip count.

    Trip counts are recovered heuristically from the loop condition's
    comparison constant (validated against known-scan-length fixtures).
    """
    comps = _parse_computations(hlo_text)

    local: dict[str, dict[str, float]] = {}
    calls: dict[str, list[tuple[str, float]]] = {}
    for name, lines in comps.items():
        local[name] = {}
        calls[name] = []
        for line in lines:
            got = _line_collective(line)
            if got:
                kind, b = got
                local[name][kind] = local[name].get(kind, 0.0) + b * _MULT[kind]
                continue
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trip = 1.0
                for cl in comps.get(cond, []):
                    cm = _CONST_RE.search(cl)
                    if cm:
                        trip = max(trip, float(cm.group(1)))
                calls[name].append((body, trip))
                continue
            cm = _CALL_RE.search(line)
            if cm and cm.group(1) in comps:
                calls[name].append((cm.group(1), 1.0))

    memo: dict[str, dict[str, float]] = {}

    def total_of(comp: str, depth=0) -> dict[str, float]:
        if comp in memo:
            return memo[comp]
        if depth > 50:
            return {}
        out = dict(local.get(comp, {}))
        for child, mult in calls.get(comp, []):
            for k, v in total_of(child, depth + 1).items():
                out[k] = out.get(k, 0.0) + v * mult
        memo[comp] = out
        return out

    # entry computation: the one that is not called by anyone
    called = {c for lst in calls.values() for c, _ in lst}
    entries = [c for c in comps if c not in called]
    totals: dict[str, float] = {}
    counts: dict[str, int] = {}
    for e in entries:
        for k, v in total_of(e).items():
            totals[k] = totals.get(k, 0.0) + v
    for name, lines in comps.items():
        for line in lines:
            got = _line_collective(line)
            if got:
                counts[got[0]] = counts.get(got[0], 0) + 1
    return {"bytes_by_kind": totals, "count_by_kind": counts,
            "total_bytes": float(sum(totals.values()))}


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float          # global FLOPs for one step (jaxpr walk)
    hlo_bytes: float          # per-chip HBM traffic (analytic model)
    collective_bytes: float   # per-chip wire bytes
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float        # 6*N*D (or 6*N_active*D)
    useful_ratio: float       # model_flops / hlo_flops
    bytes_per_device: float   # per-device memory footprint (memory_analysis)

    def as_dict(self):
        return asdict(self)


def derive_terms(*, arch: str, shape: str, mesh_name: str, chips: int,
                 flops_global: float, hbm_bytes_chip: float, coll: dict,
                 model_flops: float, bytes_per_device: float) -> RooflineTerms:
    compute_s = flops_global / (chips * PEAK_FLOPS_BF16)
    memory_s = hbm_bytes_chip / HBM_BW
    collective_s = coll["total_bytes"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful = model_flops / flops_global if flops_global else 0.0
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops_global, hlo_bytes=hbm_bytes_chip,
        collective_bytes=coll["total_bytes"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops, useful_ratio=useful,
        bytes_per_device=bytes_per_device)


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE); decode D = B tokens."""
    n = cfg.active_param_count() if cfg.moe is not None else cfg.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens  # forward only
    return 2.0 * n * shape.global_batch  # decode: one token per sequence
