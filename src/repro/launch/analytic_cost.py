"""Exact FLOP counting by walking the jaxpr (scan-aware), plus an HBM-traffic
model for the roofline memory term.

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis counts a while
loop's body ONCE, not multiplied by its trip count (verified empirically —
see tests/test_roofline.py), so any lax.scan-over-layers model is undercounted
by ~L x.  The jaxpr walk below multiplies scan bodies by their static
``length``, recurses through pjit/remat/cond/shard_map, and counts
dot_general/conv FLOPs exactly (2*M*N*K convention).  Since the walk runs on
the *differentiated* step function's jaxpr, remat recompute is already
explicit and therefore included.
"""
from __future__ import annotations

from functools import reduce
from typing import Any

import jax
import jax.extend.core as jex_core
import numpy as np


def _prod(xs) -> float:
    out = 1.0
    for x in xs:
        out *= x
    return out


def _dot_flops(eqn) -> float:
    ((lc, rc), (lb, rb)) = eqn.params["dimension_numbers"]
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    batch = _prod([a.shape[i] for i in lb])
    contract = _prod([a.shape[i] for i in lc])
    m = _prod([a.shape[i] for i in range(len(a.shape)) if i not in lc and i not in lb])
    n = _prod([b.shape[i] for i in range(len(b.shape)) if i not in rc and i not in rb])
    return 2.0 * batch * contract * m * n


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    # flops = 2 * out_elems * (kernel_spatial * in_channels)
    kernel = _prod(rhs.shape[:-1])  # conservative
    return 2.0 * _prod(out.shape) * kernel


_SUBJAXPR_PARAMS = ("jaxpr", "call_jaxpr", "body_jaxpr", "cond_jaxpr", "branches")


def jaxpr_flops(jaxpr, mult: float = 1.0) -> float:
    total = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            total += _dot_flops(eqn) * mult
        elif name in ("conv_general_dilated",):
            total += _conv_flops(eqn) * mult
        elif name == "scan":
            inner = eqn.params["jaxpr"]
            length = eqn.params["length"]
            total += jaxpr_flops(inner.jaxpr, mult * length)
        elif name == "while":
            # our code never uses unbounded while; count body once
            total += jaxpr_flops(eqn.params["body_jaxpr"].jaxpr, mult)
        elif name == "cond":
            branches = eqn.params["branches"]
            total += max(jaxpr_flops(b.jaxpr, mult) for b in branches)
        elif name == "shard_map":
            mesh = eqn.params.get("mesh")
            n = _prod(list(mesh.shape.values())) if mesh is not None else 1.0
            total += jaxpr_flops(eqn.params["jaxpr"], mult * n)
        else:
            for pname in ("jaxpr", "call_jaxpr"):
                sub = eqn.params.get(pname)
                if sub is not None:
                    inner = sub.jaxpr if hasattr(sub, 'jaxpr') else sub
                    total += jaxpr_flops(inner, mult)
    return total


def count_flops(fn, *args) -> float:
    """Global FLOPs of fn(*args) (args may be ShapeDtypeStructs)."""
    closed = jax.make_jaxpr(fn)(*args)
    return jaxpr_flops(closed.jaxpr)


# ==========================================================================
# HBM traffic model (per chip, per step)
# ==========================================================================
def hbm_bytes_per_chip(cfg, shape, mesh, *, mode: str, microbatches: int = 1,
                       param_count: int | None = None,
                       cache_bytes_total: float = 0.0) -> dict:
    """Structured napkin model of per-chip HBM traffic for one step.

    Counted flows (bf16 compute stream assumed):
    - weight streaming: every chip reads its TP shard of every weight once
      per (micro)batch pass; backward reads them again.
    - optimizer: fp32 param/m/v read + write on the FSDP shard (train only).
    - activations: residual-stream read+write at every layer boundary
      (sequence-sharded where applicable) times remat's extra forward.
    - attention score streaming for train/prefill (chunked online softmax:
      q,k,v read + out write per kv-chunk sweep — scores never hit HBM).
    - KV cache read (decode) / write (prefill).
    """
    chips = float(np.prod(list(mesh.shape.values())))
    tp = float(mesh.shape.get("model", 1))
    dp = chips / tp
    n = float(param_count if param_count is not None else cfg.param_count())
    B, S = shape.global_batch, shape.seq_len
    b_loc = max(B / dp, 1.0)
    L = cfg.n_layers + cfg.enc_layers
    d = cfg.d_model
    seq_fac = tp if S % tp == 0 else 1.0

    flows: dict[str, float] = {}
    w_shard = n * 2.0 / tp  # bf16 weights per chip after FSDP gather
    if mode == "train":
        # fwd + bwd weight reads, (1 + remat extra fwd) per microbatch
        flows["weights"] = w_shard * 3.0 * microbatches
        flows["optimizer"] = (n / chips) * 4.0 * (3 + 3)  # rw p,m,v fp32 (FSDP shard)
        flows["grads"] = (n / chips) * 4.0 * 2.0
        act = b_loc * S * d * 2.0 / seq_fac
        flows["activations"] = act * L * 2.0 * 2.0  # rw x (fwd + recompute)
        if not cfg.is_attention_free and cfg.n_heads:
            kv_bytes = b_loc * S * cfg.n_kv_heads * cfg.head_dim * 2.0 / tp
            sweeps = max(S / max(cfg.kv_chunk, 1), 1.0) / 2.0  # causal skip
            flows["attention_kv_stream"] = kv_bytes * sweeps * L * 3.0  # fwd+bwd
    elif mode == "prefill":
        flows["weights"] = w_shard
        act = b_loc * S * d * 2.0 / seq_fac
        flows["activations"] = act * L * 2.0
        flows["kv_cache_write"] = cache_bytes_total / chips
        if not cfg.is_attention_free and cfg.n_heads:
            kv_bytes = b_loc * S * cfg.n_kv_heads * cfg.head_dim * 2.0 / tp
            sweeps = max(S / max(cfg.kv_chunk, 1), 1.0) / 2.0
            flows["attention_kv_stream"] = kv_bytes * sweeps * L
    else:  # decode
        flows["weights"] = w_shard
        flows["kv_cache_read"] = cache_bytes_total / chips
        flows["activations"] = b_loc * d * 2.0 * L * 2.0
    flows["total"] = float(sum(flows.values()))
    return flows
