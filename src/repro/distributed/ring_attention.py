"""Ring attention (shard_map): sequence-parallel exact attention for the
collective-bound prefill cells (§Roofline future-work item, implemented).

Q, K, V are sequence-sharded over the TP axis.  Each step computes local
attention against the currently-held KV block while `jax.lax.ppermute`
rotates KV around the ring; online-softmax statistics merge the blocks.
Per-chip wire bytes = (n-1)/n * |KV| — the same volume a single all-gather
of KV would move — but peak memory never holds the full KV, and on real
hardware each hop overlaps with the local block's compute (the point of
Ring Attention; our dry-run scores the wire bytes, the overlap is a latency
property).

Causal masking works on absolute positions carried with each block, so the
math is exact for causal prefill, at the cost of idle hops for fully-masked
blocks (the load-imbalance fix of striped/zigzag variants is noted as
future work).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils.jax_compat import shard_map

NEG_INF = -1e30


def _local_block(q, k, v, q_pos, kv_pos, causal, scale):
    """q: [B,Sq,KV,G,D]; k,v: [B,Skv,KV,D] -> (scores-weighted acc, m, l)."""
    s = jnp.einsum("bqkgd,bpkd->bkgqp", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = kv_pos[None, :] <= q_pos[:, None]
        s = jnp.where(mask, s, NEG_INF)
    m = s.max(axis=-1)  # [B,KV,G,Sq]
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    acc = jnp.einsum("bkgqp,bpkd->bqkgd", p, v.astype(jnp.float32))
    return acc, m, l


def ring_attention(q, k, v, *, mesh: Mesh, axis: str = "model",
                   causal: bool = True, dp_axes=("data",)):
    """q: [B, S, KV, G, D]; k, v: [B, S, KV, D]; S sharded over `axis`.

    Returns [B, S, KV, G, D] with the same sharding as q.
    """
    n = mesh.shape[axis]
    B, S, KVH, G, D = q.shape
    scale = 1.0 / np.sqrt(D)
    dp = tuple(a for a in dp_axes if a in mesh.axis_names) or None

    q_spec = P(dp, axis, None, None, None)
    kv_spec = P(dp, axis, None, None)

    def ring(ql, kl, vl):
        idx = jax.lax.axis_index(axis)
        s_loc = ql.shape[1]
        q_pos = idx * s_loc + jnp.arange(s_loc)

        m0 = jnp.full((B and ql.shape[0], KVH, G, s_loc), NEG_INF, jnp.float32)
        l0 = jnp.zeros_like(m0)
        a0 = jnp.zeros(ql.shape[:1] + (s_loc, KVH, G, D), jnp.float32)

        def body(i, carry):
            m, l, acc, kb, vb = carry
            src = (idx - i) % n  # whose KV block we currently hold
            kv_pos = src * s_loc + jnp.arange(s_loc)
            a_i, m_i, l_i = _local_block(ql, kb, vb, q_pos, kv_pos, causal,
                                         scale)
            m_new = jnp.maximum(m, m_i)
            alpha = jnp.exp(m - m_new)
            beta = jnp.exp(m_i - m_new)
            l = l * alpha + l_i * beta
            acc = (acc * alpha.transpose(0, 3, 1, 2)[..., None]
                   + a_i * beta.transpose(0, 3, 1, 2)[..., None])
            # rotate KV one hop around the ring
            perm = [(j, (j + 1) % n) for j in range(n)]
            kb = jax.lax.ppermute(kb, axis, perm)
            vb = jax.lax.ppermute(vb, axis, perm)
            return m_new, l, acc, kb, vb

        m, l, acc, _, _ = jax.lax.fori_loop(0, n, body, (m0, l0, a0, kl, vl))
        l = jnp.maximum(l, 1e-30)
        out = acc / l.transpose(0, 3, 1, 2)[..., None]
        return out.astype(q.dtype)

    fn = shard_map(ring, mesh=mesh, in_specs=(q_spec, kv_spec, kv_spec),
                   out_specs=q_spec, check_vma=False)
    return fn(q, k, v)


def ring_attention_ref(q, k, v, *, causal: bool = True):
    """Single-device oracle (same math as models.attention naive path)."""
    B, S, KVH, G, D = q.shape
    pos = jnp.arange(S)
    acc, m, l = _local_block(q, k, v, pos, pos, causal, 1.0 / np.sqrt(D))
    l = jnp.maximum(l, 1e-30)
    return (acc / l.transpose(0, 3, 1, 2)[..., None]).astype(q.dtype)
