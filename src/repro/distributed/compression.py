"""Int8 error-feedback gradient compression for the DP all-reduce.

At 1000+-node scale the data-parallel gradient all-reduce crosses DCN between
pods; quantizing the payload to int8 with per-tensor scales cuts wire bytes
4x vs fp32 (2x vs bf16).  The quantization residual is fed back into the next
step's gradient (error feedback, 1-bit-Adam-style), which keeps SGD/Adam
convergence — demonstrated in tests/test_compression.py on a host mesh.

Usage inside a shard_map'd grad-sync (pure-DP mode):

    g_sync, new_residual = compressed_psum(grad, residual, axis_name="data")
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils.jax_compat import shard_map


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(grad: jnp.ndarray, residual: jnp.ndarray, *,
                    axis_name: str):
    """Error-feedback int8 psum of one gradient tensor (inside shard_map).

    Returns (synced mean gradient fp32, new residual)."""
    g = grad.astype(jnp.float32) + residual
    q, scale = quantize_int8(g)
    sent = dequantize_int8(q, scale)
    new_residual = g - sent
    # int8 payload summed in int32 to avoid overflow across the axis; the
    # scale is tiny and psum'd alongside (per-shard scales -> exact mean of
    # the dequantized payloads).
    total = jax.lax.psum(sent, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return total / n, new_residual


def tree_compressed_psum(grads, residuals, *, axis_name: str):
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out_g, out_r = [], []
    for g, r in zip(flat_g, flat_r):
        sg, nr = compressed_psum(g, r, axis_name=axis_name)
        out_g.append(sg.astype(g.dtype))
        out_r.append(nr)
    return jax.tree.unflatten(treedef, out_g), jax.tree.unflatten(treedef, out_r)


def make_dp_compressed_grad_fn(loss_fn, mesh, *, axis_name: str = "data"):
    """Wrap a per-shard loss into a shard_map'd compressed-gradient fn.

    loss_fn(params, batch_shard) -> scalar.  Params replicated over the mesh;
    batch sharded on axis 0.  Returns grad_fn(params, batch, residuals) ->
    (loss_mean, grads, new_residuals).
    """
    from jax.sharding import PartitionSpec as P

    def local(params, batch, residuals):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, residuals = tree_compressed_psum(grads, residuals,
                                                axis_name=axis_name)
        loss = jax.lax.pmean(loss, axis_name)
        return loss, grads, residuals

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(axis_name), P()),
        out_specs=(P(), P(), P()),
        check_vma=False)
