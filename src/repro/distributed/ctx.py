"""Logical-axis sharding context.

Model code annotates intermediates with *logical* axes, e.g.
``constrain(x, "batch", "seq", "model_dim")``.  A :class:`ShardingRules`
installed via ``use_sharding(rules, mesh)`` maps logical axes to mesh axes and
applies ``jax.lax.with_sharding_constraint``.  When no context is installed
(unit tests, single-device smoke runs) the calls are no-ops, so model code is
identical on 1 CPU device and on the 512-chip production mesh.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


@dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> mesh axis (str, tuple of str, or None)."""

    rules: dict = field(default_factory=dict)

    def spec(self, *logical_axes: Optional[str]) -> P:
        return P(*[self.rules.get(a) if a is not None else None for a in logical_axes])


# Default logical->mesh mapping for the production mesh (pod, data, model).
# 'batch' shards over the full data-parallel product; 'model'-ish axes over TP.
TRAIN_RULES = ShardingRules(rules={
    "batch": ("pod", "data"),
    "seq": None,
    "model_dim": None,
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "d_state": None,
    "fsdp": "data",  # parameter sharding axis (ZeRO-3)
    "seq_shard": "model",  # sequence parallelism (long-context decode)
})

# Serving: no FSDP (params TP-only), batch over data.
SERVE_RULES = ShardingRules(rules={**TRAIN_RULES.rules, "fsdp": None})

# Single-pod variants (no 'pod' axis in the mesh).
TRAIN_RULES_1POD = ShardingRules(rules={**TRAIN_RULES.rules, "batch": "data"})
SERVE_RULES_1POD = ShardingRules(rules={**SERVE_RULES.rules, "batch": "data"})

# Pure-DP policy for small models (TP=1): batch and FSDP span BOTH mesh
# axes; no tensor sharding, so the only collectives are FSDP param gathers
# and gradient reduce-scatters.  Selected per-arch (see sharding.choose_policy).
def dp_rules(mesh_axes: tuple) -> ShardingRules:
    dp = tuple(a for a in ("pod", "data", "model") if a in mesh_axes)
    return ShardingRules(rules={
        "batch": dp, "seq": None, "model_dim": None, "heads": None,
        "kv_heads": None, "ff": None, "vocab": None, "experts": None,
        "d_state": None, "fsdp": dp, "seq_shard": None,
    })


def _variant() -> str:
    import os

    return os.environ.get("REPRO_VARIANT", "baseline")


def current_rules() -> Optional[ShardingRules]:
    rules = getattr(_state, "rules", None)
    if rules is not None and _variant() == "nosp" and             rules.rules.get("seq_shard") is not None:
        rules = ShardingRules(rules={**rules.rules, "seq_shard": None})
    return rules


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_sharding(rules: ShardingRules, mesh: Optional[Mesh] = None):
    prev_r = getattr(_state, "rules", None)
    prev_m = getattr(_state, "mesh", None)
    _state.rules, _state.mesh = rules, mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = prev_r, prev_m


def _axis_size(mesh: Mesh, ax) -> int:
    if isinstance(ax, tuple):
        size = 1
        for a in ax:
            size *= mesh.shape[a]
        return size
    return mesh.shape[ax]


def constrain(x, *logical_axes: Optional[str]):
    """Apply a sharding constraint if a context is installed; else identity.

    Logical axes whose mesh size does not divide the array dim are dropped
    (replicated) — this lets one call site serve e.g. both 32k prefill
    (sequence-shardable) and single-token decode.
    """
    rules = current_rules()
    if rules is None:
        return x
    mesh = current_mesh()
    spec = rules.spec(*logical_axes)
    if mesh is not None:
        axes = []
        for dim, ax in zip(x.shape, spec):
            if ax is not None and dim % _axis_size(mesh, ax) != 0:
                ax = None
            axes.append(ax)
        spec = P(*axes)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)


def logical_spec(*logical_axes: Optional[str]) -> P:
    rules = current_rules()
    if rules is None:
        return P(*[None] * len(logical_axes))
    return rules.spec(*logical_axes)
