"""Parameter / cache / input PartitionSpec assignment.

Params are matched by their tree-path name against a rule table.  Two modes:

- ``train``: FSDP (ZeRO-3) over 'data' + TP over 'model'.  Every large matrix
  is sharded on both axes; optimizer state inherits the same specs.
- ``serve``: TP over 'model' only (params replicated over 'data' so decode
  never all-gathers weights across the batch axis).

Stacked-layer params ([L, ...]) get a leading None.  Dims that do not divide
the mesh axis fall back to None (replicated) — e.g. smollm's 9 attention
heads on a 16-way model axis.
"""
from __future__ import annotations

import re
from typing import Optional

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.utils.tree import tree_map_with_name

# (regex on param path, spec WITHOUT the stacked-layer axis)
# 'F' = fsdp axis placeholder, 'M' = model/tensor axis placeholder.
_RULES: list[tuple[str, tuple]] = [
    (r"embed/table$", ("M", "F")),
    (r"lm_head/w$", ("F", "M")),
    (r"projector/fc\d/w$", ("F", "M")),
    (r"projector/fc\d/b$", ("M",)),
    # attention
    (r"attn/w[qkv]/w$", ("F", "M")),
    (r"attn/w[qkv]/b$", ("M",)),
    (r"attn/wo/w$", ("M", "F")),
    (r"attn/wq_[ab]/w$", ("F", "M")),
    (r"attn/wkv_a/w$", ("F", None)),
    (r"attn/wkv_b/w$", (None, "M")),
    (r"cross/w[qkv]/w$", ("F", "M")),
    (r"cross/wo/w$", ("M", "F")),
    # mlp
    (r"mlp/(gate|up)/w$", ("F", "M")),
    (r"mlp/down/w$", ("M", "F")),
    (r"shared/(gate|up)/w$", ("F", "M")),
    (r"shared/down/w$", ("M", "F")),
    # moe (experts sharded over model; replicated router)
    (r"moe/router/w$", (None, None)),
    (r"moe/w_(gate|up)$", ("M", "F", None)),
    (r"moe/w_down$", ("M", None, "F")),
    # mamba1
    (r"mamba/in_proj/w$", ("F", "M")),
    (r"mamba/conv_w$", (None, "M")),
    (r"mamba/conv_b$", ("M",)),
    (r"mamba/x_proj/w$", ("M", None)),
    (r"mamba/dt_proj/w$", (None, "M")),
    (r"mamba/dt_proj/b$", ("M",)),
    (r"mamba/A_log$", ("M", None)),
    (r"mamba/D$", ("M",)),
    (r"mamba/out_proj/w$", ("M", "F")),
    # mamba2 (split projections)
    (r"mamba/in_[zx]/w$", ("F", "M")),
    (r"mamba/in_[BC]/w$", ("F", None)),
    (r"mamba/in_dt/w$", ("F", "M")),
    (r"mamba/conv_x_w$", (None, "M")),
    (r"mamba/conv_x_b$", ("M",)),
    (r"mamba/conv_[BC]_[wb]$", None),  # tiny: replicate
    (r"mamba/norm/scale$", ("M",)),
    # zamba shared block out-proj
    (r"shared_attn/out_proj/w$", ("M", "F")),
    # norms and everything else default to replicated
]

_STACKED_PREFIXES = ("layers/", "enc_layers/", "dec_layers/", "dense_layers/")


def _match_rule(name: str) -> Optional[tuple]:
    for pat, spec in _RULES:
        if re.search(pat, name):
            return spec if spec is not None else ()
    return ()


def param_pspec(name: str, leaf, cfg: ArchConfig, mesh: Mesh, *,
                mode: str = "train") -> P:
    """PartitionSpec for one named param leaf."""
    spec = list(_match_rule(name))
    stacked = name.startswith(_STACKED_PREFIXES)
    axes: list = []
    fsdp_ok = mode in ("train", "dp_train") and "data" in mesh.axis_names
    dp_all = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    shape = leaf.shape[1:] if stacked else leaf.shape
    # pad spec to rank
    spec = spec + [None] * (len(shape) - len(spec))
    for dim, ax in zip(shape, spec):
        if ax == "F":
            if mode == "dp_train":
                ax = dp_all  # FSDP over the full mesh (TP=1 policy)
            else:
                ax = "data" if fsdp_ok else None
        elif ax == "M":
            if mode == "dp_train":
                ax = None
            else:
                ax = "model" if "model" in mesh.axis_names else None
        if ax is not None:
            size = (np.prod([mesh.shape[a] for a in ax])
                    if isinstance(ax, tuple) else mesh.shape[ax])
            if dim % int(size) != 0:
                ax = None  # non-divisible dims fall back to replication
        axes.append(ax)
    if stacked:
        axes = [None] + axes
    return P(*axes)


def param_shardings(params, cfg: ArchConfig, mesh: Mesh, *, mode="train"):
    """NamedSharding tree matching the param tree."""
    return tree_map_with_name(
        lambda name, leaf: NamedSharding(
            mesh, param_pspec(name, leaf, cfg, mesh, mode=mode)), params)


def batch_pspec(mesh: Mesh, rules=None) -> P:
    """Input batch: leading dim over the active data-parallel axes."""
    if rules is not None and rules.rules.get("batch") is not None:
        dp = rules.rules["batch"]
        dp = dp if isinstance(dp, tuple) else (dp,)
        dp = tuple(a for a in dp if a in mesh.axis_names)
    else:
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(dp if dp else None)


def batch_shardings(batch, mesh: Mesh, rules=None):
    spec = batch_pspec(mesh, rules)

    def one(leaf):
        dp_axes = spec[0]
        if dp_axes is None:
            return NamedSharding(mesh, P())
        size = int(np.prod([mesh.shape[a] for a in (
            dp_axes if isinstance(dp_axes, tuple) else (dp_axes,))]))
        if leaf.shape and leaf.shape[0] % size == 0:
            return NamedSharding(mesh, P(*([spec[0]] + [None] * (len(leaf.shape) - 1))))
        return NamedSharding(mesh, P())

    import jax

    return jax.tree.map(one, batch)


def cache_pspec(name: str, leaf, cfg: ArchConfig, mesh: Mesh) -> P:
    """Decode-cache sharding: batch over 'data', kv-heads over 'model'.

    Cache leaves are stacked [L, B, S, ...]; MLA latent ([L,B,S,r]) and SSM
    conv/ssm states shard batch only (plus head/channel dims over model where
    divisible).
    """
    model_ok = "model" in mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    shape = leaf.shape
    axes: list = [None] * len(shape)
    # leading stacked-layer axis, then batch over the full DP product
    if len(shape) >= 2 and dp:
        if shape[1] % dp_size == 0:
            axes[1] = dp if len(dp) > 1 else dp[0]
        elif "data" in dp and shape[1] % mesh.shape["data"] == 0:
            axes[1] = "data"
    if name.endswith(("/k", "/v", "/k_scale", "/v_scale")) and model_ok and \
            cfg.kv_cache_shard == "seq" and len(shape) >= 3 and \
            shape[2] % mesh.shape["model"] == 0:
        # flash-decode-style: shard the cache SEQUENCE over the TP group; the
        # softmax statistics / output partials combine with tiny collectives
        axes[2] = "model"
    elif name.endswith(("/k", "/v")) and len(shape) == 5 and model_ok:
        if shape[3] % mesh.shape["model"] == 0:
            axes[3] = "model"  # kv heads
        elif shape[4] % mesh.shape["model"] == 0:
            # head_dim fallback: keeps the cache sharded when KV heads do not
            # divide the TP axis (e.g. yi-34b kv=8 on 16-way model); GSPMD
            # partial-sums the score contraction.  Costly in collectives —
            # superseded by the shard_map flash-decode path (see SS Perf).
            axes[4] = "model"
    if "ssm" in name and len(shape) == 5 and model_ok:
        if shape[2] % mesh.shape["model"] == 0:
            axes[2] = "model"  # mamba2 ssm state heads [L,B,H,P,N]
    if ("conv_x" in name or name.endswith("/conv")) and len(shape) == 4 and model_ok:
        if shape[3] % mesh.shape["model"] == 0:
            axes[3] = "model"  # conv channels
    if name.endswith("/ssm") and len(shape) == 4 and model_ok:
        if shape[2] % mesh.shape["model"] == 0:
            axes[2] = "model"  # mamba1 ssm state [L,B,di,N]
    return P(*axes)


def cache_shardings(caches, cfg: ArchConfig, mesh: Mesh):
    return tree_map_with_name(
        lambda name, leaf: NamedSharding(mesh, cache_pspec(name, leaf, cfg, mesh)),
        caches)


def choose_policy(cfg, mesh, kind: str = "train") -> str:
    """Per-arch parallelism policy (SS Perf iteration 1): small models whose
    FSDP-sharded step state fits one chip run pure-DP (TP=1) — activation
    collectives vanish and only FSDP gathers remain.  Large models keep
    FSDP+TP."""
    import os

    if os.environ.get("REPRO_VARIANT") == "fsdp_tp":
        return "train"
    if kind != "train":
        return "serve"
    n = cfg.param_count()
    chips = float(np.prod(list(mesh.shape.values())))
    state_bytes = n * 16.0 / chips      # fp32 param+m+v, bf16 copy
    layer_bytes = n / max(cfg.n_layers + cfg.enc_layers, 1) * 2.0
    # pure DP needs the sharded state plus one gathered layer in flight
    if state_bytes + 3 * layer_bytes < 4e9:
        return "dp_train"
    return "train"


def choose_serve_cache_policy(cfg, mesh) -> dict:
    """Per-arch serving cache policy (SS Perf iteration):

    - hybrid (zamba2): the wide shared-attention cache regresses under
      sequence sharding / quantization (GSPMD reshards the dequantized
      cache) -> plain heads-sharded bf16 cache.
    - GQA archs whose KV heads do NOT divide the TP axis (kv_repeat > 1 or
      head-dim fallback): flash-decode-style sequence-sharded cache with
      kv_repeat=1, plus int8 quantization.
    - GQA archs that shard evenly: keep heads sharding, add int8 quant
      (halves the decode memory term at no collective cost).
    - MLA / SSM: unchanged (latent / state caches).
    """
    if cfg.family in ("hybrid",) or cfg.n_heads == 0:
        return {"kv_cache_quant": False, "kv_cache_shard": "heads"}
    if cfg.mla is not None:
        # MLA: quantize the rank-r latent (the cache IS the latent); no head
        # sharding applies — the absorbed decode reads it per q-head locally
        return {"kv_cache_quant": True, "kv_cache_shard": "heads"}
    model = mesh.shape.get("model", 1)
    needs_seq = (cfg.kv_repeat > 1
                 or (cfg.n_kv_heads and cfg.n_kv_heads % model != 0))
    if needs_seq:
        return {"kv_cache_quant": True, "kv_cache_shard": "seq",
                "kv_repeat": 1}
    return {"kv_cache_quant": True, "kv_cache_shard": "heads"}
