"""Falcon-Mamba-7B [ssm] — Mamba-1 architecture, attention-free.

[arXiv:2410.05355; unverified].  64L d_model=4096 d_ff=0 vocab=65024,
ssm_state=16, d_inner=2*d=8192, conv=4, dt_rank=ceil(4096/16)=256.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab=65024,
    norm="rmsnorm",
    ssm=SSMConfig(
        kind="mamba1",
        d_state=16,
        d_conv=4,
        expand=2,
        chunk=256,
        dt_rank=256,
    ),
    citation="[arXiv:2410.05355; unverified]",
)
