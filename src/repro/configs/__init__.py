from repro.configs.base import (
    ARCH_IDS,
    SHAPES,
    ArchConfig,
    ShapeSpec,
    get_config,
    get_shape,
    reduce_config,
)
