"""Zamba2-1.2B [hybrid] — Mamba-2 backbone + shared attention blocks.

[arXiv:2411.15242; hf].  38L d_model=2048, shared attn block (32H kv=32,
runs at 2*d on concat(h, emb)) applied every 6 layers; d_ff=8192,
vocab=32000, ssm_state=64, mamba2 headdim=64.
"""
from repro.configs.base import ArchConfig, HybridConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,  # at the shared block's 2*d width
    d_ff=8192,
    vocab=32000,
    norm="rmsnorm",
    rope_theta=10000.0,
    ssm=SSMConfig(
        kind="mamba2",
        d_state=64,
        d_conv=4,
        expand=2,
        headdim=64,
        chunk=256,
    ),
    hybrid=HybridConfig(shared_attn_every=6, concat_embedding=True),
    citation="[arXiv:2411.15242; hf]",
)
