"""Architecture + shape configuration system.

Every assigned architecture gets a ``repro/configs/<id>.py`` defining
``CONFIG = ArchConfig(...)`` with the exact published sizes.  The registry maps
public arch ids (``--arch deepseek-v2-lite-16b``) to those modules.  Reduced
("smoke") variants of the same family are derived mechanically for CPU tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional


# --------------------------------------------------------------------------
# Sub-configs
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    top_k: int
    d_expert_ff: int
    n_shared: int = 0
    d_shared_ff: int = 0
    capacity_factor: float = 1.25
    first_dense_layers: int = 0  # leading dense layers (DeepSeek style)
    d_first_dense_ff: int = 0


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""

    kv_lora_rank: int
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    q_lora_rank: int = 0  # 0 => full-rank q projection (V2-Lite)


@dataclass(frozen=True)
class SSMConfig:
    kind: str  # 'mamba1' | 'mamba2'
    d_state: int
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64  # mamba2 only
    chunk: int = 256  # scan chunk length
    dt_rank: int = 0  # mamba1; 0 => ceil(d_model/16)


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: SSM backbone + a *shared* attention block every k layers."""

    shared_attn_every: int = 6
    concat_embedding: bool = True  # shared block sees concat(h, initial_emb)


# --------------------------------------------------------------------------
# ArchConfig
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | vlm | ssm | audio | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 => d_model // n_heads
    norm: str = "rmsnorm"  # rmsnorm | layernorm | layernorm_nonparam
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # encoder-decoder (audio family)
    enc_layers: int = 0  # 0 => decoder-only
    # modality frontend stubs ([vlm]: patch embeddings, [audio]: frame embeddings)
    frontend: str = "none"  # none | patch | frames
    frontend_dim: int = 0  # raw embedding dim produced by the (stub) frontend
    frontend_tokens: int = 0  # tokens contributed by the frontend per sample
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # attention implementation: chunked (flash-style jnp), naive, pallas
    attention_impl: str = "chunked"
    q_chunk: int = 512
    kv_chunk: int = 512
    # serving: replicate each KV head this many times so the effective KV-head
    # count divides the TP axis (vLLM-style num_kv_head_replicas)
    kv_repeat: int = 1
    # serving: KV-cache layout optimizations (SS Perf): int8-quantized cache
    # halves the decode memory term; 'seq' shards the cache on the sequence
    # axis over the TP group (flash-decode-style; kv_repeat stays 1)
    kv_cache_quant: bool = False
    kv_cache_shard: str = "heads"  # heads | seq
    # loss
    loss_chunk: int = 8192  # token-chunked cross-entropy
    # citation tag [source; verified-tier]
    citation: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # -- derived ----------------------------------------------------------
    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def supports_shape(self, shape: "ShapeSpec") -> bool:
        """long_500k needs sub-quadratic context handling (SSM / hybrid)."""
        if shape.name == "long_500k":
            return self.family in ("ssm", "hybrid")
        return True

    def param_count(self) -> int:
        """Analytic parameter count (matches init; used for 6ND roofline)."""
        from repro.models.zoo import analytic_param_count

        return analytic_param_count(self)

    def active_param_count(self) -> int:
        from repro.models.zoo import analytic_param_count

        return analytic_param_count(self, active_only=True)


# --------------------------------------------------------------------------
# Shapes (assigned to every architecture)
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

ARCH_IDS = [
    "deepseek-v2-lite-16b",
    "qwen3-moe-30b-a3b",
    "internvl2-26b",
    "olmo-1b",
    "qwen2-72b",
    "smollm-135m",
    "yi-34b",
    "falcon-mamba-7b",
    "seamless-m4t-medium",
    "zamba2-1.2b",
]


def get_config(arch_id: str) -> ArchConfig:
    """Load ``CONFIG`` from ``repro.configs.<arch_id with - -> _>``."""
    mod_name = "repro.configs." + arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(mod_name)
    return mod.CONFIG


def get_shape(shape_name: str) -> ShapeSpec:
    return SHAPES[shape_name]


# --------------------------------------------------------------------------
# Reduced (smoke) configs: same family/topology, tiny dims.
# --------------------------------------------------------------------------
def reduce_config(cfg: ArchConfig) -> ArchConfig:
    """Shrink a config to CPU-smoke scale, preserving its structural family."""
    kv_ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    n_heads = 4
    n_kv = max(1, n_heads // kv_ratio)
    repl: dict = dict(
        name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, 2 if cfg.hybrid is None else 4),
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=16,
        d_ff=128,
        vocab=256,
        loss_chunk=64,
        q_chunk=32,
        kv_chunk=32,
    )
    if cfg.moe is not None:
        repl["moe"] = dataclasses.replace(
            cfg.moe,
            n_routed=8,
            top_k=2,
            d_expert_ff=32,
            n_shared=min(cfg.moe.n_shared, 1),
            d_shared_ff=32 if cfg.moe.n_shared else 0,
            first_dense_layers=min(cfg.moe.first_dense_layers, 1),
            d_first_dense_ff=64 if cfg.moe.first_dense_layers else 0,
        )
    if cfg.mla is not None:
        repl["mla"] = dataclasses.replace(
            cfg.mla,
            kv_lora_rank=32,
            qk_nope_head_dim=16,
            qk_rope_head_dim=8,
            v_head_dim=16,
            q_lora_rank=0,
        )
        repl["head_dim"] = 16
    if cfg.ssm is not None:
        repl["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=8, d_conv=4, headdim=16, chunk=16, dt_rank=8
        )
    if cfg.hybrid is not None:
        repl["hybrid"] = dataclasses.replace(cfg.hybrid, shared_attn_every=2)
    if cfg.enc_layers > 0:
        repl["enc_layers"] = 2
    if cfg.frontend != "none":
        repl["frontend_dim"] = 48
        repl["frontend_tokens"] = 8
    return dataclasses.replace(cfg, **repl)


def make_serve_config(cfg: ArchConfig, model_axis: int) -> ArchConfig:
    """Derive the serving variant of a config for a TP axis of given size.

    Picks ``kv_repeat`` so effective KV heads divide the TP axis (when the
    query-group size allows it); params stay bf16 for serving.
    """
    kv_repeat = 1
    if cfg.n_kv_heads and cfg.n_heads:
        g = cfg.n_heads // cfg.n_kv_heads
        # smallest divisor of the query-group size that makes the effective
        # KV head count divide the TP axis (vLLM num_kv_head_replicas)
        for rep in range(1, g + 1):
            if g % rep == 0 and (cfg.n_kv_heads * rep) % model_axis == 0:
                kv_repeat = rep
                break
    return dataclasses.replace(cfg, kv_repeat=kv_repeat, param_dtype="bfloat16")
