"""DeepSeek-V2-Lite 16B [moe] — MLA kv_lora=512, 2 shared + 64 routed top-6.

[arXiv:2405.04434; hf].  27L d_model=2048 16H d_ff(expert)=1408 vocab=102400.
First layer is dense (d_ff=10944), remaining 26 are MoE.
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=102400,
    norm="rmsnorm",
    rope_theta=10000.0,
    moe=MoEConfig(
        n_routed=64,
        top_k=6,
        d_expert_ff=1408,
        n_shared=2,
        d_shared_ff=1408,
        capacity_factor=1.25,
        first_dense_layers=1,
        d_first_dense_ff=10944,
    ),
    mla=MLAConfig(
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        q_lora_rank=0,  # V2-Lite uses full-rank q
    ),
    citation="[arXiv:2405.04434; hf]",
)
