"""InternVL2-26B [vlm] — InternViT frontend (stub) + InternLM2-20B backbone.

[arXiv:2404.16821; hf].  Backbone: 48L d_model=6144 48H (GQA kv=8)
d_ff=16384 vocab=92553.  The ViT frontend is a STUB: ``input_specs()``
provides precomputed patch embeddings (InternViT-6B width 3200); a 2-layer
MLP projector maps them into the LM space (first-class, trained).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=92553,
    norm="rmsnorm",
    rope_theta=1000000.0,
    frontend="patch",
    frontend_dim=3200,
    frontend_tokens=1024,
    citation="[arXiv:2404.16821; hf]",
)
