"""OLMo-1B [dense] — non-parametric LayerNorm, MHA (kv=16), SwiGLU.

[arXiv:2402.00838; hf].  16L d_model=2048 16H d_ff=8192 vocab=50304.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab=50304,
    norm="layernorm_nonparam",
    rope_theta=10000.0,
    citation="[arXiv:2402.00838; hf]",
)
