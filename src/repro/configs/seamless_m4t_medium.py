"""SeamlessM4T-medium [audio] — encoder-decoder, multimodal frontend STUB.

[arXiv:2308.11596; hf].  12L enc + 12L dec, d_model=1024 16H (kv=16)
d_ff=4096 vocab=256206.  ``input_specs()`` provides precomputed audio frame
embeddings (the conformer speech frontend is stubbed per the assignment).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,       # decoder layers
    enc_layers=12,     # encoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=256206,
    norm="layernorm",
    rope_theta=10000.0,
    frontend="frames",
    frontend_dim=1024,
    citation="[arXiv:2308.11596; hf]",
)
