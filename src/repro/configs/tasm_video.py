"""The paper's own workload configuration: TASM video-analytics settings.

Not an LM architecture — this is the storage-manager configuration used by
the benchmarks and examples (encoder, layout constraints, policy constants),
collected in one place as the `--arch tasm-video` selectable config.
Scaled-down analogue constants are documented against the paper's values.
"""
from dataclasses import dataclass, field

from repro.codec.encode import EncoderConfig


@dataclass(frozen=True)
class TASMVideoConfig:
    # codec (paper: HEVC via NVENC/NVDEC; ours: GOP-structured DCT codec)
    encoder: EncoderConfig = field(default_factory=EncoderConfig)
    # layout constraints (paper: HEVC min tile 256x64 at 2K-4K; scaled down
    # proportionally for the 320x192 synthetic corpus)
    align: int = 8
    min_tile: int = 32
    # policy constants (paper §4)
    alpha: float = 0.8   # not-tiling threshold (§3.4.4, Fig. 10)
    eta: float = 1.0     # regret multiplier (§4.4, online indexing [11])
    # evaluation corpus (Table 1 analogues)
    sparse_coverage_max: float = 0.20  # "sparse": <20% frame coverage
    default_height: int = 192
    default_width: int = 320
    default_fps_gop: int = 16  # 1 "second" per GOP


CONFIG = TASMVideoConfig()
