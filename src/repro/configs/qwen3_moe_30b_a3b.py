"""Qwen3-30B-A3B [moe] — 128 experts top-8, GQA kv=4, QK-norm.

[hf:Qwen/Qwen3-30B-A3B; hf].  48L d_model=2048 32H d_ff(expert)=768
vocab=151936.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab=151936,
    norm="rmsnorm",
    qk_norm=True,
    rope_theta=1000000.0,
    moe=MoEConfig(
        n_routed=128,
        top_k=8,
        d_expert_ff=768,
        n_shared=0,
        capacity_factor=1.25,
    ),
    citation="[hf:Qwen/Qwen3-30B-A3B; hf]",
)
