"""Yi-34B [dense] — llama-arch GQA kv=8.

[arXiv:2403.04652; hf].  60L d_model=7168 56H d_ff=20480 vocab=64000.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab=64000,
    norm="rmsnorm",
    rope_theta=5000000.0,
    citation="[arXiv:2403.04652; hf]",
)
