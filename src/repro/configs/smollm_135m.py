"""SmolLM-135M [dense] — llama-arch small, GQA kv=3.

[hf:HuggingFaceTB/SmolLM-135M; hf].  30L d_model=576 9H d_ff=1536 vocab=49152.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    head_dim=64,
    d_ff=1536,
    vocab=49152,
    norm="rmsnorm",
    rope_theta=10000.0,
    tie_embeddings=True,
    citation="[hf:HuggingFaceTB/SmolLM-135M; hf]",
)
