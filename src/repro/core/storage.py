"""Tile-based physical storage (paper §3.4.5, Fig. 1).

Each SOT (sequence of tiles — a run of frames sharing one layout) stores one
independently decodable stream per tile:

    <root>/<video>/frames_<a>-<b>/tile<i>.npz

Retiling a SOT decodes every tile stream, re-encodes under the new layout,
and atomically replaces the SOT directory.  An in-memory mode (root=None)
backs unit tests; benchmarks use the on-disk layout.
"""
from __future__ import annotations

import hashlib
import pathlib
import shutil
import threading
import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.codec.encode import EncoderConfig, decode_tile, encode_tile
from repro.core.btree import BPlusTree
from repro.core.layout import TileLayout, single_tile_layout


@dataclass
class SOTRecord:
    sot_id: int
    frame_start: int
    frame_end: int
    layout: TileLayout
    epoch: int = 0
    size_bytes: float = 0.0


def tile_checksum(enc: dict) -> str:
    """Content digest of one encoded tile stream — scalar header plus every
    per-GOP quantized member, dtype/shape included so a reinterpreted buffer
    never collides.  The repair copy path verifies this end to end: computed
    on the source before the chunk ships, recomputed on the destination
    after the wire decode, and re-checked at commit before the replica
    flips live."""
    h = hashlib.sha256()
    h.update(np.array([enc["h"], enc["w"], enc["gop"], enc["qp"],
                       enc["n_frames"]], dtype=np.int64).tobytes())
    h.update(np.float64(enc["size_bytes"]).tobytes())
    for g in range(len(enc["kq"])):
        for member in (enc["kq"][g], enc["pq"][g]):
            a = np.ascontiguousarray(member)
            h.update(str(a.dtype).encode())
            h.update(np.array(a.shape, dtype=np.int64).tobytes())
            h.update(a.tobytes())
    return h.hexdigest()


#: decode_tiles implementations: "numpy" = the per-tile oracle loop,
#: "batched" = fused accelerator dispatches (bit-identical; codec/batch.py)
DECODE_BACKENDS = ("numpy", "batched")


class TileStore:
    def __init__(self, video: str, encoder: EncoderConfig, *,
                 root: Optional[str] = None, sot_len: Optional[int] = None,
                 decode_backend: str = "numpy"):
        self.video = video
        self.encoder = encoder
        self.sot_len = sot_len or encoder.gop  # default: one SOT per GOP
        assert self.sot_len % encoder.gop == 0, "SOT must cover whole GOPs"
        if decode_backend not in DECODE_BACKENDS:
            raise ValueError(f"decode_backend must be one of "
                             f"{DECODE_BACKENDS}, got {decode_backend!r}")
        self.decode_backend = decode_backend
        self.root = pathlib.Path(root) if root else None
        self._mem: dict[tuple[int, int, int], dict] = {}
        self.sots: list[SOTRecord] = []
        # B+-tree keyed on frame_start: interval lookup for sots_in_range
        self._intervals = BPlusTree(order=16)
        self.encode_seconds_total = 0.0
        # actual tile-stream decodes (cache hits in the serving layer never
        # reach this counter) — lets tests/benchmarks verify dedup exactly;
        # locked: group fetches decode concurrently on the worker pool.
        # pixels_decoded_total counts actual decoded pixels at 8x8-block
        # granularity (an ROI-restricted decode adds only its masked blocks)
        self.tiles_decoded_total = 0
        self.pixels_decoded_total = 0
        self._stats_lock = threading.Lock()

    # -- paths ---------------------------------------------------------------
    def _sot_dir(self, rec: SOTRecord) -> pathlib.Path:
        return (self.root / self.video /
                f"frames_{rec.frame_start}-{rec.frame_end - 1}")

    def _write_tile(self, rec: SOTRecord, tile_idx: int, enc: dict) -> None:
        if self.root is None:
            self._mem[(rec.sot_id, rec.epoch, tile_idx)] = enc
            return
        d = self._sot_dir(rec)
        d.mkdir(parents=True, exist_ok=True)
        tmp = d / f".tile{tile_idx}.tmp.npz"
        # one zip member per GOP so a prefix read (temporal random access)
        # decompresses only the GOPs it needs instead of the whole stream
        gops = {}
        for g in range(len(enc["kq"])):
            gops[f"kq_{g}"] = enc["kq"][g]
            gops[f"pq_{g}"] = enc["pq"][g]
        np.savez_compressed(tmp,
                            meta=np.array([enc["h"], enc["w"], enc["gop"],
                                           enc["qp"], enc["n_frames"]]),
                            size=np.array([enc["size_bytes"]]), **gops)
        tmp.rename(d / f"tile{tile_idx}.npz")

    def _read_tile(self, rec: SOTRecord, tile_idx: int, *,
                   n_gops: int | None = None) -> dict:
        """Load an encoded tile; ``n_gops`` limits materialization to the
        first n GOPs (a prefix read never touches the rest of the stream —
        on disk, npz members beyond the prefix are not even decompressed)."""
        if self.root is None:
            enc = self._mem[(rec.sot_id, rec.epoch, tile_idx)]
            if n_gops is None or n_gops >= len(enc["kq"]):
                return enc
            return {**enc, "kq": enc["kq"][:n_gops], "pq": enc["pq"][:n_gops]}
        with np.load(self._sot_dir(rec) / f"tile{tile_idx}.npz") as z:
            h, w, gop, qp, n_frames = (int(x) for x in z["meta"])
            total = n_frames // gop
            k = total if n_gops is None else min(n_gops, total)
            if "kq" in z.files:   # legacy layout: one member for all GOPs
                kq, pq = z["kq"][:k], z["pq"][:k]
            else:
                kq = [z[f"kq_{g}"] for g in range(k)]
                pq = [z[f"pq_{g}"] for g in range(k)]
            return {"kq": kq, "pq": pq, "h": h, "w": w, "gop": gop,
                    "qp": qp, "n_frames": n_frames,
                    "size_bytes": float(z["size"][0])}

    # -- ingest ---------------------------------------------------------------
    def ingest(self, frames: np.ndarray,
               layouts: Optional[dict[int, TileLayout]] = None) -> float:
        """Encode the whole video.  layouts: sot_id -> layout (default ω).
        Returns encode seconds."""
        T, H, W = frames.shape
        assert T % self.sot_len == 0, (T, self.sot_len)
        n_sots = T // self.sot_len
        t0 = time.perf_counter()
        for s in range(n_sots):
            a, b = s * self.sot_len, (s + 1) * self.sot_len
            layout = (layouts or {}).get(s, single_tile_layout(H, W))
            rec = SOTRecord(s, a, b, layout)
            self._encode_sot(rec, frames[a:b])
            self._register(rec)
        dt = time.perf_counter() - t0
        self.encode_seconds_total += dt
        return dt

    def _register(self, rec: SOTRecord) -> None:
        self.sots.append(rec)
        self._intervals.insert(rec.frame_start, rec)

    def restore(self, records: list[SOTRecord]) -> None:
        """Adopt SOT records from a persisted manifest (tile data already on
        disk); only valid for on-disk stores."""
        assert self.root is not None, "cannot restore an in-memory store"
        for rec in records:
            self._register(rec)

    def _encode_sot(self, rec: SOTRecord, frames: np.ndarray) -> None:
        total = 0.0
        for i, (y1, x1, y2, x2) in enumerate(rec.layout.tile_rects()):
            enc = encode_tile(np.ascontiguousarray(frames[:, y1:y2, x1:x2]),
                              self.encoder)
            self._write_tile(rec, i, enc)
            total += enc["size_bytes"]
        rec.size_bytes = total

    # -- decode ----------------------------------------------------------------
    def decode_tiles(self, sot_id: int, tile_idxs, *,
                     n_frames=None,
                     blocks: Optional[dict] = None) -> dict[int, np.ndarray]:
        """Decode the given tile streams of a SOT up to n_frames.  Whole GOPs
        except the last, which stops at the last requested frame (temporal
        random access never decodes past the request).  ``n_frames`` is one
        depth for every tile, or a ``tile_idx -> depth`` dict (a merged
        group fetch decodes each tile only as deep as its deepest consumer).

        ``blocks``: optional ``tile_idx -> block mask`` (sorted tile-local
        8x8-block indices, or ``None`` for the full tile) — ROI-restricted
        decode: only masked blocks are dequantized/transformed, the rest of
        each returned array stays zero (see ``decode_tile``).  Tiles absent
        from the dict decode fully.

        With ``decode_backend="batched"`` every (tile, GOP, mask) selection
        of the call is flattened into fused accelerator dispatches
        (``codec/batch.py``) instead of the per-tile numpy loop; arrays and
        the decode counters are bit-identical either way."""
        rec = self.sots[sot_id]
        span = rec.frame_end - rec.frame_start
        gop = self.encoder.gop
        tile_idxs = list(tile_idxs)
        if isinstance(n_frames, dict):
            depth = {t: min(n_frames.get(t, span), span) for t in tile_idxs}
        else:
            nf = span if n_frames is None else min(n_frames, span)
            depth = {t: nf for t in tile_idxs}
        out = {}
        pixels = 0
        plan = []   # (tile, enc, n_full, tail, mask)
        for t in tile_idxs:
            nf = depth[t]
            n_full = nf // gop
            tail = nf - n_full * gop
            n_gops = n_full + (1 if tail else 0)
            enc = self._read_tile(rec, t, n_gops=n_gops)
            mask = (blocks or {}).get(t)
            n_blocks = (enc["h"] // 8) * (enc["w"] // 8) if mask is None \
                else len(mask)
            pixels += n_blocks * 64 * nf
            plan.append((t, enc, n_full, tail, mask))
        if self.decode_backend == "batched":
            # jax rides in only when the batched backend is actually used
            from repro.codec.batch import decode_tile_batch
            owners, items = [], []
            for t, enc, n_full, tail, mask in plan:
                if n_full:
                    owners.append(t)
                    items.append((enc, list(range(n_full)), None, mask))
                if tail:
                    owners.append(t)
                    items.append((enc, [n_full], tail, mask))
            parts_by_tile: dict[int, list] = {}
            for t, arr in zip(owners, decode_tile_batch(items)):
                parts_by_tile.setdefault(t, []).append(arr)
            for t, parts in parts_by_tile.items():
                out[t] = (np.concatenate(parts, axis=0) if len(parts) > 1
                          else parts[0])
        else:
            for t, enc, n_full, tail, mask in plan:
                parts = []
                if n_full:
                    parts.append(decode_tile(enc, gop_indices=range(n_full),
                                             blocks=mask))
                if tail:
                    parts.append(decode_tile(enc, gop_indices=[n_full],
                                             frames_within=tail, blocks=mask))
                out[t] = (np.concatenate(parts, axis=0) if len(parts) > 1
                          else parts[0])
        with self._stats_lock:
            self.tiles_decoded_total += len(tile_idxs)
            self.pixels_decoded_total += pixels
        return out

    def decode_full_sot(self, sot_id: int) -> np.ndarray:
        """Reassemble all tiles of a SOT into full frames (stitching)."""
        rec = self.sots[sot_id]
        tiles = self.decode_tiles(sot_id, range(rec.layout.n_tiles))
        T = rec.frame_end - rec.frame_start
        H, W = rec.layout.frame_height, rec.layout.frame_width
        frames = np.zeros((T, H, W), dtype=np.float32)
        for i, (y1, x1, y2, x2) in enumerate(rec.layout.tile_rects()):
            frames[:, y1:y2, x1:x2] = tiles[i][:T]
        return frames

    # -- retile -----------------------------------------------------------------
    def retile(self, sot_id: int, new_layout: TileLayout) -> float:
        """Decode + re-encode a SOT under a new layout.  Returns seconds."""
        rec = self.sots[sot_id]
        if new_layout == rec.layout:
            return 0.0
        t0 = time.perf_counter()
        frames = self.decode_full_sot(sot_id)
        old_dir = self._sot_dir(rec) if self.root is not None else None
        old_epoch = rec.epoch
        rec.layout = new_layout
        rec.epoch += 1
        if old_dir is not None and old_dir.exists():
            shutil.rmtree(old_dir)
        self._encode_sot(rec, frames)
        # drop in-memory blobs of the previous epoch
        if self.root is None:
            for k in [k for k in self._mem if k[0] == sot_id and k[1] == old_epoch]:
                del self._mem[k]
        dt = time.perf_counter() - t0
        self.encode_seconds_total += dt
        return dt

    # -- stats -------------------------------------------------------------------
    def storage_bytes(self) -> float:
        return float(sum(r.size_bytes for r in self.sots))

    def sots_in_range(self, f_lo: int, f_hi: int) -> list[SOTRecord]:
        """SOTs overlapping [f_lo, f_hi), ascending — an O(log n + k)
        range scan of the frame-interval B+-tree (SOTs are fixed-length, so
        any overlapping SOT starts at or after f_lo - sot_len + 1)."""
        lo_key = max(0, f_lo - self.sot_len + 1)
        return [rec for _, recs in self._intervals.scan(lo_key, f_hi)
                for rec in recs if rec.frame_end > f_lo]
