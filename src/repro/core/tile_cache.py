"""Byte-budgeted LRU cache of decoded tile arrays (serving layer, part 1).

Decoded tiles are the engine's most expensive artifact: every scan that
touches a SOT pays a tile-stream decode even when an earlier query already
materialized the same pixels.  ``TileCache`` keeps those arrays across
queries, keyed::

    (video, sot_id, epoch, tile_idx)

The ``epoch`` component makes invalidation *structural*: ``TileStore.retile``
bumps the SOT's epoch, so every key minted against the old layout simply
stops being asked for — the cache can never serve pre-retile pixels.  Stale
epochs are additionally purged eagerly (:meth:`invalidate`) so dead entries
do not squat on the byte budget.  This holds for every retile producer
alike: foreground ``VideoStore.retile`` calls, inline policy hooks, and the
background :class:`~repro.core.tuner.PhysicalTuner` all route through the
same epoch-bumping engine path, so a scan racing a background re-tile reads
either the old epoch's pixels or the new one's — never a mix.

Frame-depth semantics: a cached array of ``n`` frames serves any request for
``<= n`` frames as a prefix view.  Decode is GOP-independent and
deterministic, so ``arr[:k]`` is bit-identical to a fresh ``k``-frame decode
of the same tile.  A request for *more* frames than cached is a miss; the
deeper decode then replaces the shallower entry.

Block-coverage semantics (ROI-restricted decode): an entry records which
8x8 blocks of the tile its array actually holds — ``None`` for a full-tile
decode, else the mask that was passed to ``decode_tile(blocks=...)``
(pixels outside it are zero, *not* tile content).  A request hits only if
the entry **covers** it: a full-tile entry serves any sub-ROI request, a
covering ROI entry serves any subset mask (per-block decode is
deterministic, so covered blocks are bit-identical), and a request for
blocks outside the entry's mask is a miss.  On such a miss the scheduler
re-decodes the *union* of the old and new masks at the max of both depths,
so :meth:`put` never shrinks an entry in either dimension — coverage and
depth only ever grow until eviction.

Thread safety: every public method takes the internal lock; returned arrays
are shared read-only views — callers must not write into them (the executor
only crops from them).
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Iterable, Optional

import numpy as np

#: cache key: (video, sot_id, epoch, tile_idx)
TileKey = tuple[str, int, int, int]

#: block coverage: None = full tile, else frozenset of tile-local indices
BlockMask = Optional[frozenset]

DEFAULT_CACHE_BYTES = 256 << 20  # 256 MiB


def _covers(entry_blocks: BlockMask, requested: BlockMask) -> bool:
    """Does an entry holding ``entry_blocks`` serve a request for
    ``requested``?  ``None`` means "the whole tile" on either side."""
    if entry_blocks is None:
        return True
    if requested is None:
        return False
    return requested <= entry_blocks


@dataclass
class _Entry:
    arr: np.ndarray
    blocks: BlockMask


@dataclass
class CacheStats:
    """Cumulative counters (monotone except ``bytes_cached``/``entries``)."""
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    invalidations: int = 0
    bytes_cached: int = 0
    entries: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class TileCache:
    """Thread-safe byte-budgeted LRU of decoded tile arrays.

    ``budget_bytes <= 0`` disables the cache: every ``get`` misses and
    ``put`` is a no-op (useful for measuring cold-cache behaviour).
    """

    def __init__(self, budget_bytes: int = DEFAULT_CACHE_BYTES):
        self.budget_bytes = int(budget_bytes)
        self._lru: OrderedDict[TileKey, _Entry] = OrderedDict()
        self._lock = threading.Lock()
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        self._invalidations = 0
        self._bytes = 0

    # ------------------------------------------------------------- access
    def get(self, key: TileKey, n_frames: int | None = None,
            blocks: Optional[Iterable[int]] = None) -> np.ndarray | None:
        """Return the cached decode for ``key`` (first ``n_frames`` frames),
        or None.  A cached array shallower than ``n_frames``, or one whose
        block coverage does not include every block in ``blocks``
        (``None`` = the whole tile), is a miss."""
        requested = None if blocks is None else frozenset(blocks)
        with self._lock:
            e = self._lru.get(key)
            if e is None or (n_frames is not None
                             and e.arr.shape[0] < n_frames) \
                    or not _covers(e.blocks, requested):
                self._misses += 1
                return None
            self._lru.move_to_end(key)
            self._hits += 1
            return e.arr if n_frames is None else e.arr[:n_frames]

    def coverage(self, key: TileKey) -> Optional[tuple[int, BlockMask]]:
        """Peek an entry's ``(n_frames, blocks)`` coverage without touching
        LRU order or hit/miss counters — the scheduler uses it to widen a
        covering-miss re-decode to the union of old and new masks."""
        with self._lock:
            e = self._lru.get(key)
            return None if e is None else (e.arr.shape[0], e.blocks)

    def put(self, key: TileKey, arr: np.ndarray,
            blocks: Optional[Iterable[int]] = None) -> None:
        """Insert (or deepen/widen) a decoded tile; evicts LRU entries over
        budget.  Arrays larger than the whole budget are not cached.  An
        entry is only replaced by one that covers it (>= frames AND a
        superset block mask) — a narrower or shallower decode never clobbers
        an entry that can serve more requests."""
        nbytes = int(arr.nbytes)
        if nbytes > self.budget_bytes:
            return
        new_blocks = None if blocks is None else frozenset(blocks)
        with self._lock:
            old = self._lru.pop(key, None)
            if old is not None:
                if old.arr.shape[0] > arr.shape[0] \
                        or not _covers(new_blocks, old.blocks):
                    self._lru[key] = old   # keep the wider/deeper entry
                    return
                self._bytes -= old.arr.nbytes
            self._lru[key] = _Entry(arr, new_blocks)
            self._bytes += nbytes
            while self._bytes > self.budget_bytes and self._lru:
                _, victim = self._lru.popitem(last=False)
                self._bytes -= victim.arr.nbytes
                self._evictions += 1

    # ------------------------------------------------------- invalidation
    def invalidate(self, video: str | None = None,
                   sot_id: int | None = None,
                   before_epoch: int | None = None) -> int:
        """Drop entries matching the given components; ``before_epoch``
        keeps entries at or above that epoch (purge-stale).  Returns the
        number of entries dropped."""
        with self._lock:
            doomed = [k for k in self._lru
                      if (video is None or k[0] == video)
                      and (sot_id is None or k[1] == sot_id)
                      and (before_epoch is None or k[2] < before_epoch)]
            for k in doomed:
                self._bytes -= self._lru.pop(k).arr.nbytes
            self._invalidations += len(doomed)
            return len(doomed)

    def clear(self) -> int:
        return self.invalidate()

    # --------------------------------------------------------------- stats
    def stats(self) -> CacheStats:
        with self._lock:
            return CacheStats(hits=self._hits, misses=self._misses,
                              evictions=self._evictions,
                              invalidations=self._invalidations,
                              bytes_cached=self._bytes,
                              entries=len(self._lru))

    def __len__(self) -> int:
        with self._lock:
            return len(self._lru)

    def __contains__(self, key: TileKey) -> bool:
        with self._lock:
            return key in self._lru
