"""Byte-budgeted, workload-predictive cache of decoded tile arrays.

Decoded tiles are the engine's most expensive artifact: every scan that
touches a SOT pays a tile-stream decode even when an earlier query already
materialized the same pixels.  ``TileCache`` keeps those arrays across
queries, keyed::

    (video, sot_id, epoch, tile_idx)

The ``epoch`` component makes invalidation *structural*: ``TileStore.retile``
bumps the SOT's epoch, so every key minted against the old layout simply
stops being asked for — the cache can never serve pre-retile pixels.  Stale
epochs are additionally purged eagerly (:meth:`invalidate`) so dead entries
do not squat on the byte budget.  This holds for every retile producer
alike: foreground ``VideoStore.retile`` calls, inline policy hooks, and the
background :class:`~repro.core.tuner.PhysicalTuner` all route through the
same epoch-bumping engine path, so a scan racing a background re-tile reads
either the old epoch's pixels or the new one's — never a mix.

Frame-depth semantics: a cached array of ``n`` frames serves any request for
``<= n`` frames as a prefix view.  Decode is GOP-independent and
deterministic, so ``arr[:k]`` is bit-identical to a fresh ``k``-frame decode
of the same tile.  A request for *more* frames than cached is a miss; the
deeper decode then replaces the shallower entry.

Block-coverage semantics (ROI-restricted decode): an entry records which
8x8 blocks of the tile its array actually holds — ``None`` for a full-tile
decode, else the mask that was passed to ``decode_tile(blocks=...)``
(pixels outside it are zero, *not* tile content).  A request hits only if
the entry **covers** it: a full-tile entry serves any sub-ROI request, a
covering ROI entry serves any subset mask (per-block decode is
deterministic, so covered blocks are bit-identical), and a request for
blocks outside the entry's mask is a miss.  On such a miss the scheduler
re-decodes the *union* of the old and new masks at the max of both depths,
so :meth:`put` never shrinks an entry in either dimension — coverage and
depth only ever grow until eviction.

Three workload-predictive behaviours ride on those unchanged semantics,
all selected through :class:`~repro.core.config.CacheConfig`:

- **Block-packed ROI entries** (``block_packed=True``): an ROI entry stores
  only its decoded blocks — a boolean pixel mask plus the packed pixel
  array — instead of the zero-padded full-tile canvas, so the same byte
  budget holds many more subframe entries.  :meth:`get` re-materializes
  the canvas on each hit (zeros outside the mask, exactly the bytes decode
  produced), trading a memcpy for budget; served pixels are bit-identical.
- **Expected-reuse eviction** (``eviction="reuse"``): each resident entry
  counts its re-accesses; the eviction victim is the entry with the lowest
  observed reuse (prioritized-replay-style importance weighting — priority
  proportional to observed re-access frequency), oldest-first as the
  tiebreak.  ``eviction="lru"`` preserves the pre-predictive pure-LRU
  behaviour bit-for-bit (insertion/touch order, ``popitem(last=False)``).
- **Prefetch accounting**: the scheduler's prefetcher (see
  ``core/scheduler.py``) inserts entries with ``put(..., prefetch=True)``.
  Such an insert is strictly bounded — it may only evict entries that were
  never re-accessed (a prefetch never evicts a hotter entry; if that can't
  free enough budget the insert is dropped).  ``prefetch_issued`` counts
  predictively-decoded tiles, ``prefetch_hits`` first demand-hits on a
  prefetched entry, ``prefetch_wasted`` prefetched entries that were
  dropped, evicted, invalidated or replaced without ever serving a hit.

Thread safety: every public method takes the internal lock; returned arrays
are shared read-only views (or freshly-materialized canvases for packed
entries) — callers must not write into them (the executor only crops from
them).
"""
from __future__ import annotations

import threading
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Iterable, Optional

import numpy as np

from repro.core.config import DEFAULT_CACHE_BYTES, CacheConfig

#: cache key: (video, sot_id, epoch, tile_idx)
TileKey = tuple[str, int, int, int]

#: block coverage: None = full tile, else frozenset of tile-local indices
BlockMask = Optional[frozenset]

__all__ = ["TileCache", "CacheStats", "WorkloadPredictor", "TileKey",
           "BlockMask", "DEFAULT_CACHE_BYTES"]


def _covers(entry_blocks: BlockMask, requested: BlockMask) -> bool:
    """Does an entry holding ``entry_blocks`` serve a request for
    ``requested``?  ``None`` means "the whole tile" on either side."""
    if entry_blocks is None:
        return True
    if requested is None:
        return False
    return requested <= entry_blocks


@dataclass
class _Entry:
    arr: np.ndarray                     # canvas [F,h,w], or packed [F,npx]
    blocks: BlockMask
    n_frames: int
    shape_hw: tuple[int, int]
    mask2d: Optional[np.ndarray]        # bool [h,w] when block-packed
    nbytes: int                         # bytes charged to the budget
    canvas_nbytes: int                  # what a zero-padded canvas costs
    uses: int = 0                       # re-accesses while resident
    prefetched: bool = False            # prefetcher insert, no demand hit yet


@dataclass
class CacheStats:
    """Cumulative counters (monotone except ``bytes_cached``/``entries``/
    ``packed_bytes_saved``, which are live gauges)."""
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    invalidations: int = 0
    bytes_cached: int = 0
    entries: int = 0
    prefetch_issued: int = 0
    prefetch_hits: int = 0
    prefetch_wasted: int = 0
    packed_bytes_saved: int = 0
    evictions_by_reason: dict = field(default_factory=dict)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class WorkloadPredictor:
    """Sliding-window detector over the scan stream.

    Fed one ``(video, sot_id)`` pair per observed SOTScan (the tuner's
    workload-log tap, see ``tuner.on_scan``).  Per video it keeps the
    recent *distinct* SOT ids; when the last :attr:`MIN_RUN` of them form
    an arithmetic progression with nonzero stride — a scan sliding its
    window across the video, in either direction — it predicts the next
    ``depth`` SOTs on that line.  Anything else (random access, repeats)
    predicts nothing: prefetch is strictly opt-in evidence-driven work.

    Not thread-safe on its own: the scheduler calls it under its lock.
    """

    MIN_RUN = 3

    def __init__(self, depth: int = 2, history: int = 8):
        self.depth = max(1, int(depth))
        self.history = max(self.MIN_RUN, int(history))
        self._hist: dict[str, deque[int]] = {}

    def observe(self, video: str, sot_id: int) -> tuple[int, ...]:
        """Record one observed SOT scan; return the predicted next SOT ids
        (possibly empty)."""
        h = self._hist.get(video)
        if h is None:
            h = self._hist[video] = deque(maxlen=self.history)
        if h and h[-1] == sot_id:       # warm repeat: no new evidence
            return ()
        h.append(sot_id)
        if len(h) < self.MIN_RUN:
            return ()
        tail = list(h)[-self.MIN_RUN:]
        stride = tail[1] - tail[0]
        if stride == 0 or any(tail[i + 1] - tail[i] != stride
                              for i in range(self.MIN_RUN - 1)):
            return ()
        return tuple(tail[-1] + stride * (i + 1) for i in range(self.depth))

    def reset(self, video: Optional[str] = None) -> None:
        if video is None:
            self._hist.clear()
        else:
            self._hist.pop(video, None)


def _block_mask2d(blocks: frozenset, h: int, w: int) -> np.ndarray:
    """Boolean pixel mask for a set of tile-local row-major 8x8-block
    indices (the codec's block geometry; see ``codec/encode.py``)."""
    grid = np.zeros((h // 8, w // 8), dtype=bool)
    grid.flat[sorted(blocks)] = True
    return np.repeat(np.repeat(grid, 8, axis=0), 8, axis=1)


class TileCache:
    """Thread-safe byte-budgeted cache of decoded tile arrays.

    ``budget_bytes <= 0`` disables the cache: every ``get`` misses and
    ``put`` is a no-op (useful for measuring cold-cache behaviour).
    Construct either with a bare byte budget (legacy surface) or a full
    :class:`~repro.core.config.CacheConfig`.
    """

    def __init__(self, budget_bytes: Optional[int] = None, *,
                 config: Optional[CacheConfig] = None):
        if config is None:
            config = CacheConfig(budget_bytes=budget_bytes)
        elif budget_bytes is not None:
            raise ValueError("pass budget_bytes or config, not both")
        self.config = config.resolve()
        self.budget_bytes = self.config.budget_bytes
        # insertion/touch-ordered entry table.  Named for its legacy role:
        # in "lru" mode its order IS the eviction order; in "reuse" mode it
        # is the recency tiebreak under the importance weights.
        self._lru: OrderedDict[TileKey, _Entry] = OrderedDict()
        self._lock = threading.Lock()
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        self._invalidations = 0
        self._bytes = 0
        self._prefetch_issued = 0
        self._prefetch_hits = 0
        self._prefetch_wasted = 0
        self._packed_saved = 0
        self._evictions_by_reason: dict[str, int] = {}

    # ----------------------------------------------------------- entries
    def _make_entry(self, arr: np.ndarray, blocks: BlockMask,
                    prefetched: bool) -> _Entry:
        """Build the storage form of one decoded tile — packed (mask +
        selected pixels) for ROI entries under ``block_packed``, the plain
        canvas otherwise.  Runs outside the lock (the pack is a copy)."""
        n_frames, h, w = arr.shape
        canvas_nbytes = int(arr.nbytes)
        if (self.config.block_packed and blocks is not None
                and h % 8 == 0 and w % 8 == 0):
            mask2d = _block_mask2d(blocks, h, w)
            packed = np.ascontiguousarray(arr[:, mask2d])
            nbytes = int(packed.nbytes + mask2d.nbytes)
            if nbytes < canvas_nbytes:
                return _Entry(arr=packed, blocks=blocks, n_frames=n_frames,
                              shape_hw=(h, w), mask2d=mask2d, nbytes=nbytes,
                              canvas_nbytes=canvas_nbytes,
                              prefetched=prefetched)
        return _Entry(arr=arr, blocks=blocks, n_frames=n_frames,
                      shape_hw=(h, w), mask2d=None, nbytes=canvas_nbytes,
                      canvas_nbytes=canvas_nbytes, prefetched=prefetched)

    @staticmethod
    def _materialize(e: _Entry, n_frames: Optional[int]) -> np.ndarray:
        """The served array: a prefix view of the canvas, or a freshly
        scattered canvas for packed entries (zeros outside the mask — the
        exact bytes a masked decode produces, so serving is bit-identical
        to the unpacked path)."""
        if e.mask2d is None:
            return e.arr if n_frames is None else e.arr[:n_frames]
        n = e.n_frames if n_frames is None else n_frames
        out = np.zeros((n, *e.shape_hw), dtype=e.arr.dtype)
        out[:, e.mask2d] = e.arr[:n]
        return out

    # ------------------------------------------------------------- access
    def get(self, key: TileKey, n_frames: int | None = None,
            blocks: Optional[Iterable[int]] = None) -> np.ndarray | None:
        """Return the cached decode for ``key`` (first ``n_frames`` frames),
        or None.  A cached array shallower than ``n_frames``, or one whose
        block coverage does not include every block in ``blocks``
        (``None`` = the whole tile), is a miss."""
        requested = None if blocks is None else frozenset(blocks)
        with self._lock:
            e = self._lru.get(key)
            if e is None or (n_frames is not None
                             and e.n_frames < n_frames) \
                    or not _covers(e.blocks, requested):
                self._misses += 1
                return None
            self._lru.move_to_end(key)
            self._hits += 1
            e.uses += 1
            if e.prefetched:
                e.prefetched = False
                self._prefetch_hits += 1
            return self._materialize(e, n_frames)

    def coverage(self, key: TileKey) -> Optional[tuple[int, BlockMask]]:
        """Peek an entry's ``(n_frames, blocks)`` coverage without touching
        recency order or hit/miss counters — the scheduler uses it to widen
        a covering-miss re-decode to the union of old and new masks."""
        with self._lock:
            e = self._lru.get(key)
            return None if e is None else (e.n_frames, e.blocks)

    # ------------------------------------------------------------ insert
    def _drop(self, key: TileKey, e: _Entry) -> None:
        """Remove an already-popped entry's accounting (lock held)."""
        self._bytes -= e.nbytes
        self._packed_saved -= e.canvas_nbytes - e.nbytes
        if e.prefetched:
            self._prefetch_wasted += 1

    def _pick_victim(self, exclude: TileKey,
                     prefetch: bool) -> Optional[TileKey]:
        """The next eviction victim (lock held).  ``"lru"`` mode: the
        oldest entry, exactly the legacy ``popitem(last=False)``.
        ``"reuse"`` mode: the lowest observed-reuse weight, oldest first
        among ties.  A prefetch insert may only claim never-re-accessed
        entries (``uses == 0``) in either mode — never a hotter one."""
        best = None
        best_uses = None
        for k, e in self._lru.items():
            if k == exclude:
                continue
            if prefetch and e.uses > 0:
                continue
            if self.config.eviction == "lru" and not prefetch:
                return k
            if best_uses is None or e.uses < best_uses:
                best, best_uses = k, e.uses
                if best_uses == 0 and self.config.eviction == "lru":
                    return best    # lru + prefetch: oldest cold entry
        return best

    def put(self, key: TileKey, arr: np.ndarray,
            blocks: Optional[Iterable[int]] = None, *,
            prefetch: bool = False) -> bool:
        """Insert (or deepen/widen) a decoded tile; evicts entries over
        budget.  Arrays larger than the whole budget are not cached.  An
        entry is only replaced by one that covers it (>= frames AND a
        superset block mask) — a narrower or shallower decode never
        clobbers an entry that can serve more requests.

        ``prefetch=True`` marks a predictive insert: it may only evict
        entries that were never re-accessed, and is dropped (returning
        False, counted as wasted) when that cannot free enough budget."""
        new_blocks = None if blocks is None else frozenset(blocks)
        e = self._make_entry(arr, new_blocks, prefetched=prefetch)
        if e.nbytes > self.budget_bytes:
            if prefetch:
                with self._lock:
                    self._prefetch_wasted += 1
            return False
        with self._lock:
            old = self._lru.pop(key, None)
            if old is not None:
                if old.n_frames > e.n_frames \
                        or not _covers(new_blocks, old.blocks):
                    self._lru[key] = old   # keep the wider/deeper entry
                    if prefetch:
                        self._prefetch_wasted += 1
                    return False
                self._drop(key, old)
                # same logical object, deeper/wider bytes: the reuse signal
                # (and a pending prefetch credit) carries across the replace
                e.uses = old.uses
                e.prefetched = old.prefetched if prefetch else False
            self._lru[key] = e
            self._bytes += e.nbytes
            self._packed_saved += e.canvas_nbytes - e.nbytes
            reason = "prefetch" if prefetch else "budget"
            while self._bytes > self.budget_bytes:
                victim = self._pick_victim(exclude=key, prefetch=prefetch)
                if victim is None:
                    # only a hotter population remains and the insert was a
                    # prefetch: the prediction loses, not the residents
                    self._drop(key, self._lru.pop(key))
                    return False
                self._drop(victim, self._lru.pop(victim))
                self._evictions += 1
                self._evictions_by_reason[reason] = \
                    self._evictions_by_reason.get(reason, 0) + 1
            return True

    # ------------------------------------------------------- invalidation
    def invalidate(self, video: str | None = None,
                   sot_id: int | None = None,
                   before_epoch: int | None = None) -> int:
        """Drop entries matching the given components; ``before_epoch``
        keeps entries at or above that epoch (purge-stale).  Returns the
        number of entries dropped."""
        with self._lock:
            doomed = [k for k in self._lru
                      if (video is None or k[0] == video)
                      and (sot_id is None or k[1] == sot_id)
                      and (before_epoch is None or k[2] < before_epoch)]
            for k in doomed:
                self._drop(k, self._lru.pop(k))
            self._invalidations += len(doomed)
            return len(doomed)

    def clear(self) -> int:
        return self.invalidate()

    # ----------------------------------------------------------- prefetch
    def note_prefetch_issued(self, n_tiles: int = 1) -> None:
        """Count ``n_tiles`` predictively-issued tile decodes (called by
        the scheduler's prefetcher when it enqueues the work)."""
        with self._lock:
            self._prefetch_issued += n_tiles

    # --------------------------------------------------------------- stats
    def stats(self) -> CacheStats:
        with self._lock:
            return CacheStats(hits=self._hits, misses=self._misses,
                              evictions=self._evictions,
                              invalidations=self._invalidations,
                              bytes_cached=self._bytes,
                              entries=len(self._lru),
                              prefetch_issued=self._prefetch_issued,
                              prefetch_hits=self._prefetch_hits,
                              prefetch_wasted=self._prefetch_wasted,
                              packed_bytes_saved=self._packed_saved,
                              evictions_by_reason=dict(
                                  self._evictions_by_reason))

    def __len__(self) -> int:
        with self._lock:
            return len(self._lru)

    def __contains__(self, key: TileKey) -> bool:
        with self._lock:
            return key in self._lru
