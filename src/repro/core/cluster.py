"""Distributed VideoStore: a router tier over N ``VideoStoreServer`` nodes.

One TASM node already serves many client processes (``server.py``), but a
single store caps out at one machine's decode throughput and loses
everything when its process dies.  This module scales the same declarative
surface horizontally — VSS-style, with the storage tier split from the
query tier:

- :class:`PlacementMap` — consistent hashing over video names with an
  *explicit, persisted* assignment table.  The sha1 ring (virtual nodes,
  deterministic across processes) proposes owners; a bounded-load walk
  (cap ``ceil((placed+1)/N)``) keeps primaries within one video of even,
  and the recorded assignment is what routing obeys — membership changes
  suggest moves (:meth:`PlacementMap.plan_rebalance`) but never silently
  re-home data.

- :class:`ClusterRouter` — duck-types the ``VideoStore`` surface the
  socket front end touches, so a stock :class:`VideoStoreServer` (or the
  thin :class:`ClusterRouterServer` subclass with placement introspection
  ops) can serve a whole cluster.  Scans route to the first live replica
  in placement order (primary first, so repeats land on a warm tile
  cache); ``execute_many`` batches fan out per node in one RPC each and
  results re-assemble in strict submission order; mutations
  (``ingest``/``add_detections``/``retile``/…) apply to every replica.
  Each node keeps its own scheduler, cache, and tuner.

- Replication: ``replication=K`` writes every mutation to K nodes.  A
  dead node is marked down and excluded from reads; a replica that missed
  a mutation is marked stale per video.  Failover is *epoch-checked*: the
  router tracks the layout-epoch table each video should have (ingest
  acks + its own retiles), and a replica whose epochs lag is never read —
  a pre-retile layout cannot be served.  Node epochs only grow (local
  tuners bump them independently), so the check is ``>=`` per SOT.

- :class:`ClusterClient` — ``RemoteVideoStore`` plus cluster
  introspection RPCs, for talking to a :class:`ClusterRouterServer`.

Results are bit-identical to a single in-process store: per-node results
are exact (PR 5), and cross-node merges rebuild flat regions in plan
video order while spending ``limit`` sequentially per video — the
engine's own semantics (see ``query.split_plan``/``merge_results``).
"""
from __future__ import annotations

import bisect
import dataclasses
import hashlib
import json
import math
import os
import pathlib
import random
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Optional

from repro.core import wire
from repro.core.client import RemoteVideoStore
from repro.core.engine import IngestStats
from repro.core.repair import RepairStats, RepairWorker
from repro.core.query import (PhysicalPlan, ScanPlan, ScanQuery, ScanResult,
                              ScanStats, merge_results, split_plan)
from repro.core.server import VideoStoreServer
from repro.core.tile_cache import CacheStats
from repro.core.tuner import TunerStats

def _sum_cache_docs(docs) -> dict:
    """Aggregate per-node ``stats()["cache"]`` documents: counters and
    gauges add; ``evictions_by_reason`` merges per reason."""
    total = dataclasses.asdict(CacheStats())
    for d in docs:
        for k, v in d.items():
            if k == "evictions_by_reason":
                agg = total[k]
                for r, n in (v or {}).items():
                    agg[r] = agg.get(r, 0) + n
            elif k in total:
                total[k] += v
    return total


#: connection-level failures that trigger mark-down + failover (semantic
#: errors — KeyError, ValueError, … — always propagate to the caller)
_CONN_ERRORS = (wire.ConnectionClosed, wire.WireError, OSError)


def _ring_hash(key: str) -> int:
    """Deterministic across processes and runs (``hash()`` is salted)."""
    return int.from_bytes(hashlib.sha1(key.encode()).digest()[:8], "big")


def _parse_addr(addr) -> dict:
    """Node address → ``RemoteVideoStore`` kwargs: ``(host, port)`` tuple
    or ``"host:port"`` string = TCP, anything else = Unix socket path."""
    if isinstance(addr, (tuple, list)):
        return {"host": addr[0], "port": int(addr[1])}
    s = str(addr)
    if ":" in s and "/" not in s:
        host, port = s.rsplit(":", 1)
        return {"host": host or "127.0.0.1", "port": int(port)}
    return {"path": s}


def _map_threads(fn, items: list) -> list:
    """Apply ``fn`` concurrently on ephemeral threads (results in input
    order, first exception re-raised).  Ephemeral rather than pooled so
    nested fan-outs (a serving-session scan splitting across nodes) can
    never deadlock on exhausted pool workers."""
    if len(items) <= 1:
        return [fn(x) for x in items]
    results: list = [None] * len(items)
    errs: list = []

    def run(i, x):
        try:
            results[i] = fn(x)
        except BaseException as e:  # noqa: BLE001 - re-raised below
            errs.append(e)

    threads = [threading.Thread(target=run, args=(i, x), daemon=True)
               for i, x in enumerate(items)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errs:
        raise errs[0]
    return results


# ============================================================== placement
class PlacementMap:
    """Consistent-hash ring + explicit persisted video→nodes assignments.

    The ring (``vnodes`` virtual points per node, sha1) provides stable
    *proposals*: adding a node moves ~1/N of ring ownership
    (:meth:`ring_owner`).  Actual routing obeys :attr:`assignments`, an
    explicit table written at :meth:`place` time and persisted as JSON —
    so a membership change never silently re-homes ingested data; it only
    changes where *future* videos land, and :meth:`plan_rebalance` lists
    the deliberate moves that would re-align old ones.

    :meth:`place` walks ring successors skipping nodes already at the
    bounded-load cap ``ceil((placed+1)/N)``, which keeps primary counts
    within one of each other for any placement sequence.
    """

    def __init__(self, nodes, *, replication: int = 1, vnodes: int = 64,
                 path: Optional[str] = None):
        self.replication = int(replication)
        self.vnodes = int(vnodes)
        self.path = path
        self.nodes: list[str] = []
        for n in nodes:
            if n in self.nodes:
                raise ValueError(f"duplicate node {n!r}")
            self.nodes.append(n)
        self.assignments: dict[str, list[str]] = {}
        self._rebuild_ring()

    # ----------------------------------------------------------- the ring
    def _rebuild_ring(self) -> None:
        self._ring = sorted(
            (_ring_hash(f"{n}#{i}"), n)
            for n in self.nodes for i in range(self.vnodes))

    def _ring_walk(self, key: str):
        """Nodes in ring-successor order from ``key``'s point, each once."""
        if not self._ring:
            return
        idx = bisect.bisect_right(self._ring, (_ring_hash(key), "￿"))
        seen: set[str] = set()
        n_pts = len(self._ring)
        for off in range(n_pts):
            node = self._ring[(idx + off) % n_pts][1]
            if node not in seen:
                seen.add(node)
                yield node

    def ring_owner(self, video: str) -> str:
        """Pure consistent hash, no load bound, no memory — the stability
        anchor (adding a node re-homes ~1/N of these)."""
        for n in self._ring_walk(video):
            return n
        raise ValueError("placement map has no nodes")

    def ring_replicas(self, video: str, k: Optional[int] = None
                      ) -> list[str]:
        """First ``k`` distinct ring successors (pure CH, no memory)."""
        k = self.replication if k is None else int(k)
        out: list[str] = []
        for n in self._ring_walk(video):
            out.append(n)
            if len(out) >= k:
                break
        return out

    # --------------------------------------------------------- membership
    def add_node(self, name: str) -> None:
        if name in self.nodes:
            raise ValueError(f"duplicate node {name!r}")
        self.nodes.append(name)
        self._rebuild_ring()
        self.save()

    def remove_node(self, name: str) -> None:
        """Drop a node from the ring.  Existing assignments still naming
        it are untouched — migrating them is a deliberate operation (see
        :meth:`plan_rebalance`), not a side effect."""
        self.nodes.remove(name)
        self._rebuild_ring()
        self.save()

    # ---------------------------------------------------------- placement
    def place(self, video: str, *, replication: Optional[int] = None
              ) -> list[str]:
        """Return ``video``'s replica list, assigning it first if new.

        Primary: first ring successor under the bounded-load cap
        ``ceil((placed+1)/N)`` — max-min primary spread ≤ 1 for any
        sequence.  Replicas: the next distinct ring successors.  The
        assignment is recorded and persisted; repeat calls return it
        unchanged."""
        if video in self.assignments:
            return list(self.assignments[video])
        if not self.nodes:
            raise ValueError("placement map has no nodes")
        k = min(len(self.nodes),
                self.replication if replication is None
                else int(replication))
        counts = {n: 0 for n in self.nodes}
        for reps in self.assignments.values():
            if reps and reps[0] in counts:
                counts[reps[0]] += 1
        cap = math.ceil((len(self.assignments) + 1) / len(self.nodes))
        primary = next(n for n in self._ring_walk(video)
                       if counts[n] < cap)
        reps = [primary] + [n for n in self._ring_walk(video)
                            if n != primary][:k - 1]
        self.assignments[video] = reps
        self.save()
        return list(reps)

    def assign(self, video: str, nodes) -> None:
        """Explicitly pin a video's replica list (rebalance application)."""
        nodes = list(nodes)
        unknown = [n for n in nodes if n not in self.nodes]
        if unknown:
            raise ValueError(f"unknown nodes {unknown}")
        self.assignments[video] = nodes
        self.save()

    def nodes_for(self, video: str) -> list[str]:
        return list(self.assignments.get(video, []))

    def primary(self, video: str) -> Optional[str]:
        reps = self.assignments.get(video)
        return reps[0] if reps else None

    def plan_rebalance(self) -> dict[str, tuple[str, str]]:
        """``video -> (current primary, ring owner)`` for every video the
        pure ring would now place elsewhere.  Returned, never applied —
        moving data is the operator's call (:meth:`assign` after copying)."""
        return {v: (reps[0], self.ring_owner(v))
                for v, reps in self.assignments.items()
                if reps and reps[0] != self.ring_owner(v)}

    # -------------------------------------------------------- persistence
    def to_doc(self) -> dict:
        return {"version": 1, "nodes": list(self.nodes),
                "replication": self.replication, "vnodes": self.vnodes,
                "assignments": {v: list(r)
                                for v, r in self.assignments.items()}}

    @classmethod
    def from_doc(cls, doc: dict, *, path: Optional[str] = None
                 ) -> "PlacementMap":
        pm = cls(doc["nodes"], replication=doc.get("replication", 1),
                 vnodes=doc.get("vnodes", 64))
        pm.assignments = {v: list(r)
                          for v, r in doc.get("assignments", {}).items()}
        pm.path = path
        return pm

    def save(self) -> None:
        """Durable write: temp file + fsync + atomic rename (+ best-effort
        directory fsync), so a crash — even a power loss — mid-save leaves
        either the old table or the new one, never a torn file.  The
        assignment table is what routing obeys; a torn table would orphan
        every video."""
        if self.path is None:
            return
        p = pathlib.Path(self.path)
        tmp = p.with_suffix(p.suffix + ".tmp")
        with open(tmp, "w") as fh:
            fh.write(json.dumps(self.to_doc(), indent=1, sort_keys=True))
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, p)
        try:  # the rename itself must survive a power loss too
            dfd = os.open(str(p.parent), os.O_RDONLY)
            try:
                os.fsync(dfd)
            finally:
                os.close(dfd)
        except OSError:  # pragma: no cover - dir fsync is best-effort
            pass

    @classmethod
    def load(cls, path: str) -> "PlacementMap":
        with open(path) as fh:
            doc = json.load(fh)
        return cls.from_doc(doc, path=path)


# ================================================================= router
class ClusterScanQuery(ScanQuery):
    """The chainable builder, routed through the cluster."""

    def explain(self) -> PhysicalPlan:
        return self._engine.lower(self.plan())

    def execute(self) -> ScanResult:
        return self._engine.execute(self.plan())

    def submit(self) -> Future:
        return self._engine.submit(self.plan())


class RouterServingSession:
    """``serve()`` over the cluster: ``submit`` returns a Future.  Each
    submission routes independently; per-node micro-batching happens on
    the nodes' own shared sessions, so concurrent submissions hitting one
    node still merge into union-of-tiles decodes there."""

    def __init__(self, router: "ClusterRouter"):
        self._router = router
        self._futs: list[Future] = []
        self._lock = threading.Lock()
        self._closed = False

    def submit(self, query) -> Future:
        with self._lock:
            if self._closed:
                raise RuntimeError("serving session is closed")
            fut = self._router.submit(query)
            self._futs.append(fut)
            return fut

    def execute(self, query) -> ScanResult:
        return self.submit(query).result()

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            futs = list(self._futs)
        for f in futs:
            try:
                f.result()
            except Exception:  # noqa: BLE001 - surfaced via the future
                pass

    def __enter__(self) -> "RouterServingSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class ClusterRouter:
    """Route the ``VideoStore`` surface across N remote nodes.

    ``nodes`` maps node name → address (Unix socket path, ``"host:port"``,
    or ``(host, port)``).  The placement map comes from ``placement=``,
    is loaded from ``placement_path`` when that file exists, or is built
    fresh over the given nodes with ``replication=K``.

    Duck-types everything :class:`VideoStoreServer` touches, so the
    router can sit directly behind the PR 5 socket front end — clients
    cannot tell a cluster from a single store (results are
    bit-identical).  Thread-safe; reads fail over across replicas, and a
    node that dies mid-call is marked down and excluded until
    :meth:`ping_nodes` sees it answer again.
    """

    def __init__(self, nodes: dict, *, replication: int = 1,
                 placement: Optional[PlacementMap] = None,
                 placement_path: Optional[str] = None,
                 codec: Optional[str] = None,
                 max_frame_bytes: int = wire.DEFAULT_MAX_FRAME_BYTES,
                 node_retries: int = 1, timeout: Optional[float] = None,
                 health_interval: Optional[float] = None):
        """``timeout`` is the per-node connect timeout AND per-RPC
        deadline (a hung node fails over instead of blocking a serving
        thread; see ``RemoteVideoStore``).  ``health_interval`` starts a
        background health loop probing every node about that often
        (jittered) so recovered nodes rejoin automatically; down nodes
        are probed with exponential backoff.  ``None`` (default) keeps
        revival explicit via :meth:`ping_nodes`."""
        if not nodes:
            raise ValueError("cluster needs at least one node")
        self.addresses = dict(nodes)
        self.codec = codec
        self.max_frame_bytes = int(max_frame_bytes)
        self.node_retries = int(node_retries)
        self.timeout = timeout
        self.health_interval = health_interval
        if placement is None:
            if placement_path is not None and os.path.exists(placement_path):
                placement = PlacementMap.load(placement_path)
            else:
                placement = PlacementMap(sorted(self.addresses),
                                         replication=replication,
                                         path=placement_path)
        unknown = [n for n in placement.nodes if n not in self.addresses]
        if unknown:
            raise ValueError(f"placement names unknown nodes {unknown}")
        self.placement = placement
        self._lock = threading.RLock()
        self._channels: dict[str, RemoteVideoStore] = {}
        self._down: set[str] = set()
        self._stale: set[tuple[str, str]] = set()     # (video, node)
        self._verified: set[tuple[str, str]] = set()  # epoch-checked pairs
        self._epochs: dict[str, dict[int, int]] = {}  # expected generation
        self._closed = False
        self._pool = ThreadPoolExecutor(
            max_workers=max(8, 4 * len(self.addresses)),
            thread_name_prefix="tasm-router")
        self.repairer: Optional[RepairWorker] = None  # lazily started
        self._health_stop = threading.Event()
        self._health_thread: Optional[threading.Thread] = None
        self._health_next: dict[str, float] = {}   # down-node probe gate
        self._health_backoff: dict[str, float] = {}
        for name in self.addresses:  # eager dial; down nodes mark themselves
            try:
                self._channel(name)
            except OSError:
                self._down.add(name)
        if health_interval is not None:
            self._health_thread = threading.Thread(
                target=self._health_loop, name="tasm-router-health",
                daemon=True)
            self._health_thread.start()

    # ------------------------------------------------------------ channels
    def _channel(self, name: str) -> RemoteVideoStore:
        with self._lock:
            if self._closed:
                raise RuntimeError("cluster router is closed")
            ch = self._channels.get(name)
            if ch is None:
                # want_plans=True is load-bearing: multi-video results
                # rebuild their flat region list from the plan's video
                # order, and merges re-serialize through to_doc
                ch = RemoteVideoStore(
                    codec=self.codec, max_frame_bytes=self.max_frame_bytes,
                    want_plans=True, retries=self.node_retries,
                    timeout=self.timeout, **_parse_addr(self.addresses[name]))
                self._channels[name] = ch
            return ch

    def _mark_down(self, name: str) -> None:
        with self._lock:
            self._down.add(name)
            ch = self._channels.pop(name, None)
        if ch is not None:
            try:
                ch.close()
            except Exception:  # noqa: BLE001 - best-effort teardown
                pass

    def ping_nodes(self) -> dict[str, bool]:
        """Health-probe every node.  A node that answers rejoins the read
        set (per-video staleness marks survive — a revived node that
        missed a mutation stays excluded for those videos)."""
        out: dict[str, bool] = {}
        for name in sorted(self.addresses):
            try:
                self._channel(name).ping()
                with self._lock:
                    self._down.discard(name)
                out[name] = True
            except _CONN_ERRORS:
                self._mark_down(name)
                out[name] = False
        return out

    def _health_loop(self) -> None:
        """Periodic background ``ping_nodes``: live nodes are probed every
        (jittered) interval so a hang/death is noticed off the serving
        path, and down nodes rejoin automatically when they answer —
        probed with exponential backoff so a corpse isn't hammered."""
        interval = float(self.health_interval)
        while not self._health_stop.wait(interval *
                                         random.uniform(0.75, 1.25)):
            with self._lock:
                if self._closed:
                    return
                down = set(self._down)
            now = time.monotonic()
            for name in sorted(self.addresses):
                if name in down and now < self._health_next.get(name, 0.0):
                    continue
                try:
                    self._channel(name).ping()
                    with self._lock:
                        self._down.discard(name)
                    self._health_backoff.pop(name, None)
                    self._health_next.pop(name, None)
                except _CONN_ERRORS:
                    self._mark_down(name)
                    b = min(self._health_backoff.get(name, interval) * 2,
                            interval * 16)
                    self._health_backoff[name] = b
                    self._health_next[name] = time.monotonic() + b

    def _dial_node(self, name: str) -> RemoteVideoStore:
        """A FRESH connection to one node — repair streams ride their own
        socket (caller closes it) so bulk chunk frames never head-of-line
        block the shared serving channel."""
        with self._lock:
            if self._closed:
                raise RuntimeError("cluster router is closed")
            addr = self.addresses[name]
        return RemoteVideoStore(
            codec=self.codec, max_frame_bytes=self.max_frame_bytes,
            want_plans=True, timeout=self.timeout, **_parse_addr(addr))

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            chans = list(self._channels.values())
            self._channels.clear()
            repairer = self.repairer
        self._health_stop.set()
        if self._health_thread is not None:
            self._health_thread.join(timeout=5)
        if repairer is not None:
            repairer.stop()
        self._pool.shutdown(wait=True)
        for ch in chans:
            try:
                ch.close()
            except Exception:  # noqa: BLE001 - best-effort teardown
                pass

    def __enter__(self) -> "ClusterRouter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ----------------------------------------------------------- read side
    def _reader_name(self, video: str) -> Optional[str]:
        """First live, non-stale replica in placement order — primary
        first, so repeat scans land on a warm tile cache.  ``None`` if no
        replica currently qualifies; KeyError if the video is unplaced."""
        reps = self.placement.nodes_for(video)
        if not reps:
            raise KeyError(f"unknown video {video!r}")
        with self._lock:
            for n in reps:
                if n not in self._down and (video, n) not in self._stale:
                    return n
        return None

    def _ensure_consistent(self, video: str, name: str,
                           ch: RemoteVideoStore) -> bool:
        """Epoch-check a replica before first reading a video from it.
        The primary is authoritative (mutations land there first, and a
        primary that missed one is already stale-marked); any other
        replica must prove its epoch table covers every mutation the
        router has acknowledged — ``>=`` per SOT, because local tuners
        bump epochs independently of the router."""
        if name == self.placement.primary(video):
            return True
        with self._lock:
            if (video, name) in self._verified:
                return True
            expected = dict(self._epochs.get(video) or {})
        if expected:
            try:
                have = ch.epochs(video)
            except _CONN_ERRORS:
                self._mark_down(name)
                return False
            except KeyError:
                with self._lock:
                    self._stale.add((video, name))
                return False
            if not all(have.get(s, -1) >= e for s, e in expected.items()):
                with self._lock:  # pre-retile generation: never serve it
                    self._stale.add((video, name))
                return False
        with self._lock:
            self._verified.add((video, name))
        return True

    def _on_video(self, video: str, fn):
        """Run ``fn(channel)`` against the first consistent live replica,
        failing over on connection errors (the failed node is marked down
        so the next candidate is tried)."""
        last_err: Optional[BaseException] = None
        for _ in range(len(self.addresses) + 1):
            name = self._reader_name(video)
            if name is None:
                break
            try:
                ch = self._channel(name)
            except OSError as e:
                self._mark_down(name)
                last_err = e
                continue
            if not self._ensure_consistent(video, name, ch):
                last_err = last_err or wire.ConnectionClosed(
                    f"replica {name} is stale for {video!r}")
                continue
            try:
                return fn(ch)
            except _CONN_ERRORS as e:
                self._mark_down(name)
                last_err = e
        raise last_err or wire.ConnectionClosed(
            f"no live replica serves {video!r}")

    def _single_reader(self, videos) -> Optional[tuple[str,
                                                       RemoteVideoStore]]:
        """The one node currently serving ALL of ``videos``, epoch-checked
        — the fast path that forwards a whole plan in one RPC (and lets
        the node apply multi-video ``limit`` natively)."""
        names = set()
        for v in videos:
            n = self._reader_name(v)
            if n is None:
                return None
            names.add(n)
        if len(names) != 1:
            return None
        name = names.pop()
        try:
            ch = self._channel(name)
        except OSError:
            self._mark_down(name)
            return None
        if not all(self._ensure_consistent(v, name, ch) for v in videos):
            return None
        return name, ch

    # ---------------------------------------------------------------- scan
    def scan(self, videos, labels=None,
             frames: Optional[tuple[int, int]] = None) -> ClusterScanQuery:
        q = ClusterScanQuery(self, videos)
        if labels is not None:
            q = q.labels(labels)
        if frames is not None:
            q = q.frames(*frames)
        return q

    @staticmethod
    def _as_plan(query) -> ScanPlan:
        if isinstance(query, PhysicalPlan):
            return query.logical
        if isinstance(query, ScanQuery):
            return query.plan()
        if isinstance(query, ScanPlan):
            return query
        raise TypeError(f"cannot route {type(query).__name__}; want "
                        "ScanQuery, ScanPlan, or PhysicalPlan")

    def execute(self, query) -> ScanResult:
        return self._execute_plan(self._as_plan(query))

    def submit(self, query) -> Future:
        """Fire-and-collect on the router's pool (serving sessions)."""
        plan = self._as_plan(query)
        return self._pool.submit(self._execute_plan, plan)

    def serve(self, **_kw) -> RouterServingSession:
        """Concurrent-submission session (``max_batch`` etc. are node-side
        concerns: each node's shared session micro-batches its share)."""
        return RouterServingSession(self)

    def _execute_plan(self, plan: ScanPlan) -> ScanResult:
        one = self._single_reader(plan.videos)
        if one is not None:
            name, ch = one
            try:
                return ch.execute(plan)
            except _CONN_ERRORS:
                self._mark_down(name)  # fall through to per-video failover
        parts = split_plan(plan, lambda v: v)  # per-video routing units
        if len(parts) == 1:
            return self._exec_one(parts[0][1])
        if plan.limit is not None:
            # the engine spends a limit video-by-video in plan order;
            # sequential execution with a decremented budget reproduces
            # that exactly across nodes
            results, remaining = [], int(plan.limit)
            for _, sub in parts:
                if remaining <= 0:
                    results.append(ScanResult(
                        regions=[], stats=ScanStats(),
                        plan=PhysicalPlan(logical=sub),
                        regions_by_video={}))
                    continue
                r = self._exec_one(dataclasses.replace(sub,
                                                       limit=remaining))
                remaining -= sum(len(rs)
                                 for rs in r.regions_by_video.values())
                results.append(r)
            return merge_results(plan, results)
        results = _map_threads(self._exec_one, [sub for _, sub in parts])
        return merge_results(plan, results)

    def _exec_one(self, sub: ScanPlan) -> ScanResult:
        return self._on_video(sub.videos[0], lambda ch: ch.execute(sub))

    def execute_many(self, queries) -> list[ScanResult]:
        """Fan the batch out per node — each node gets ONE execute_many
        RPC with its plans (one submission wave into its shared session,
        so they micro-batch there) — and re-assemble results in strict
        submission order.  Cross-node plans and plans whose node dies
        mid-batch fall back to routed per-plan execution."""
        plans = [self._as_plan(q) for q in queries]
        results: list[Optional[ScanResult]] = [None] * len(plans)
        groups: dict[str, list[int]] = {}
        solo: list[int] = []
        for i, p in enumerate(plans):
            names = {self._reader_name(v) for v in p.videos}
            if len(names) == 1 and None not in names:
                groups.setdefault(names.pop(), []).append(i)
            else:
                solo.append(i)

        def run_batch(item):
            name, idxs = item
            try:
                ch = self._channel(name)
                vids = {v for i in idxs for v in plans[i].videos}
                if all(self._ensure_consistent(v, name, ch) for v in vids):
                    return list(zip(
                        idxs, ch.execute_many([plans[i] for i in idxs])))
            except _CONN_ERRORS:
                self._mark_down(name)
            return [(i, self._execute_plan(plans[i])) for i in idxs]

        for out in _map_threads(run_batch, list(groups.items())):
            for i, r in out:
                results[i] = r
        for i in solo:
            results[i] = self._execute_plan(plans[i])
        return results

    def lower(self, plan) -> PhysicalPlan:
        """Explain across the cluster: single-node plans lower remotely
        in one RPC; cross-node plans concatenate per-video lowerings."""
        plan = self._as_plan(plan)
        one = self._single_reader(plan.videos)
        if one is not None:
            name, ch = one
            try:
                return ch._explain(plan)
            except _CONN_ERRORS:
                self._mark_down(name)
        parts = [self._on_video(sub.videos[0],
                                lambda ch, s=sub: ch._explain(s))
                 for _, sub in split_plan(plan, lambda v: v)]
        return PhysicalPlan(
            logical=plan,
            sot_scans=[s for p in parts for s in p.sot_scans],
            lookup_s=sum(p.lookup_s for p in parts))

    # ------------------------------------------------------------ mutation
    def _mutate(self, video: str, fn):
        """Apply a mutation to every replica.  Succeeds if at least one
        replica applied it; replicas that failed at the connection level
        are marked down AND stale for this video (they missed a write and
        must not serve it).  Semantic errors propagate immediately —
        replicas hold identical state, so the first node's verdict is
        the cluster's."""
        reps = self.placement.nodes_for(video)
        if not reps:
            raise KeyError(f"unknown video {video!r}")
        result, applied = None, False
        first_err: Optional[BaseException] = None
        for node in reps:
            with self._lock:
                down = node in self._down
            if down:
                with self._lock:
                    self._stale.add((video, node))
                continue
            try:
                r = fn(self._channel(node))
            except _CONN_ERRORS as e:
                self._mark_down(node)
                with self._lock:
                    self._stale.add((video, node))
                first_err = first_err or e
                continue
            if not applied:
                result, applied = r, True
        if not applied:
            raise first_err or wire.ConnectionClosed(
                f"no live replica of {video!r}")
        with self._lock:  # epoch tables may have moved: re-verify replicas
            self._verified = {(v, n) for v, n in self._verified
                              if v != video}
        return result

    def add_video(self, name: str, *, encoder=None, policy=None,
                  cost_model=None, sot_len=None) -> None:
        self.placement.place(name)
        self._mutate(name, lambda ch: ch.add_video(
            name, encoder=encoder, policy=policy, cost_model=cost_model,
            sot_len=sot_len))

    def ingest(self, name: str, frames, *, detections=None,
               initial_layouts=None, **video_kw) -> IngestStats:
        """Write all replicas; the acknowledged epoch tables must agree
        (same physical generation everywhere) and become the expected
        table failover verifies against."""
        self.placement.place(name)
        acks: dict[str, dict[int, int]] = {}

        def one(ch):
            s = ch.ingest(name, frames, detections=detections,
                          initial_layouts=initial_layouts, **video_kw)
            return s, ch.last_ingest_epochs

        stats, table = None, None
        reps = self.placement.nodes_for(name)
        first_err: Optional[BaseException] = None
        for node in reps:
            with self._lock:
                down = node in self._down
            if down:
                with self._lock:
                    self._stale.add((name, node))
                continue
            try:
                s, t = one(self._channel(node))
            except _CONN_ERRORS as e:
                self._mark_down(node)
                with self._lock:
                    self._stale.add((name, node))
                first_err = first_err or e
                continue
            acks[node] = t
            if stats is None:
                stats, table = s, t
        if stats is None:
            raise first_err or wire.ConnectionClosed(
                f"no live replica accepted ingest of {name!r}")
        if any(t != table for t in acks.values()):
            raise RuntimeError(
                f"replica epoch tables diverged ingesting {name!r}: {acks}")
        with self._lock:
            self._epochs[name] = dict(table)
            self._verified = {(v, n) for v, n in self._verified
                              if v != name}
        return stats

    def add_detections(self, video: str, detections_by_frame: dict) -> None:
        self._mutate(video, lambda ch: ch.add_detections(
            video, detections_by_frame))

    def add_metadata(self, video: str, frame: int, label: str,
                     x1: int, y1: int, x2: int, y2: int) -> None:
        self._mutate(video, lambda ch: ch.add_metadata(
            video, frame, label, x1, y1, x2, y2))

    def retile(self, video: str, sot_id: int, new_layout) -> float:
        dt = self._mutate(video,
                          lambda ch: ch.retile(video, sot_id, new_layout))
        if dt:  # layout actually changed: every replica bumped this SOT
            with self._lock:
                tbl = self._epochs.setdefault(video, {})
                tbl[int(sot_id)] = tbl.get(int(sot_id), 0) + 1
        return dt

    # -------------------------------------------------- repair / rebalance
    def _repair_worker(self) -> RepairWorker:
        with self._lock:
            if self._closed:
                raise RuntimeError("cluster router is closed")
            if self.repairer is None:
                self.repairer = RepairWorker(self)
            return self.repairer

    def expected_epochs(self, video: str) -> dict[int, int]:
        """The layout-generation table this video is expected to serve
        (ingest acks + router-acknowledged retiles) — what failover and
        the repair commit verify against."""
        with self._lock:
            return dict(self._epochs.get(video) or {})

    def _repair_source(self, video: str, *, exclude=()) -> Optional[str]:
        """Next live, non-stale replica a copy can stream from."""
        exclude = set(exclude)
        with self._lock:
            for n in self.placement.nodes_for(video):
                if n in exclude or n in self._down:
                    continue
                if (video, n) in self._stale:
                    continue
                return n
        return None

    def _apply_repair(self, job) -> None:
        """Flip placement after a verified copy: ``dst`` joins the
        replica list (first for moves — it's the new primary), the dead
        replicas this copy replaced leave it.  Verified marks clear so
        the epoch check runs against the fresh replica before its first
        read — a rebuilt replica can never serve a pre-retile
        generation."""
        with self._lock:
            drop = set(job.drop)
            reps = [n for n in self.placement.nodes_for(job.video)
                    if n != job.dst and n not in drop]
            reps = [job.dst] + reps if job.dst_primary else reps + [job.dst]
            self.placement.assign(job.video, reps)
            self._stale = {(v, n) for v, n in self._stale
                           if not (v == job.video and n == job.dst)}
            self._verified = {(v, n) for v, n in self._verified
                              if v != job.video}

    def repair(self, video: Optional[str] = None,
               node: Optional[str] = None) -> list[dict]:
        """Enqueue background copy jobs restoring the replication factor.
        ``video=`` heals one video; ``node=`` treats that node as
        permanently lost and re-replicates everything it held; neither
        heals every under-replicated video (currently-down nodes count as
        lost).  Returns the enqueued job descriptors immediately — the
        copies run off the serving path; poll :meth:`repair_status` (or
        :meth:`drain_repair`) for completion.  Reads keep routing to live
        replicas throughout, and each video's assignment only flips after
        its copy verifies."""
        with self._lock:
            lost = set(self._down)
        if node is not None:
            if node not in self.addresses:
                raise KeyError(f"unknown node {node!r}")
            lost.add(node)
        if video is not None:
            if video not in self.placement.assignments:
                raise KeyError(f"unknown video {video!r}")
            targets = [video]
        else:
            targets = sorted(self.placement.assignments)
        jobs = []
        for v in targets:
            reps = self.placement.nodes_for(v)
            live = [n for n in reps if n not in lost]
            k = min(self.placement.replication,
                    len([n for n in self.addresses if n not in lost]))
            if len(live) >= k:
                continue
            src = self._repair_source(v, exclude=lost)
            drop = tuple(n for n in reps if n in lost)
            candidates = [n for n in self.placement._ring_walk(v)
                          if n not in lost and n not in reps]
            worker = self._repair_worker()
            for dst in candidates[:k - len(live)]:
                jobs.append(worker.submit(v, src or "", dst,
                                          kind="replicate", drop=drop))
        return [j.describe() for j in jobs]

    def rebalance(self, apply: bool = False) -> dict:
        """The moves :meth:`PlacementMap.plan_rebalance` suggests — and,
        with ``apply=True``, their application: each moved video streams
        to its ring owner in the background and flips to it as primary
        only after verification.  A ring owner that already holds a
        replica flips immediately (no data to move)."""
        moves = self.placement.plan_rebalance()
        doc: dict = {"moves": {v: list(m) for v, m in sorted(moves.items())},
                     "applied": bool(apply), "jobs": [], "flipped": []}
        if not apply:
            return doc
        with self._lock:
            lost = set(self._down)
        k = self.placement.replication
        for v, (_cur, new) in sorted(moves.items()):
            reps = self.placement.nodes_for(v)
            if new in reps:
                with self._lock:
                    self.placement.assign(
                        v, [new] + [n for n in reps if n != new])
                    self._verified = {(vv, n) for vv, n in self._verified
                                      if vv != v}
                doc["flipped"].append(v)
                continue
            if new in lost:
                continue    # cannot move onto a dead node; plan again later
            src = self._repair_source(v, exclude={new})
            worker = self._repair_worker()
            # dst becomes primary; the old replica list is kept behind it,
            # trimmed back to K
            doc["jobs"].append(worker.submit(
                v, src or "", new, kind="move", drop=tuple(reps[k - 1:]),
                dst_primary=True).describe())
        return doc

    def repair_status(self) -> dict:
        """Per-job progress (chunks/bytes/retries/re-streams) plus
        worker-lifetime totals — the admin RPC the CLI polls."""
        with self._lock:
            worker = self.repairer
        if worker is None:
            return {"jobs": [], "stats": dataclasses.asdict(RepairStats())}
        return {"jobs": worker.jobs(),
                "stats": dataclasses.asdict(worker.stats())}

    def drain_repair(self, timeout: Optional[float] = None) -> dict:
        """Barrier: wait for every queued copy to finish, then return
        :meth:`repair_status`.  Re-raises the most recent job failure."""
        with self._lock:
            worker = self.repairer
        if worker is not None:
            worker.drain(timeout)
        return self.repair_status()

    def join_node(self, name: str, addr) -> dict:
        """Register a node at runtime: address book + placement ring.
        Existing assignments are untouched (future placements may land on
        it, and :meth:`repair` / :meth:`rebalance` can copy onto it)."""
        with self._lock:
            if self._closed:
                raise RuntimeError("cluster router is closed")
            known = self.addresses.get(name)
            if known is not None and known != addr:
                raise ValueError(
                    f"node {name!r} is already registered at {known!r}")
            self.addresses[name] = addr
            if name not in self.placement.nodes:
                self.placement.add_node(name)
        try:
            self._channel(name).ping()
            with self._lock:
                self._down.discard(name)
            alive = True
        except _CONN_ERRORS:
            self._mark_down(name)
            alive = False
        return {"node": name, "alive": alive,
                "nodes": sorted(self.addresses)}

    # ------------------------------------------------------------- tuning
    def _sum_tuner(self, fn) -> TunerStats:
        total = TunerStats()
        for name in sorted(self.addresses):
            with self._lock:
                if name in self._down:
                    continue
            try:
                t = fn(self._channel(name))
            except _CONN_ERRORS:
                self._mark_down(name)
                continue
            for f in dataclasses.fields(TunerStats):
                setattr(total, f.name,
                        getattr(total, f.name) + getattr(t, f.name))
        return total

    def drain_tuner(self, timeout: Optional[float] = None) -> TunerStats:
        return self._sum_tuner(lambda ch: ch.drain_tuner(timeout))

    def tuner_stats(self) -> TunerStats:
        return self._sum_tuner(lambda ch: ch.tuner_stats())

    def _sum_cache(self, fn) -> CacheStats:
        """Sum one :class:`CacheStats` per live node (counters add;
        ``evictions_by_reason`` merges per reason)."""
        total = CacheStats()
        for name in sorted(self.addresses):
            with self._lock:
                if name in self._down:
                    continue
            try:
                c = fn(self._channel(name))
            except _CONN_ERRORS:
                self._mark_down(name)
                continue
            for f in dataclasses.fields(CacheStats):
                if f.name == "evictions_by_reason":
                    for r, n in c.evictions_by_reason.items():
                        total.evictions_by_reason[r] = \
                            total.evictions_by_reason.get(r, 0) + n
                else:
                    setattr(total, f.name,
                            getattr(total, f.name) + getattr(c, f.name))
        return total

    def drain_prefetch(self, timeout: Optional[float] = None) -> CacheStats:
        """Prefetch barrier across every live node; summed cache stats."""
        return self._sum_cache(lambda ch: ch.drain_prefetch(timeout))

    def config(self) -> dict:
        """Per-node resolved configuration documents (``None`` for a down
        node) — the router twin of :meth:`VideoStore.config`."""
        nodes: dict[str, Optional[dict]] = {}
        for name in sorted(self.addresses):
            with self._lock:
                if name in self._down:
                    nodes[name] = None
                    continue
            try:
                doc = self._channel(name).config()
                nodes[name] = {k: v.to_doc() for k, v in doc.items()}
            except _CONN_ERRORS:
                self._mark_down(name)
                nodes[name] = None
        return {"nodes": nodes}

    # ------------------------------------------------------------- catalog
    def videos(self) -> list[str]:
        return sorted(self.placement.assignments)

    def __contains__(self, name: str) -> bool:
        return name in self.placement.assignments

    def __len__(self) -> int:
        return len(self.placement.assignments)

    def epochs(self, video: str) -> dict[int, int]:
        return self._on_video(video, lambda ch: ch.epochs(video))

    def stats(self) -> dict:
        """Cluster-wide accounting: per-node engine stats (``None`` for a
        down node) plus summed totals and the placement table."""
        nodes: dict[str, Optional[dict]] = {}
        for name in sorted(self.addresses):
            with self._lock:
                if name in self._down:
                    nodes[name] = None
                    continue
            try:
                nodes[name] = self._channel(name).stats()
            except _CONN_ERRORS:
                self._mark_down(name)
                nodes[name] = None
        live = [d for d in nodes.values() if d]
        with self._lock:
            down = sorted(self._down)
        return {
            "videos": self.videos(),
            "replication": self.placement.replication,
            "placement": {v: list(r)
                          for v, r in self.placement.assignments.items()},
            "nodes": nodes,
            "down": down,
            "tiles_decoded_total": sum(d["tiles_decoded_total"]
                                       for d in live),
            "pixels_decoded_total": sum(d["pixels_decoded_total"]
                                        for d in live),
            "storage_bytes": sum(d["storage_bytes"] for d in live),
            "cache": _sum_cache_docs(d.get("cache") or {} for d in live),
        }


# ============================================================== front end
class ClusterRouterServer(VideoStoreServer):
    """The PR 5 socket front end over a :class:`ClusterRouter` — clients
    speak the identical protocol to a cluster or a single node.  Adds
    placement/health introspection ops on top."""

    def _handle(self, op: str, req: dict):
        router: ClusterRouter = self.store
        if op == "ping":
            doc = super()._handle(op, req)
            with router._lock:
                down = sorted(router._down)
            doc.update(cluster=True, nodes=sorted(router.addresses),
                       down=down)
            return doc
        if op == "placement":
            return router.placement.to_doc()
        if op == "node_health":
            return router.ping_nodes()
        if op == "repair":
            return router.repair(video=req.get("video"),
                                 node=req.get("node"))
        if op == "rebalance":
            return router.rebalance(apply=bool(req.get("apply")))
        if op == "repair_status":
            return router.repair_status()
        if op == "drain_repair":
            return router.drain_repair(req.get("timeout"))
        if op == "join_node":
            return router.join_node(req["name"], req["addr"])
        return super()._handle(op, req)


class ClusterClient(RemoteVideoStore):
    """Talk to a :class:`ClusterRouterServer`: the full declarative
    surface of :class:`RemoteVideoStore` (scans, batches, sessions,
    mutations — routed transparently) plus cluster introspection."""

    def placement(self) -> dict:
        return self._call("placement")

    def node_health(self) -> dict:
        """Router-side health probe of every node (revives answerers)."""
        return self._call("node_health")

    def repair(self, video: Optional[str] = None,
               node: Optional[str] = None) -> list:
        """Enqueue background re-replication; returns job descriptors."""
        params: dict = {}
        if video is not None:
            params["video"] = video
        if node is not None:
            params["node"] = node
        return self._call("repair", **params)

    def rebalance(self, apply: bool = False) -> dict:
        return self._call("rebalance", apply=bool(apply))

    def repair_status(self) -> dict:
        return self._call("repair_status")

    def drain_repair(self, timeout: Optional[float] = None) -> dict:
        """Block until every queued copy job finishes (or *timeout*)."""
        dl = None if self._timeout is None else self._timeout + (timeout or 0.0)
        return self._call("drain_repair", timeout=timeout, _deadline=dl)

    def join_node(self, name: str, addr) -> dict:
        """Register a (possibly fresh) node with the router at runtime."""
        return self._call("join_node", name=name, addr=addr)
