"""Declarative scan queries with an explicit plan/execute split.

The engine's query surface is a chainable builder::

    store.scan("cam0").labels("car").frames(0, 96).execute()
    store.scan(["cam0", "cam1"]).labels("car", "person").limit(32).explain()

Three stages, each a first-class object:

- :class:`ScanQuery`    — the builder; immutable, every chained call returns
                          a fresh query, so partial queries can be forked.
- :class:`ScanPlan`     — the *logical* plan: videos, CNF predicate, frame
                          range, limit.  No storage details.
- :class:`PhysicalPlan` — the lowered plan: the exact SOTs and tile indices
                          to decode per video, with pixel/tile/cost estimates
                          from the §4.1 what-if cost interface.  Produced by
                          ``VideoStore.lower``; ``.explain()`` returns it
                          without decoding anything.

Execution goes through the serving layer (``scheduler.py``): plans are
batches of explicit :class:`SOTScan` work units, so a scheduler can merge
overlapping SOT scans from concurrent queries into one decode and serve
repeat tiles from the epoch-keyed tile cache (``tile_cache.py``); see
``engine.py`` for the full picture.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

from repro.core.semantic_index import parse_predicate


# --------------------------------------------------------------------- stats
@dataclass
class ScanStats:
    """Per-query accounting.  ``pixels_decoded`` counts pixels *actually*
    decoded on behalf of this query, at 8x8-block granularity: an
    ROI-restricted fetch adds its masked blocks x frames, and a tile
    served from the cache (or an earlier consumer's decode in a merged
    batch) adds nothing — a fully warm repeat scan reports 0.  A
    covering-miss re-decode is charged in full: when the cache holds a
    partial entry the fetch widens to the union of the old and new masks
    at the max of both depths (entries never shrink), so the triggering
    query can be charged more than its own mask.  Like
    ``cache_misses``, shared fresh work is charged to the first query
    (submission order) that needed it, so summing over history counts each
    decoded block once.  For ``.decode(False)`` estimation-only scans it
    falls back to the plan's ``est_pixels``.  ``tiles_decoded`` stays the
    *planned* tile-stream-open estimate (it fills for estimation-only scans
    too).  ``cache_hits``/``cache_misses`` count what the serving layer
    actually did: of the tiles this query needed, how many were served from
    the tile cache (or a merged batch decode) vs freshly decoded.  A
    freshly decoded tile shared by several merged queries is charged as a
    miss only to the first query (submission order) that needed it;
    likewise in a merged batch each group's decode wall seconds land in the
    first consumer's ``decode_s``, so summing over history counts shared
    work once (a solo ``execute`` keeps the old wall-clock-of-decode-phase
    meaning).

    ``retile_s`` — seconds of policy-driven re-encoding charged to THIS
    query.  Non-zero only under ``tuning="inline"``, where re-tiles run
    synchronously inside the scan that triggered them.  Under
    ``tuning="background"`` (the ``VideoStore`` default) queries are never
    charged tuning work: re-tiles run on the tuner thread and are
    observable only via :class:`~repro.core.tuner.TunerStats` and
    ``store.drain_tuner()``.

    ``marshal_s``/``payload_bytes``/``transport`` — reply-marshalling
    accounting, stamped by the serving layer as the result crosses a
    process boundary (all-zero/empty for in-process execution).
    ``marshal_s`` is seconds spent building the reply doc and packing its
    payload; ``payload_bytes`` is the packed size of the region arrays
    (npz blob bytes on the socket transport, raw shared bytes on shm);
    ``transport`` is ``"shm"`` or ``"npz"`` — what this result actually
    rode."""
    lookup_s: float = 0.0
    decode_s: float = 0.0
    retile_s: float = 0.0
    detect_s: float = 0.0
    pixels_decoded: float = 0.0
    tiles_decoded: float = 0.0
    cache_hits: int = 0
    cache_misses: int = 0
    regions: int = 0
    marshal_s: float = 0.0
    payload_bytes: float = 0.0
    transport: str = ""

    @property
    def tiles_fetched(self) -> int:
        """Tiles this query obtained through the serving layer."""
        return self.cache_hits + self.cache_misses

    @property
    def cache_hit_rate(self) -> float:
        return self.cache_hits / self.tiles_fetched if self.tiles_fetched \
            else 0.0

    @property
    def query_s(self) -> float:
        """Paper's per-query time: index lookup + decode."""
        return self.lookup_s + self.decode_s

    @property
    def total_s(self) -> float:
        return self.lookup_s + self.decode_s + self.retile_s + self.detect_s

    # -- wire ---------------------------------------------------------------
    def to_doc(self) -> dict:
        """JSON-able field dict (wire layer; properties recompute)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_doc(cls, doc: dict) -> "ScanStats":
        return cls(**doc)


@dataclass
class ScanResult:
    regions: list  # (frame, bbox, pixels) — single video; see regions_by_video
    stats: ScanStats
    plan: Optional["PhysicalPlan"] = None
    regions_by_video: dict = field(default_factory=dict)

    # -- wire ---------------------------------------------------------------
    def to_doc(self, include_plan: bool = True) -> dict:
        """Wire doc: JSON-able except the region pixel arrays, which stay
        ``np.ndarray`` for the wire layer to pack into the frame's npz
        payload.  Only ``regions_by_video`` is serialized — the flat
        ``regions`` list shares its arrays and is rebuilt on the far side
        from the plan's video order, so each crop ships once.
        ``include_plan=False`` (clients with ``want_plans=False``) skips
        the O(regions) plan-doc marshalling entirely — it runs on the
        server's shared dispatcher thread."""
        videos = list(self.plan.logical.videos) if self.plan is not None \
            else sorted(self.regions_by_video)
        return {
            "videos": videos,
            "stats": self.stats.to_doc(),
            "plan": self.plan.to_doc()
            if include_plan and self.plan is not None else None,
            "rbv": {v: [[f, list(b), px] for f, b, px in rs]
                    for v, rs in self.regions_by_video.items()},
        }

    @classmethod
    def from_doc(cls, doc: dict) -> "ScanResult":
        rbv = {v: [(int(f), tuple(b), px) for f, b, px in rs]
               for v, rs in doc["rbv"].items()}
        videos = list(doc["videos"])
        if len(videos) == 1:
            regions = list(rbv.get(videos[0], []))
        else:  # multi-video flat list prepends the video (scheduler order)
            regions = [(v, f, b, px) for v in videos
                       for f, b, px in rbv.get(v, [])]
        return cls(regions=regions,
                   stats=ScanStats.from_doc(doc["stats"]),
                   plan=PhysicalPlan.from_doc(doc["plan"])
                   if doc.get("plan") is not None else None,
                   regions_by_video=rbv)


# ------------------------------------------------------------- logical plan
@dataclass(frozen=True)
class ScanPlan:
    """Logical plan: what to retrieve, with no storage details."""
    videos: tuple[str, ...]
    cnf: tuple[tuple[str, ...], ...]          # CNF over labels; () = all
    frame_range: Optional[tuple[int, int]] = None
    limit: Optional[int] = None
    decode: bool = True

    @property
    def flat_labels(self) -> tuple[str, ...]:
        return tuple(sorted({l for clause in self.cnf for l in clause}))

    def describe(self) -> str:
        pred = " AND ".join("(" + " OR ".join(c) + ")" for c in self.cnf) \
            or "<all labels>"
        rng = f" FRAMES [{self.frame_range[0]}, {self.frame_range[1]})" \
            if self.frame_range else ""
        lim = f" LIMIT {self.limit}" if self.limit is not None else ""
        return f"SCAN {','.join(self.videos)} WHERE {pred}{rng}{lim}"

    # -- wire ---------------------------------------------------------------
    def to_doc(self) -> dict:
        return {"videos": list(self.videos),
                "cnf": [list(c) for c in self.cnf],
                "frame_range": list(self.frame_range)
                if self.frame_range else None,
                "limit": self.limit, "decode": self.decode}

    @classmethod
    def from_doc(cls, doc: dict) -> "ScanPlan":
        rng = doc.get("frame_range")
        return cls(videos=tuple(doc["videos"]),
                   cnf=tuple(tuple(c) for c in doc["cnf"]),
                   frame_range=(int(rng[0]), int(rng[1])) if rng else None,
                   limit=doc.get("limit"), decode=bool(doc.get("decode", True)))


# ------------------------------------------------------------ physical plan
@dataclass
class SOTScan:
    """One physical work unit: decode `tile_idxs` of one SOT.

    ``blocks_by_tile`` is the plan's block-coverage mask — for every tile in
    ``tile_idxs``, the sorted tuple of tile-local 8x8-block indices the
    query's boxes intersect, or ``None`` for "every block" (full-tile
    decode).  An *empty* dict marks a full-tile plan (``roi_decode=False``
    or a pre-ROI plan): the scheduler then decodes whole tiles, exactly the
    PR-3 path.  Masks are minted against ``epoch``'s layout; a stale plan
    recomputes them from ``boxes_by_frame`` at fetch time."""
    video: str
    sot_id: int
    epoch: int                      # layout epoch the plan was made against
    tile_idxs: tuple[int, ...]
    n_frames: int                   # relative frames to decode (from SOT start)
    boxes_by_frame: dict            # frame -> [BBox], restricted to this SOT
    query_range: tuple[int, int]    # effective temporal range (for policies)
    labels: tuple[str, ...] = ()    # resolved flat labels (for policies)
    est_pixels: float = 0.0
    est_tiles: float = 0.0
    est_cost_s: float = 0.0
    blocks_by_tile: dict = field(default_factory=dict)

    # -- wire ---------------------------------------------------------------
    def to_doc(self) -> dict:
        """JSON-able doc.  Int-keyed dicts become ``[key, value]`` pair
        lists (JSON objects cannot key on ints) and block masks keep the
        ``None`` = every-block convention."""
        return {
            "video": self.video, "sot_id": self.sot_id, "epoch": self.epoch,
            "tile_idxs": list(self.tile_idxs), "n_frames": self.n_frames,
            "boxes_by_frame": [[f, [list(b) for b in boxes]]
                               for f, boxes in
                               sorted(self.boxes_by_frame.items())],
            "query_range": list(self.query_range),
            "labels": list(self.labels),
            "est_pixels": self.est_pixels, "est_tiles": self.est_tiles,
            "est_cost_s": self.est_cost_s,
            "blocks_by_tile": [[t, None if m is None else list(m)]
                               for t, m in
                               sorted(self.blocks_by_tile.items())],
        }

    @classmethod
    def from_doc(cls, doc: dict) -> "SOTScan":
        return cls(
            video=doc["video"], sot_id=int(doc["sot_id"]),
            epoch=int(doc["epoch"]),
            tile_idxs=tuple(int(t) for t in doc["tile_idxs"]),
            n_frames=int(doc["n_frames"]),
            boxes_by_frame={int(f): [tuple(int(c) for c in b) for b in boxes]
                            for f, boxes in doc["boxes_by_frame"]},
            query_range=tuple(int(v) for v in doc["query_range"]),
            labels=tuple(doc["labels"]),
            est_pixels=doc["est_pixels"], est_tiles=doc["est_tiles"],
            est_cost_s=doc["est_cost_s"],
            blocks_by_tile={int(t): None if m is None
                            else tuple(int(b) for b in m)
                            for t, m in doc["blocks_by_tile"]})


@dataclass
class PhysicalPlan:
    """Lowered plan: exact SOTs/tiles to decode plus cost estimates."""
    logical: ScanPlan
    sot_scans: list[SOTScan] = field(default_factory=list)
    lookup_s: float = 0.0

    @property
    def est_pixels(self) -> float:
        return sum(s.est_pixels for s in self.sot_scans)

    @property
    def est_tiles(self) -> float:
        return sum(s.est_tiles for s in self.sot_scans)

    @property
    def est_cost_s(self) -> float:
        return sum(s.est_cost_s for s in self.sot_scans)

    @property
    def n_regions(self) -> int:
        return sum(len(b) for s in self.sot_scans
                   for b in s.boxes_by_frame.values())

    def describe(self) -> str:
        lines = [self.logical.describe()]
        for s in self.sot_scans:
            roi = ""
            if s.blocks_by_tile:
                n_sel = sum(len(m) for m in s.blocks_by_tile.values()
                            if m is not None)
                full = sum(1 for m in s.blocks_by_tile.values() if m is None)
                roi = f" blocks={n_sel}+{full}full" if full \
                    else f" blocks={n_sel}"
            lines.append(
                f"  {s.video} sot={s.sot_id} epoch={s.epoch} "
                f"tiles={list(s.tile_idxs)}{roi} frames<={s.n_frames} "
                f"~{s.est_pixels / 1e6:.2f}Mpx est={s.est_cost_s * 1e3:.2f}ms")
        lines.append(
            f"  total: {len(self.sot_scans)} SOTs, {self.est_tiles:.0f} tile "
            f"streams, {self.est_pixels / 1e6:.2f}Mpx, "
            f"est {self.est_cost_s * 1e3:.2f}ms, {self.n_regions} regions")
        return "\n".join(lines)

    def __str__(self) -> str:  # pragma: no cover - convenience
        return self.describe()

    # -- wire ---------------------------------------------------------------
    def to_doc(self) -> dict:
        return {"logical": self.logical.to_doc(), "lookup_s": self.lookup_s,
                "sot_scans": [s.to_doc() for s in self.sot_scans]}

    @classmethod
    def from_doc(cls, doc: dict) -> "PhysicalPlan":
        return cls(logical=ScanPlan.from_doc(doc["logical"]),
                   sot_scans=[SOTScan.from_doc(s) for s in doc["sot_scans"]],
                   lookup_s=doc.get("lookup_s", 0.0))


# ----------------------------------------------------- cluster split/merge
def split_plan(plan: ScanPlan, key_of) -> list[tuple[object, ScanPlan]]:
    """Split a multi-video logical plan into ``(key, subplan)`` runs for
    cross-node execution: consecutive videos sharing ``key_of(video)``
    (their owning node) form one subplan, in plan order.  Keeping the
    runs contiguous — rather than grouping all of a node's videos into
    one subplan — preserves the engine's *sequential* semantics exactly:
    executing the runs in list order visits videos in the same order a
    single store would, which is what makes a decremented ``limit``
    bit-identical (the engine spends the limit video-by-video in plan
    order).  The subplans inherit the parent's predicate/range/decode;
    the caller owns limit accounting across runs."""
    groups: list[tuple[object, list[str]]] = []
    for v in plan.videos:
        k = key_of(v)
        if groups and groups[-1][0] == k:
            groups[-1][1].append(v)
        else:
            groups.append((k, [v]))
    return [(k, dataclasses.replace(plan, videos=tuple(vs)))
            for k, vs in groups]


def merge_results(plan: ScanPlan, parts: list) -> ScanResult:
    """Re-assemble per-node partial :class:`ScanResult`\\ s of
    :func:`split_plan` subplans into one result for the original plan —
    bit-identical to a single store executing it: ``regions_by_video``
    is the union, the flat ``regions`` list is rebuilt in the parent
    plan's video order (multi-video tuples prepend the video name, the
    scheduler's convention), and stats fields are summed.  The merged
    physical plan concatenates the parts' SOT scans in run order when
    every part carried one (else ``None``)."""
    rbv: dict = {}
    for r in parts:
        rbv.update(r.regions_by_video)
    if len(plan.videos) == 1:
        regions = list(rbv.get(plan.videos[0], []))
    else:
        regions = [(v, f, b, px) for v in plan.videos
                   for f, b, px in rbv.get(v, [])]
    # numeric stats sum; the (string) transport field merges to the common
    # value when every part rode the same transport, else "mixed"
    transports = {r.stats.transport for r in parts if r.stats.transport}
    stats = ScanStats(**{
        f.name: sum(getattr(r.stats, f.name) for r in parts)
        for f in dataclasses.fields(ScanStats) if f.name != "transport"},
        transport=transports.pop() if len(transports) == 1
        else "mixed" if transports else "")
    merged_plan = None
    if parts and all(r.plan is not None for r in parts):
        merged_plan = PhysicalPlan(
            logical=plan,
            sot_scans=[s for r in parts for s in r.plan.sot_scans],
            lookup_s=sum(r.plan.lookup_s for r in parts))
    return ScanResult(regions=regions, stats=stats, plan=merged_plan,
                      regions_by_video=rbv)


# ------------------------------------------------------------------ builder
class ScanQuery:
    """Chainable, immutable scan-query builder bound to a ``VideoStore``.

    ``labels`` accepts a single label, several labels (one disjunctive
    clause, matching the old ``scan(["car", "person"])``), or a full CNF
    (sequence of clauses).  With no ``labels`` call the scan targets every
    label known to the index.
    """

    def __init__(self, engine, videos):
        self._engine = engine
        if isinstance(videos, str):
            videos = (videos,)
        self._videos: tuple[str, ...] = tuple(videos)
        self._cnf: Optional[tuple[tuple[str, ...], ...]] = None
        self._range: Optional[tuple[int, int]] = None
        self._limit: Optional[int] = None
        self._decode: bool = True

    # -- chain ---------------------------------------------------------------
    def _clone(self) -> "ScanQuery":
        # type(self): a RemoteScanQuery (client.py) forks into its own kind
        q = type(self)(self._engine, self._videos)
        q._cnf, q._range = self._cnf, self._range
        q._limit, q._decode = self._limit, self._decode
        return q

    def labels(self, *labels) -> "ScanQuery":
        q = self._clone()
        if not labels:
            q._cnf = ()  # sentinel: all labels, resolved at lowering
        elif len(labels) == 1 and not isinstance(labels[0], str):
            q._cnf = parse_predicate(labels[0])  # list or CNF
        else:
            q._cnf = parse_predicate(list(labels))  # one disjunctive clause
        return q

    def frames(self, lo: int, hi: int) -> "ScanQuery":
        if lo >= hi:
            raise ValueError(f"empty frame range [{lo}, {hi})")
        q = self._clone()
        q._range = (int(lo), int(hi))
        return q

    def limit(self, n: int) -> "ScanQuery":
        if n < 0:
            raise ValueError("limit must be >= 0")
        q = self._clone()
        q._limit = int(n)
        return q

    def decode(self, flag: bool = True) -> "ScanQuery":
        q = self._clone()
        q._decode = bool(flag)
        return q

    # -- plan / execute ------------------------------------------------------
    def plan(self) -> ScanPlan:
        if self._cnf is None:
            raise ValueError("no predicate: call .labels(...) before "
                             ".plan()/.explain()/.execute()")
        return ScanPlan(videos=self._videos, cnf=self._cnf,
                        frame_range=self._range, limit=self._limit,
                        decode=self._decode)

    def explain(self) -> PhysicalPlan:
        """Lower to a physical plan (SOTs, tiles, estimated cost) WITHOUT
        decoding, running policies, or recording history."""
        return self._engine.lower(self.plan())

    def execute(self) -> ScanResult:
        return self._engine.execute(self._engine.lower(self.plan()))

    # -- wire ---------------------------------------------------------------
    def to_doc(self) -> dict:
        """Builder state as a JSON-able doc (``cnf`` may still be unset —
        unlike :meth:`plan` this never raises, so partial queries ship)."""
        return {"videos": list(self._videos),
                "cnf": None if self._cnf is None
                else [list(c) for c in self._cnf],
                "frame_range": list(self._range) if self._range else None,
                "limit": self._limit, "decode": self._decode}

    @classmethod
    def from_doc(cls, engine, doc: dict) -> "ScanQuery":
        q = cls(engine, tuple(doc["videos"]))
        cnf = doc.get("cnf")
        q._cnf = None if cnf is None else tuple(tuple(c) for c in cnf)
        rng = doc.get("frame_range")
        q._range = (int(rng[0]), int(rng[1])) if rng else None
        lim = doc.get("limit")
        q._limit = None if lim is None else int(lim)
        q._decode = bool(doc.get("decode", True))
        return q
