"""Object detectors feeding the semantic index (paper §3.3, §5.2.4).

No GPU model is available, so detection quality/cost regimes are modelled on
the paper's three settings, all derived from generator ground truth except
background subtraction (which is computed from real frame differences):

- ``full``   : YOLOv3-analogue — every object, tight boxes, every frame.
- ``strided``: full quality every k-th frame, boxes propagated between
               detections (the "YOLOv3 every five frames" edge regime).
- ``tiny``   : Tiny-YOLO-analogue — misses a (seeded) fraction of objects and
               jitters boxes (the paper found this yields poor layouts).
- ``bgsub``  : real frame-difference foreground extraction (KNN-subtraction
               stand-in; genuinely fails on camera pan, as in the paper).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.layout import BBox


@dataclass
class DetectorConfig:
    kind: str = "full"      # full | strided | tiny | bgsub
    stride: int = 1         # detect every k-th frame (strided)
    miss_rate: float = 0.0  # fraction of objects missed (tiny: ~0.5)
    jitter: int = 0         # bbox jitter in px (tiny: ~4)
    seconds_per_frame: float = 0.05  # modelled detector latency (YOLOv3-ish)
    seed: int = 0


def detect(frames: np.ndarray, gt_detections, cfg: DetectorConfig,
           frame_range: Optional[tuple[int, int]] = None):
    """Returns (detections_by_frame, modelled_seconds).

    detections_by_frame: frame -> [(label, bbox)].
    """
    lo, hi = frame_range if frame_range else (0, len(gt_detections))
    lo, hi = max(lo, 0), min(hi, len(gt_detections))
    rng = np.random.default_rng(cfg.seed + lo)
    out: dict[int, list] = {}

    if cfg.kind == "bgsub":
        secs = 0.002 * (hi - lo)  # cheap
        for f in range(max(lo, 1), hi):
            diff = np.abs(frames[f] - frames[f - 1]) > 25.0
            if not diff.any():
                continue
            ys, xs = np.nonzero(diff)
            # single foreground box around all motion (KNN-subtraction-grade)
            box = (int(ys.min()), int(xs.min()), int(ys.max()) + 1, int(xs.max()) + 1)
            out[f] = [("object", box)]
        return out, secs

    stride = cfg.stride if cfg.kind == "strided" else 1
    detected_frames = list(range(lo, hi, stride))
    secs = cfg.seconds_per_frame * len(detected_frames)
    H = frames.shape[1] if frames is not None else 10 ** 9
    W = frames.shape[2] if frames is not None else 10 ** 9
    for f in detected_frames:
        dets = []
        for label, bbox in gt_detections[f]:
            if cfg.kind == "tiny" or cfg.miss_rate > 0:
                miss = cfg.miss_rate if cfg.miss_rate > 0 else 0.5
                if rng.random() < miss:
                    continue
            box = bbox
            jit = cfg.jitter if cfg.jitter else (4 if cfg.kind == "tiny" else 0)
            if jit:
                dy, dx = rng.integers(-jit, jit + 1, size=2)
                box = (int(np.clip(bbox[0] + dy, 0, H - 1)),
                       int(np.clip(bbox[1] + dx, 0, W - 1)),
                       int(np.clip(bbox[2] + dy, 1, H)),
                       int(np.clip(bbox[3] + dx, 1, W)))
            dets.append((label, box))
        if dets:
            out[f] = dets
    # strided: propagate each detection to the skipped frames (cheap tracking)
    if stride > 1:
        filled: dict[int, list] = {}
        for f in range(lo, hi):
            anchor = lo + ((f - lo) // stride) * stride
            if anchor in out:
                filled[f] = out[anchor]
        out = filled
    return out, secs
