"""A B+-tree with range scans — the storage structure behind the semantic
index (paper §3.2: "a B-tree clustered on (video, label, time)").

Plain-Python, order-configurable, property-tested against a dict oracle in
tests/test_btree.py.  Keys are arbitrary comparable tuples; values accumulate
in insertion order (duplicate keys allowed — multiple boxes per key).
"""
from __future__ import annotations

import bisect
from typing import Any, Iterator, Optional


class _Node:
    __slots__ = ("keys", "children", "values", "next")

    def __init__(self, leaf: bool):
        self.keys: list = []
        self.children: Optional[list] = None if leaf else []
        self.values: Optional[list] = [] if leaf else None
        self.next: Optional[_Node] = None  # leaf chain for range scans

    @property
    def is_leaf(self) -> bool:
        return self.children is None


class BPlusTree:
    def __init__(self, order: int = 32):
        assert order >= 4
        self.order = order
        self.root = _Node(leaf=True)
        self._size = 0

    def __len__(self) -> int:
        return self._size

    # -- insert -------------------------------------------------------------
    def insert(self, key, value) -> None:
        self._size += 1
        split = self._insert(self.root, key, value)
        if split is not None:
            mid_key, right = split
            new_root = _Node(leaf=False)
            new_root.keys = [mid_key]
            new_root.children = [self.root, right]
            self.root = new_root

    def _insert(self, node: _Node, key, value):
        if node.is_leaf:
            i = bisect.bisect_right(node.keys, key)
            if i > 0 and node.keys[i - 1] == key:
                node.values[i - 1].append(value)
                self._size -= 0  # duplicate key: values accumulate
                return None
            node.keys.insert(i, key)
            node.values.insert(i, [value])
            if len(node.keys) > self.order:
                return self._split_leaf(node)
            return None
        i = bisect.bisect_right(node.keys, key)
        split = self._insert(node.children[i], key, value)
        if split is not None:
            mid_key, right = split
            node.keys.insert(i, mid_key)
            node.children.insert(i + 1, right)
            if len(node.keys) > self.order:
                return self._split_inner(node)
        return None

    def _split_leaf(self, node: _Node):
        mid = len(node.keys) // 2
        right = _Node(leaf=True)
        right.keys = node.keys[mid:]
        right.values = node.values[mid:]
        node.keys = node.keys[:mid]
        node.values = node.values[:mid]
        right.next = node.next
        node.next = right
        return right.keys[0], right

    def _split_inner(self, node: _Node):
        mid = len(node.keys) // 2
        right = _Node(leaf=False)
        mid_key = node.keys[mid]
        right.keys = node.keys[mid + 1:]
        right.children = node.children[mid + 1:]
        node.keys = node.keys[:mid]
        node.children = node.children[:mid + 1]
        return mid_key, right

    # -- lookup -------------------------------------------------------------
    def _leaf_for(self, key) -> _Node:
        node = self.root
        while not node.is_leaf:
            i = bisect.bisect_right(node.keys, key)
            node = node.children[i]
        return node

    def get(self, key) -> list:
        leaf = self._leaf_for(key)
        i = bisect.bisect_left(leaf.keys, key)
        if i < len(leaf.keys) and leaf.keys[i] == key:
            return list(leaf.values[i])
        return []

    def scan(self, lo, hi) -> Iterator[tuple[Any, list]]:
        """Yield (key, values) for lo <= key < hi, in key order."""
        leaf = self._leaf_for(lo)
        i = bisect.bisect_left(leaf.keys, lo)
        while leaf is not None:
            while i < len(leaf.keys):
                k = leaf.keys[i]
                if k >= hi:
                    return
                yield k, list(leaf.values[i])
                i += 1
            leaf = leaf.next
            i = 0

    def keys(self) -> Iterator:
        node = self.root
        while not node.is_leaf:
            node = node.children[0]
        while node is not None:
            yield from node.keys
            node = node.next

    def depth(self) -> int:
        d, node = 1, self.root
        while not node.is_leaf:
            node = node.children[0]
            d += 1
        return d
