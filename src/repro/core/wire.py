"""Wire layer for cross-process serving (``server.py`` / ``client.py``).

Framing: every message is one *frame* — a 4-byte big-endian payload length
followed by the payload.  The payload's first byte tags the codec::

    b"M"  msgpack (when the optional ``msgpack`` package is installed)
    b"J"  UTF-8 JSON (always available — the CI fallback)

Both sides decode by tag, so a JSON-only client can talk to an
msgpack-capable server and vice versa; the sender picks the best codec it
has (override with ``REPRO_WIRE=json|msgpack`` or the ``codec=`` argument).

Messages are JSON-able dicts *except* numpy arrays: :func:`dumps` walks the
doc, replaces each ``np.ndarray`` with a ``{"__nd__": i}`` reference and
ships the arrays in a single npz blob riding alongside the doc (raw bytes
under msgpack, base64 under JSON).  :func:`loads` reverses the walk, so
region crops and ingest frames round-trip bit-identically with their
dtype/shape intact (``allow_pickle`` stays off — object arrays are
rejected, not smuggled).

Payload transport is swappable per frame: instead of the npz blob a frame
may carry an ``"s"`` shared-memory descriptor — ``{"seg": name, "items":
[[offset, shape, dtype], ...]}`` indexed like the array list — produced by
a ``segment_writer`` (the server's :class:`~repro.core.shm.SegmentPool`)
and resolved by an ``shm_reader`` (the client maps the segment and builds
zero-copy numpy views).  A writer returning ``None`` (remote peer, pool
exhausted, /dev/shm missing) falls back to the npz blob in the same
frame format, so both transports decode through one :func:`loads`.

Oversized frames are rejected on BOTH sides before any payload allocation:
:func:`dumps` raises when the encoded frame would exceed ``max_bytes`` and
:func:`read_frame` raises after reading only the 4-byte header, so a
misbehaving (or malicious) peer cannot force the server to materialize an
arbitrarily large buffer.  The server answers with an error frame and
closes that connection; other connections are unaffected.
"""
from __future__ import annotations

import base64
import io
import json
import os
import socket
import struct
from typing import Any, Optional

import numpy as np

try:  # optional: baked into the container; CI's bare install falls to JSON
    import msgpack as _msgpack
except ImportError:  # pragma: no cover - environment-dependent
    _msgpack = None

#: refuse frames larger than this by default (header-checked, pre-alloc)
DEFAULT_MAX_FRAME_BYTES = 256 << 20  # 256 MiB

_HEADER = struct.Struct(">I")
_TAG_MSGPACK = b"M"
_TAG_JSON = b"J"
_ND_KEY = "__nd__"


class WireError(Exception):
    """Malformed, oversized, or undecodable frame."""


class ConnectionClosed(WireError):
    """The peer closed the socket (mid-frame close is a plain WireError)."""


def default_codec() -> str:
    """'msgpack' when available, else 'json'; ``REPRO_WIRE`` overrides."""
    env = os.environ.get("REPRO_WIRE")
    if env:
        if env not in ("json", "msgpack"):
            raise ValueError(f"REPRO_WIRE={env!r}; want json|msgpack")
        if env == "msgpack" and _msgpack is None:
            raise ValueError("REPRO_WIRE=msgpack but msgpack is not "
                             "installed")
        return env
    return "msgpack" if _msgpack is not None else "json"


# ----------------------------------------------------------- ndarray walk
def _extract_arrays(obj: Any, arrays: list[np.ndarray]) -> Any:
    """Deep-copy ``obj`` with every ndarray swapped for an ``__nd__`` ref.
    Tuples become lists (the codecs don't distinguish them; the query-layer
    ``from_doc`` restorers re-tuple what must be hashable)."""
    if isinstance(obj, np.ndarray):
        if obj.dtype.hasobject:
            # reject on the SENDER: np.savez would silently pickle these,
            # and the receiver's allow_pickle=False rejection surfaces as
            # an uncorrelatable connection-level error
            raise WireError(f"object-dtype array ({obj.dtype}) cannot "
                            "cross the wire")
        arrays.append(obj)
        return {_ND_KEY: len(arrays) - 1}
    if isinstance(obj, dict):
        return {k: _extract_arrays(v, arrays) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_extract_arrays(v, arrays) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, np.bool_):
        return bool(obj)
    return obj


def _restore_arrays(obj: Any, lookup) -> Any:
    if isinstance(obj, dict):
        if set(obj) == {_ND_KEY}:
            return lookup(obj[_ND_KEY])
        return {k: _restore_arrays(v, lookup) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_restore_arrays(v, lookup) for v in obj]
    return obj


def _pack_npz(arrays: list[np.ndarray]) -> tuple[bytes, list]:
    """Pack arrays into one npz blob, STACKING same-(dtype, shape) arrays
    into a single member: a scan result carries one small crop per region,
    and zip-member overhead (header + crc per entry) would otherwise
    dominate the wire cost of a warm scan.  Returns ``(blob, index)`` where
    ``index[i] = [member, pos]`` locates array ``i`` (``pos`` = -1 for a
    member holding exactly that array un-stacked)."""
    groups: dict[tuple, list[int]] = {}
    for i, a in enumerate(arrays):
        groups.setdefault((str(a.dtype), a.shape), []).append(i)
    members: dict[str, np.ndarray] = {}
    index: list = [None] * len(arrays)
    for g, idxs in enumerate(groups.values()):
        name = f"g{g}"
        if len(idxs) == 1:
            members[name] = arrays[idxs[0]]
            index[idxs[0]] = [name, -1]
        else:
            members[name] = np.stack([arrays[i] for i in idxs])
            for pos, i in enumerate(idxs):
                index[i] = [name, pos]
    buf = io.BytesIO()
    np.savez(buf, **members)
    return buf.getvalue(), index


# ------------------------------------------------------------ dumps/loads
def dumps(doc: dict, *, codec: Optional[str] = None,
          max_bytes: int = DEFAULT_MAX_FRAME_BYTES,
          segment_writer=None, on_payload=None) -> bytes:
    """Encode one message to a tagged payload (no length prefix).

    ``segment_writer(arrays)`` — when given — is offered the frame's
    array list first; if it returns a shared-memory descriptor doc the
    frame ships that (``"s"``) instead of the npz blob, and if it returns
    ``None`` the npz path proceeds unchanged.  ``on_payload(clean,
    transport, payload_bytes)`` fires after array packing (the dominant
    marshalling cost) but *before* the envelope encode, so a caller can
    stamp marshalling accounting into the outgoing doc itself."""
    codec = codec or default_codec()
    arrays: list[np.ndarray] = []
    clean = _extract_arrays(doc, arrays)
    blob, index, shm_doc = None, None, None
    if arrays:
        if segment_writer is not None:
            shm_doc = segment_writer(arrays)
        if shm_doc is None:
            blob, index = _pack_npz(arrays)
    if on_payload is not None:
        nbytes = len(blob) if blob is not None else \
            sum(int(a.nbytes) for a in arrays)
        on_payload(clean, "shm" if shm_doc is not None else "npz", nbytes)
    if codec == "msgpack":
        if _msgpack is None:
            raise WireError("msgpack codec requested but not installed")
        payload = _TAG_MSGPACK + _msgpack.packb(
            {"d": clean, "z": blob, "zi": index, "s": shm_doc},
            use_bin_type=True)
    else:
        payload = _TAG_JSON + json.dumps(
            {"d": clean,
             "z": base64.b64encode(blob).decode("ascii") if blob else None,
             "zi": index, "s": shm_doc},
            separators=(",", ":")).encode("utf-8")
    if len(payload) > max_bytes:
        raise WireError(f"frame of {len(payload)} bytes exceeds the "
                        f"{max_bytes}-byte limit")
    return payload


def loads(payload: bytes, *, shm_reader=None) -> dict:
    """Decode a tagged payload back to its message doc.

    ``shm_reader(shm_doc)`` — when given — resolves an ``"s"``
    shared-memory descriptor to the list of arrays it describes (index-
    aligned with the frame's ``__nd__`` refs).  A frame carrying ``"s"``
    with no reader installed raises: silently returning refs would hand
    the caller descriptor dicts where arrays belong."""
    if not payload:
        raise WireError("empty frame payload")
    tag, body = payload[:1], payload[1:]
    try:
        if tag == _TAG_MSGPACK:
            if _msgpack is None:
                raise WireError("received an msgpack frame but msgpack is "
                                "not installed (peer should fall back to "
                                "JSON)")
            msg = _msgpack.unpackb(body, raw=False,
                                   max_bin_len=len(body),
                                   strict_map_key=False)
        elif tag == _TAG_JSON:
            msg = json.loads(body.decode("utf-8"))
        else:
            raise WireError(f"unknown frame codec tag {tag!r}")
        if not isinstance(msg, dict) or "d" not in msg:
            raise WireError("frame payload is not a message envelope")
        blob = msg.get("z")
        if isinstance(blob, str):  # JSON ships the npz blob base64'd
            blob = base64.b64decode(blob)
        lookup = None
        shm_doc = msg.get("s")
        if shm_doc is not None:
            if shm_reader is None:
                raise WireError("frame carries a shared-memory payload "
                                "but no shm reader is installed")
            views = shm_reader(shm_doc)

            def lookup(i: int, views=views):
                return views[i]

        elif blob:
            npz = np.load(io.BytesIO(blob), allow_pickle=False)
            index = msg.get("zi") or []
            members: dict[str, np.ndarray] = {}

            def lookup(i: int, npz=npz, index=index, members=members):
                name, pos = index[i]
                if name not in members:
                    members[name] = npz[name]  # decompress each member once
                arr = members[name]
                return arr if pos < 0 else arr[pos]

        return _restore_arrays(msg["d"], lookup)
    except WireError:
        raise
    except Exception as e:  # corrupt msgpack/json/base64/npz alike
        raise WireError(f"undecodable frame: {type(e).__name__}: {e}") \
            from e


# ---------------------------------------------------------------- sockets
def write_frame(sock: socket.socket, doc: dict, *,
                codec: Optional[str] = None,
                max_bytes: int = DEFAULT_MAX_FRAME_BYTES) -> None:
    payload = dumps(doc, codec=codec, max_bytes=max_bytes)
    sock.sendall(_HEADER.pack(len(payload)) + payload)


def _read_exact(sock: socket.socket, n: int, *, eof_ok: bool) -> bytes:
    """Read exactly ``n`` bytes, tolerant of arbitrarily fragmented
    ``recv`` returns (a peer dribbling one byte at a time, or a header
    split across TCP segments, reassembles identically).  Fills a single
    preallocated buffer via ``recv_into`` so a heavily fragmented frame
    costs no per-chunk allocations or a final join."""
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        r = sock.recv_into(view[got:], min(n - got, 1 << 20))
        if r == 0:
            if eof_ok and got == 0:
                raise ConnectionClosed("peer closed the connection")
            raise WireError("connection closed mid-frame")
        got += r
    return bytes(buf)


def read_frame(sock: socket.socket, *,
               max_bytes: int = DEFAULT_MAX_FRAME_BYTES,
               shm_reader=None) -> dict:
    """Read one frame; raises :class:`ConnectionClosed` on a clean EOF
    between frames, :class:`WireError` on truncation, oversize, or an
    undecodable payload.  The length header is validated BEFORE the payload
    is read, so an oversized frame never allocates its claimed size."""
    header = _read_exact(sock, _HEADER.size, eof_ok=True)
    (length,) = _HEADER.unpack(header)
    if length > max_bytes:
        raise WireError(f"peer announced a {length}-byte frame; limit is "
                        f"{max_bytes}")
    if length == 0:
        raise WireError("zero-length frame")
    return loads(_read_exact(sock, length, eof_ok=False),
                 shm_reader=shm_reader)


# -------------------------------------------------------------- RPC docs
def error_doc(rid, exc: BaseException) -> dict:
    """Error response frame for a failed request (``rid`` may be None when
    the request was too malformed to carry an id)."""
    return {"id": rid, "ok": False,
            "error": {"type": type(exc).__name__, "message": str(exc)}}


def result_doc(rid, value) -> dict:
    return {"id": rid, "ok": True, "value": value}
