"""ScanScheduler: merged, cached execution of physical plans (serving
layer, part 2).

The engine's :class:`~repro.core.query.PhysicalPlan` makes every scan an
explicit list of :class:`~repro.core.query.SOTScan` work units, which is
exactly what a scheduler needs:

- **Merge rule** — within a batch, SOTScans from different plans targeting
  the same ``(video, sot_id)`` become one *group fetch*: each member's tile
  needs are resolved against the SOT's **current** layout (stale-epoch plans
  recompute ``tiles_intersecting``, exactly like the old ``_decode_one``),
  the union of tile indices is fetched once through the
  :class:`~repro.core.tile_cache.TileCache`, and every member crops its
  regions from the shared arrays.  A shared ``(sot, tile)`` is therefore
  decoded at most once per batch — and zero times when cached.
- **Worker pool** — group fetches run on one long-lived thread pool shared
  by all callers (the old per-execute pool is gone).
- **Serial-equivalent semantics** — after the parallel fetch phase, each
  plan is *finished* (regions assembled, policy hooks run, history recorded)
  strictly in submission order.  If a policy hook re-tiles a SOT (inline
  tuning mode), the epoch bump makes the batch's group fetch stale; later
  plans in the batch detect the mismatch and re-fetch at the new epoch.
  Per-query regions are thus bit-identical to running the same plans
  through serial ``execute()`` calls, and the cache can never serve
  pre-retile pixels (keys carry the epoch).
- **Policy hooks via the tuner** — the per-SOT hooks are dispatched through
  the engine's :class:`~repro.core.tuner.PhysicalTuner`: under
  ``tuning="inline"`` they observe + retile synchronously here (charged to
  the query's ``retile_s``, preserving the pre-tuner semantics bit-for-bit);
  under ``tuning="background"`` (the default) they only append observations
  to the tuner's bounded workload log, and retiling happens asynchronously
  on the tuner thread — the scan path never pays re-encode latency.
- **Stats attribution** — each query's :class:`ScanStats` reports
  ``cache_hits``/``cache_misses`` over the tiles it needed; a freshly
  decoded tile is charged as a miss to the first plan (submission order)
  that needed it, and as a hit to every later one.

:class:`ServingSession` (``store.serve()``) is the concurrent front end: a
dispatcher thread drains a submission queue and micro-batches whatever is
queued into one ``execute_many`` call, so overlapping scans from concurrent
callers merge without any coordination on their part.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Optional

import numpy as np

from repro.core.layout import BBox, TileLayout, block_coverage
from repro.core.query import (PhysicalPlan, ScanPlan, ScanQuery, ScanResult,
                              ScanStats, SOTScan)
from repro.core.tile_cache import TileCache, WorkloadPredictor

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.engine import VideoStore

#: one decode group: every SOTScan in a batch hitting this (video, sot_id)
GroupKey = tuple[str, int]


def _resolve_needs(ss: SOTScan, rec) -> tuple[tuple[int, ...], dict]:
    """The (tile indices, per-tile block masks) ``ss`` needs under the
    SOT's *current* layout.  Planned values when the epoch still matches;
    recomputed from the requested boxes after a retile (stale plan).  The
    mask dict is empty for full-tile plans (``roi_decode=False``); in an
    ROI plan a mask of ``None`` means every block of that tile."""
    if rec.epoch == ss.epoch:
        return ss.tile_idxs, ss.blocks_by_tile
    if ss.blocks_by_tile:   # ROI plan: recompute coverage under new layout
        bbt = block_coverage(rec.layout, ss.boxes_by_frame)
        return tuple(sorted(bbt)), bbt
    needed: set[int] = set()
    for boxes in ss.boxes_by_frame.values():
        for box in boxes:
            needed.update(rec.layout.tiles_intersecting(box))
    return tuple(sorted(needed)), {}


@dataclass
class _GroupFetch:
    """Decoded state of one group at one epoch."""
    epoch: int
    layout: TileLayout
    tiles: dict[int, np.ndarray]
    fresh: set[int]                       # decoded this fetch (cache misses)
    need: dict[int, tuple[int, ...]]      # id(SOTScan) -> resolved tiles
    pixels_by_tile: dict[int, float] = field(default_factory=dict)
    seconds: float = 0.0                  # wall time of this fetch
    claimed: set[int] = field(default_factory=set)
    time_claimed: bool = False


class ScanScheduler:
    """Executes batches of physical plans with merged, cached decodes.

    One scheduler per :class:`VideoStore`; ``lock`` serializes batches (and
    engine-level retiles), so concurrent callers of ``VideoStore.execute``
    are safe, while *merging* happens for plans submitted together through
    :meth:`execute_many` or a :class:`ServingSession`.
    """

    def __init__(self, engine: "VideoStore", *,
                 max_workers: Optional[int] = None,
                 cache: Optional[TileCache] = None):
        self.engine = engine
        self.cache = cache if cache is not None else TileCache()
        self.max_workers = max_workers or engine.max_decode_workers
        self.lock = threading.RLock()
        self._pool: Optional[ThreadPoolExecutor] = None
        self._pool_lock = threading.Lock()
        # predictive prefetch (CacheConfig.prefetch): the tuner's workload
        # tap feeds the predictor; detected sliding windows enqueue decode
        # jobs for the next SOTs on the worker pool (see _prefetch_job)
        self._predictor: Optional[WorkloadPredictor] = None
        self._prefetch_cv = threading.Condition()
        self._prefetch_pending: set[GroupKey] = set()
        self._prefetch_inflight = 0

    # ----------------------------------------------------------- frontend
    def _normalize(self, plan) -> PhysicalPlan:
        if isinstance(plan, ScanQuery):
            plan = plan.plan()
        if isinstance(plan, ScanPlan):
            plan = self.engine.lower(plan)
        if not isinstance(plan, PhysicalPlan):
            raise TypeError(f"cannot execute {type(plan).__name__}; want "
                            "ScanQuery, ScanPlan or PhysicalPlan")
        return plan

    def execute(self, plan) -> ScanResult:
        return self.execute_many([plan])[0]

    def execute_many(self, plans) -> list[ScanResult]:
        """Execute plans as one batch: shared-tile decodes are merged, then
        each plan finishes (assembly + policy hooks) in submission order."""
        pplans = [self._normalize(p) for p in plans]
        with self.lock:
            return self._execute_batch(pplans)

    def session(self, **kw) -> "ServingSession":
        return ServingSession(self, **kw)

    # -------------------------------------------------------------- batch
    def _ensure_pool(self) -> ThreadPoolExecutor:
        with self._pool_lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self.max_workers,
                    thread_name_prefix="tasm-decode")
            return self._pool

    def offload(self, fn, *args):
        """Run ``fn(*args)`` on the decode worker pool WITHOUT taking the
        batch lock — the serving layer uses this to marshal replies (doc
        building + payload packing) off its dispatcher thread, and those
        jobs must not queue behind in-flight batches.  Returns the
        future.  Like ``execute``, a call after ``shutdown`` re-creates
        the pool on demand; only a submit RACING the shutdown raises
        ``RuntimeError`` (callers fall back to running inline)."""
        return self._ensure_pool().submit(fn, *args)

    def shutdown(self) -> None:
        """Release the worker pool (idempotent; a later batch re-creates
        it on demand)."""
        with self.lock:
            with self._pool_lock:
                pool, self._pool = self._pool, None
            if pool is not None:
                pool.shutdown(wait=True)

    # ----------------------------------------------------------- prefetch
    def note_scan(self, sot_scans: "list[SOTScan]") -> None:
        """Workload tap (called by ``tuner.on_scan`` under the batch lock,
        for EVERY scan regardless of tuning mode or policy): feed the
        sliding-window predictor and enqueue prefetch decode jobs for the
        SOTs it expects next.  No-op unless ``CacheConfig.prefetch``."""
        cfg = self.cache.config
        if not cfg.prefetch or self.cache.budget_bytes <= 0:
            return
        if self._predictor is None:
            self._predictor = WorkloadPredictor(depth=cfg.prefetch_depth)
        for ss in sot_scans:
            for sid in self._predictor.observe(ss.video, ss.sot_id):
                self._maybe_prefetch(ss.video, sid)

    def _maybe_prefetch(self, video: str, sot_id: int) -> None:
        """Enqueue one predicted SOT's decode, single-flight per
        ``(video, sot_id)``; predictions past the end of the video (the
        window sliding off the edge) are dropped here."""
        entry = self.engine._videos.get(video)
        if entry is None or not 0 <= sot_id < len(entry.store.sots):
            return
        gkey = (video, sot_id)
        with self._prefetch_cv:
            if gkey in self._prefetch_pending:
                return
            self._prefetch_pending.add(gkey)
            self._prefetch_inflight += 1
        try:
            self._ensure_pool().submit(self._prefetch_job, video, sot_id)
        except BaseException:
            with self._prefetch_cv:
                self._prefetch_pending.discard(gkey)
                self._prefetch_inflight -= 1
                self._prefetch_cv.notify_all()
            raise

    def _prefetch_job(self, video: str, sot_id: int) -> None:
        """Decode one predicted SOT's tiles (full depth, full blocks — a
        full entry serves ANY later sub-request bit-identically) and admit
        them with ``put(prefetch=True)`` (never evicting a hotter entry).

        Charging: this decode belongs to no query — it never touches a
        ``ScanStats``.  The work lands in the store's decode totals and in
        ``CacheStats.prefetch_issued``; a scan that later hits the entry
        records an ordinary cache hit with zero pixels charged (exactly
        the shared-decode first-consumer rule, with the prefetcher as the
        consumer that already paid).  Epoch safety is structural: entries
        carry the epoch read before the decode, a retile racing us bumps
        it, and we re-check + purge after the puts, so stale pixels are
        never served and never squat on the budget."""
        gkey = (video, sot_id)
        try:
            entry = self.engine._videos.get(video)
            if entry is None or not 0 <= sot_id < len(entry.store.sots):
                return
            rec = entry.store.sots[sot_id]
            epoch = rec.epoch
            n_frames = rec.frame_end - rec.frame_start
            tiles = []
            for t in range(rec.layout.n_tiles):
                cov = self.cache.coverage((video, sot_id, epoch, t))
                if cov is not None and cov[0] >= n_frames and cov[1] is None:
                    continue           # already fully resident
                tiles.append(t)
            if not tiles:
                return
            self.cache.note_prefetch_issued(len(tiles))
            dec = entry.store.decode_tiles(sot_id, tiles, n_frames=n_frames)
            if rec.epoch == epoch:
                for t, arr in dec.items():
                    self.cache.put((video, sot_id, epoch, t), arr,
                                   prefetch=True)
            if rec.epoch != epoch:
                self.cache.invalidate(video, sot_id, before_epoch=rec.epoch)
        except Exception:
            # best-effort by contract: a lost race (drop_video, store-level
            # retile deleting files mid-read) abandons the prediction
            pass
        finally:
            with self._prefetch_cv:
                self._prefetch_pending.discard(gkey)
                self._prefetch_inflight -= 1
                self._prefetch_cv.notify_all()

    def drain_prefetch(self, timeout: Optional[float] = None) -> None:
        """Deterministic prefetch barrier: block until every prefetch job
        enqueued before this call has completed (tests and benchmarks use
        it to make 'the next window is already resident' assertable)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._prefetch_cv:
            while self._prefetch_inflight:
                remaining = None if deadline is None \
                    else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"drain_prefetch timed out with "
                        f"{self._prefetch_inflight} jobs in flight")
                self._prefetch_cv.wait(remaining)

    def _execute_batch(self, pplans: list[PhysicalPlan]) -> list[ScanResult]:
        groups: dict[GroupKey, list[tuple[int, SOTScan]]] = {}
        for i, pp in enumerate(pplans):
            if not pp.logical.decode:
                continue
            for ss in pp.sot_scans:
                groups.setdefault((ss.video, ss.sot_id), []).append((i, ss))

        fetched: dict[GroupKey, _GroupFetch] = {}
        batch_decode_s = 0.0
        if groups:
            keys = sorted(groups)
            t0 = time.perf_counter()
            if len(keys) == 1:
                k = keys[0]
                fetched[k] = self._fetch(k, [ss for _, ss in groups[k]])
            else:
                pool = self._ensure_pool()
                fn = lambda k: self._fetch(k, [ss for _, ss in groups[k]])
                for k, f in zip(keys, pool.map(fn, keys)):
                    fetched[k] = f
            batch_decode_s = time.perf_counter() - t0

        results = [self._finish_one(i, pp, groups, fetched, batch_decode_s,
                                    single_plan=len(pplans) == 1)
                   for i, pp in enumerate(pplans)]
        if self.engine.dirty:
            self.engine.save()
        return results

    def _fetch(self, gkey: GroupKey, members: list[SOTScan]) -> _GroupFetch:
        """Decode one group: union of the members' (current-layout) tile
        needs, each tile through the cache.  Block masks union across
        members, so a shared tile decodes each needed block at most once;
        a cached entry covering a member's mask (full tile, or a superset
        ROI) is a hit, and a covering miss re-decodes the union of the old
        entry's mask and the new need (never shrinking coverage)."""
        t0 = time.perf_counter()
        video, sot_id = gkey
        entry = self.engine.video(video)
        rec = entry.store.sots[sot_id]
        epoch = rec.epoch
        need: dict[int, tuple[int, ...]] = {}
        # per-tile decode depth: the deepest member that needs the tile (a
        # group-wide max would re-decode warm shallow tiles whenever any
        # deeper query shares the group)
        depth: dict[int, int] = {}
        # per-tile block mask: union over members; None = full tile
        masks: dict[int, object] = {}
        stale_seen = False
        for ss in members:
            stale_seen |= ss.epoch != epoch
            tiles, bbt = _resolve_needs(ss, rec)
            need[id(ss)] = tiles
            for t in tiles:
                depth[t] = max(depth.get(t, 0), ss.n_frames)
                m = bbt.get(t) if bbt else None
                if t not in masks:
                    masks[t] = None if m is None else set(m)
                elif masks[t] is not None:
                    masks[t] = None if m is None else masks[t] | set(m)
        for t, m in masks.items():
            # a union that grew to every block IS a full-tile need:
            # normalize to None so the cached entry serves later
            # whole-tile requests too (None covers everything)
            if m is not None and len(m) == rec.layout.tile_blocks(t):
                masks[t] = None
        if stale_seen:
            # a retile outdated this plan; if it was a store-level retile
            # (engine-path ones purge on the spot) dead-epoch entries are
            # still squatting on the byte budget — purge is idempotent
            self.cache.invalidate(video, sot_id, before_epoch=epoch)
        out: dict[int, np.ndarray] = {}
        to_decode: dict[int, object] = {}        # tile -> mask
        decode_depth: dict[int, int] = {}        # tile -> decode depth
        for t in sorted(depth):
            key = (video, sot_id, epoch, t)
            arr = self.cache.get(key, depth[t], blocks=masks[t])
            if arr is not None:
                out[t] = arr
                continue
            nf, m = depth[t], masks[t]
            cov = self.cache.coverage(key)
            if cov is not None:
                # widen to cover the existing entry too, so the re-decode
                # can replace it (put never shrinks depth or coverage)
                nf = max(nf, cov[0])
                m = None if (m is None or cov[1] is None) else m | cov[1]
                if m is not None and len(m) == rec.layout.tile_blocks(t):
                    m = None
            to_decode[t] = m
            decode_depth[t] = nf
        fresh: set[int] = set()
        pixels_by_tile: dict[int, float] = {}
        if to_decode:
            # the whole merged group goes down in ONE decode_tiles call —
            # per-tile depths ride along, so the batched backend can fuse
            # every (tile, GOP, mask) selection into one dispatch
            blocks = {t: (None if m is None else tuple(sorted(m)))
                      for t, m in to_decode.items()}
            dec = entry.store.decode_tiles(sot_id, sorted(to_decode),
                                           n_frames=decode_depth,
                                           blocks=blocks)
            for t, arr in dec.items():
                out[t] = arr
                fresh.add(t)
                m = blocks[t]
                n_blocks = rec.layout.tile_blocks(t) if m is None else len(m)
                pixels_by_tile[t] = float(n_blocks * 64 * arr.shape[0])
                self.cache.put((video, sot_id, epoch, t), arr, blocks=m)
        return _GroupFetch(epoch=epoch, layout=rec.layout,
                           tiles=out, fresh=fresh, need=need,
                           pixels_by_tile=pixels_by_tile,
                           seconds=time.perf_counter() - t0)

    # ----------------------------------------------------------- per plan
    def _finish_one(self, idx: int, pplan: PhysicalPlan,
                    groups: dict[GroupKey, list[tuple[int, SOTScan]]],
                    fetched: dict[GroupKey, _GroupFetch],
                    batch_decode_s: float, single_plan: bool) -> ScanResult:
        engine = self.engine
        plan = pplan.logical
        stats = ScanStats(lookup_s=pplan.lookup_s)
        for ss in pplan.sot_scans:
            # tiles_decoded stays the planned estimate; pixels_decoded is
            # *actual* work for decoding scans (accumulated per fresh tile
            # below) and falls back to the estimate for .decode(False)
            if not plan.decode:
                stats.pixels_decoded += ss.est_pixels
            stats.tiles_decoded += ss.est_tiles

        regions_by_video: dict[str, list] = {v: [] for v in plan.videos}
        if plan.decode and pplan.sot_scans:
            if single_plan:
                # old executor semantics: wall time of the decode phase
                stats.decode_s = batch_decode_s
            for ss in pplan.sot_scans:
                gkey = (ss.video, ss.sot_id)
                rec = engine.video(ss.video).store.sots[ss.sot_id]
                f = fetched.get(gkey)
                if f is None or f.epoch != rec.epoch:
                    # an earlier plan's policy hook re-tiled this SOT (or the
                    # group was never fetched): re-fetch at the new epoch for
                    # this plan and the batch's remaining consumers
                    rest = [s for j, s in groups.get(gkey, []) if j >= idx]
                    f = self._fetch(gkey, rest or [ss])
                    fetched[gkey] = f
                if not single_plan and not f.time_claimed:
                    # merged batch: a group's fetch seconds are charged to
                    # its first consumer (like fresh-tile misses), so
                    # summing decode_s over history counts shared work once
                    f.time_claimed = True
                    stats.decode_s += f.seconds
                my_tiles = f.need.get(id(ss))
                if my_tiles is None:
                    my_tiles, _ = _resolve_needs(ss, rec)
                for t in my_tiles:
                    if t in f.fresh and t not in f.claimed:
                        f.claimed.add(t)
                        stats.cache_misses += 1
                        stats.pixels_decoded += f.pixels_by_tile.get(t, 0.0)
                    else:
                        stats.cache_hits += 1
                out = regions_by_video[ss.video]
                for frame, boxes in sorted(ss.boxes_by_frame.items()):
                    rel = frame - rec.frame_start
                    for box in boxes:
                        out.append((frame, box,
                                    _crop(f.layout, f.tiles, rel, box)))

        # policy hooks, serially per SOT, dispatched through the tuner:
        # inline mode observes + retiles here (charged to this query's
        # retile_s; any retile invalidates this batch's fetch via the epoch
        # bump), background mode only emits observations to the tuner's
        # workload log (retile_s stays 0 — tuning work lands in TunerStats)
        stats.retile_s += engine.tuner.on_scan(pplan.sot_scans)

        regions: list = []
        if len(plan.videos) == 1:
            regions = regions_by_video[plan.videos[0]]
        else:
            for v in plan.videos:
                regions.extend((v, f2, box, px)
                               for f2, box, px in regions_by_video[v])
        stats.regions = len(regions)
        engine.history.append(stats)
        for v in plan.videos:
            engine.video(v).history.append(stats)
        return ScanResult(regions=regions, stats=stats, plan=pplan,
                          regions_by_video=regions_by_video)


# --------------------------------------------------------------- serving
_STOP = object()


class ServingSession:
    """Concurrent submission surface over a :class:`ScanScheduler`.

    A dispatcher thread drains the submission queue and micro-batches
    whatever is queued into one ``execute_many`` call, so scans submitted
    concurrently (or back-to-back) merge their overlapping SOT decodes::

        with store.serve() as session:
            futs = [session.submit(store.scan("cam0").labels("car"))
                    for _ in range(8)]
            results = [f.result() for f in futs]

    ``submit`` accepts a :class:`ScanQuery`, :class:`ScanPlan` or
    :class:`PhysicalPlan` and returns a :class:`concurrent.futures.Future`
    resolving to the :class:`ScanResult`.
    """

    def __init__(self, scheduler: ScanScheduler, *, max_batch: int = 64):
        self._scheduler = scheduler
        self._max_batch = max(1, int(max_batch))
        self._q: queue.SimpleQueue = queue.SimpleQueue()
        self._closed = False
        # orders submit's check+enqueue against close's flag-set, so a
        # submission either lands ahead of the _STOP sentinel or raises
        self._state_lock = threading.Lock()
        self._thread = threading.Thread(target=self._run, name="tasm-serve",
                                        daemon=True)
        self._thread.start()

    def submit(self, plan) -> Future:
        fut: Future = Future()
        with self._state_lock:
            if self._closed:
                raise RuntimeError("serving session is closed")
            self._q.put((plan, fut))
        return fut

    def execute(self, plan) -> ScanResult:
        """Synchronous convenience: submit + wait."""
        return self.submit(plan).result()

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is _STOP:
                return
            batch = [item]
            stop = False
            while len(batch) < self._max_batch:
                try:
                    nxt = self._q.get_nowait()
                except queue.Empty:
                    break
                if nxt is _STOP:
                    stop = True
                    break
                batch.append(nxt)
            # normalize per submission so one bad query can't fail the batch
            plans, live = [], []
            for plan, fut in batch:
                if not fut.set_running_or_notify_cancel():
                    continue  # caller cancelled while queued
                try:
                    plans.append(self._scheduler._normalize(plan))
                    live.append(fut)
                except BaseException as e:
                    fut.set_exception(e)
            if plans:
                try:
                    results = self._scheduler.execute_many(plans)
                except BaseException as e:
                    for fut in live:
                        fut.set_exception(e)
                else:
                    for fut, res in zip(live, results):
                        fut.set_result(res)
            if stop:
                return

    def close(self) -> None:
        """Drain pending submissions, then stop the dispatcher."""
        with self._state_lock:
            if not self._closed:
                self._closed = True
                self._q.put(_STOP)
        self._thread.join()
        while True:  # fail anything that raced the close
            try:
                item = self._q.get_nowait()
            except queue.Empty:
                break
            if item is not _STOP and item[1].set_running_or_notify_cancel():
                item[1].set_exception(
                    RuntimeError("serving session is closed"))

    def __enter__(self) -> "ServingSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ------------------------------------------------------------------ crop
def _crop(layout: TileLayout, tiles: dict[int, np.ndarray],
          rel_frame: int, box: BBox) -> np.ndarray:
    """Assemble the pixels of ``box`` from decoded tiles of one frame
    (bit-identical to the engine's old serial path)."""
    y1, x1, y2, x2 = box
    out = np.zeros((y2 - y1, x2 - x1), dtype=np.float32)
    for t in layout.tiles_intersecting(box):
        if t not in tiles:
            continue
        ty1, tx1, ty2, tx2 = layout.tile_rect(t)
        iy1, ix1 = max(y1, ty1), max(x1, tx1)
        iy2, ix2 = min(y2, ty2), min(x2, tx2)
        if iy1 >= iy2 or ix1 >= ix2:
            continue
        out[iy1 - y1:iy2 - y1, ix1 - x1:ix2 - x1] = \
            tiles[t][rel_frame, iy1 - ty1:iy2 - ty1, ix1 - tx1:ix2 - tx1]
    return out
