"""Cost model (paper §4.1).

    C(s, q, L) = beta * P(s, q, L) + gamma * T(s, q, L)

P = pixels decoded, T = tiles opened.  Decoding a tile in a non-keyframe
requires decoding that tile in every frame from the preceding keyframe, so a
tile touched by the query on *any* frame of a GOP is decoded for the whole
GOP (paper §2).  ``calibrate`` re-fits (beta, gamma) from measured decode
times of *our* codec — the paper prescribes exactly this per-system re-fit
(they report R^2 = 0.996 on NVDEC; ours is reported in EXPERIMENTS.md).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Mapping, Optional, Sequence

import numpy as np

from repro.core.layout import BBox, TileLayout, block_coverage


@dataclass
class CostModel:
    beta: float = 1.0e-8   # seconds per pixel decoded (calibrated)
    gamma: float = 1.0e-4  # seconds per tile opened (calibrated)
    r_squared: float = 0.0
    # -- third term: per-tile-open IO (calibrated by ``calibrate_io``) ------
    # Opening a tile decompresses its WHOLE coefficient stream for every
    # touched GOP, regardless of how few 8x8 blocks the ROI decode then
    # gathers.  beta/gamma are fit on full-tile decodes, where that
    # decompression is folded into beta — fine at tile granularity, but a
    # block-granular estimate that only charges beta on masked pixels
    # silently drops it.  ``io_per_pixel`` is the decompression seconds per
    # coefficient pixel *opened but not decoded*; 0.0 (the default) keeps
    # the legacy two-term behaviour.
    io_per_pixel: float = 0.0
    io_r_squared: float = 0.0   # fit quality of the io term (diagnostic)

    def cost(self, pixels: float, tiles: float,
             io_pixels: Optional[float] = None) -> float:
        """Estimated decode seconds.  ``io_pixels`` (block granularity
        only) is the full-tile pixel count the decode must decompress —
        the third term charges ``io_per_pixel`` for each pixel opened but
        not decoded, so a full-tile mask (``io_pixels == pixels``) costs
        exactly the two-term estimate and the granularities agree at the
        boundary."""
        c = self.beta * pixels + self.gamma * tiles
        if io_pixels is not None:
            c += self.io_per_pixel * max(io_pixels - pixels, 0.0)
        return c

    # -- encoding-cost model (R(s, L) in §4.4): linear in pixels encoded ----
    encode_per_pixel: float = 4.0e-8
    encode_per_tile: float = 2.0e-4

    def encode_cost(self, pixels: float, tiles: float) -> float:
        return self.encode_per_pixel * pixels + self.encode_per_tile * tiles


def pixels_and_tiles(layout: TileLayout, boxes_by_frame: Mapping[int, Sequence[BBox]],
                     *, gop: int, sot_frames: tuple[int, int]) -> tuple[float, float]:
    """P and T for a query hitting ``boxes_by_frame`` within one SOT.

    boxes_by_frame: frame -> requested boxes (only frames inside the SOT and
    the query's temporal range).  GOP semantics: within each GOP of the SOT,
    a tile intersecting any requested box is decoded for all frames of that
    GOP up to the last requested frame.
    """
    f_start, f_end = sot_frames
    if not boxes_by_frame:
        return 0.0, 0.0
    pixels = 0.0
    tiles = 0.0
    # group requested frames by GOP
    by_gop: dict[int, list[int]] = {}
    for f in boxes_by_frame:
        if f_start <= f < f_end:
            by_gop.setdefault((f - f_start) // gop, []).append(f)
    for g, frames in by_gop.items():
        needed: set[int] = set()
        for f in frames:
            for box in boxes_by_frame[f]:
                needed.update(layout.tiles_intersecting(box))
        if not needed:
            continue
        last = max(frames)
        gop_first = f_start + g * gop
        n_decoded_frames = last - gop_first + 1
        pixels += sum(layout.tile_pixels(t) for t in needed) * n_decoded_frames
        tiles += len(needed)
    return pixels, tiles


def roi_pixels_and_tiles(layout: TileLayout,
                         boxes_by_frame: Mapping[int, Sequence[BBox]],
                         *, gop: int, sot_frames: tuple[int, int]
                         ) -> tuple[float, float, float, dict]:
    """Block-granular P and T for ROI-restricted decode, the full-tile
    pixel count the decode must *open* (``io_pixels`` — decompressed per
    tile-open whether or not its blocks are gathered; the third cost-model
    term charges ``io_per_pixel`` on the opened-but-not-decoded gap), plus
    the per-tile block-coverage masks (``tile -> sorted block tuple |
    None`` for full).

    This is what the engine *actually* pays under ``decode_tile(blocks=...)``:
    each touched tile decodes only the blocks the query's boxes intersect,
    for the prefix of frames up to the last requested frame (matching
    ``TileStore.decode_tiles``'s depth semantics exactly, so a cold solo
    scan's estimate equals its measured ``pixels_decoded``).  T keeps the
    tile-granular tile-open count — the stream/container cost of touching a
    tile is unchanged by how few of its blocks decode.

    Note the deliberate asymmetry with :func:`pixels_and_tiles`: that
    function models a *standard full-tile decoder* and remains the input to
    layout decisions (policies' alpha/regret gates, tuner admission) — at
    block granularity the pixel term is layout-invariant (tile boundaries
    are 8-aligned), so it cannot rank layouts.
    """
    f_start, _ = sot_frames
    in_sot = {f: b for f, b in boxes_by_frame.items()
              if sot_frames[0] <= f < sot_frames[1]}
    if not in_sot:
        return 0.0, 0.0, 0.0, {}
    masks = block_coverage(layout, in_sot)
    n_frames = max(in_sot) - f_start + 1
    pixels = float(sum(
        (layout.tile_blocks(t) if m is None else len(m)) * 64
        for t, m in masks.items()) * n_frames)
    io_pixels, tiles = pixels_and_tiles(layout, in_sot, gop=gop,
                                        sot_frames=sot_frames)
    return pixels, tiles, io_pixels, masks


def query_cost(layout: TileLayout, boxes_by_frame, model: CostModel, *,
               gop: int, sot_frames: tuple[int, int]) -> float:
    p, t = pixels_and_tiles(layout, boxes_by_frame, gop=gop, sot_frames=sot_frames)
    return model.cost(p, t)


def calibrate(measurements: Iterable[tuple[float, float, float]]) -> CostModel:
    """Fit beta, gamma from (pixels, tiles, seconds) measurements (paper's
    1,400-combination linear fit, on our codec)."""
    rows = list(measurements)
    A = np.array([[p, t] for p, t, _ in rows], dtype=np.float64)
    y = np.array([s for _, _, s in rows], dtype=np.float64)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    pred = A @ coef
    ss_res = float(np.sum((y - pred) ** 2))
    ss_tot = float(np.sum((y - y.mean()) ** 2)) or 1e-12
    r2 = 1.0 - ss_res / ss_tot
    beta = float(max(coef[0], 1e-12))
    gamma = float(max(coef[1], 0.0))
    return CostModel(beta=beta, gamma=gamma, r_squared=r2)


def calibrate_io(measurements: Iterable[tuple[float, float, float, float]],
                 base: CostModel) -> CostModel:
    """Fit the per-tile-open IO term from ROI-restricted decode timings.

    ``measurements``: ``(masked_pixels, tiles, io_pixels, seconds)`` rows
    from block-masked decodes (tiny masks over tiles of varying size, so
    ``io_pixels - masked_pixels`` spans a wide range).  beta/gamma stay
    exactly as :func:`calibrate` fit them — tile-granularity costs (the
    basis for layout decisions) are untouched; only the residual
    ``seconds - beta*P - gamma*T`` is regressed against the
    opened-but-not-decoded pixel gap.  Sets ``io_per_pixel`` (clamped
    non-negative) and ``io_r_squared`` (fit quality of the full
    three-term prediction over these samples)."""
    rows = list(measurements)
    x = np.array([max(iop - p, 0.0) for p, _, iop, _ in rows],
                 dtype=np.float64)
    resid = np.array([s - base.cost(p, t) for p, t, _, s in rows],
                     dtype=np.float64)
    denom = float(x @ x)
    base.io_per_pixel = float(max(x @ resid / denom, 0.0)) if denom \
        else 0.0
    y = np.array([s for *_, s in rows], dtype=np.float64)
    pred = np.array([base.cost(p, t, iop) for p, t, iop, _ in rows],
                    dtype=np.float64)
    ss_res = float(np.sum((y - pred) ** 2))
    ss_tot = float(np.sum((y - y.mean()) ** 2)) or 1e-12
    base.io_r_squared = 1.0 - ss_res / ss_tot
    return base


def calibrate_encode(measurements: Iterable[tuple[float, float, float]],
                     base: CostModel) -> CostModel:
    rows = list(measurements)
    A = np.array([[p, t] for p, t, _ in rows], dtype=np.float64)
    y = np.array([s for _, _, s in rows], dtype=np.float64)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    base.encode_per_pixel = float(max(coef[0], 1e-12))
    base.encode_per_tile = float(max(coef[1], 0.0))
    return base
