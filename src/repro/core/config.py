"""Unified runtime configuration for :class:`~repro.core.engine.VideoStore`.

The engine's serving knobs used to be five ad-hoc keyword arguments
(``tile_cache_bytes``, ``tuning``, ``tuner_admission``, ``roi_decode``,
``decode_backend``).  They are now grouped into three small config objects::

    VideoStore(cache=CacheConfig(...),
               tuning=TuningConfig(...),
               decode=DecodeConfig(...))

Every config is a plain dataclass with ``to_doc``/``from_doc``, so the same
surface travels over the wire: ``RemoteVideoStore.config()`` and the router's
``config`` op return these documents, and ``scripts/tasm_serve.py`` builds
them from ``--cache-*`` / ``--tuning*`` / ``--decode-*`` flags.

Precedence (one rule for every knob, most-specific wins):

1. an **explicit** config field (``CacheConfig(eviction="lru")``),
2. a **deprecated keyword alias** (``VideoStore(tile_cache_bytes=...)``) —
   it maps 1:1 onto the config field; passing both the alias and a config
   that sets the same field is an error, not a silent pick,
3. an **environment override** — ``REPRO_CACHE_BYTES``,
   ``REPRO_CACHE_EVICTION``, ``REPRO_DECODE_BACKEND``,
4. the built-in default.

Fields whose default is ``None`` mean "not set here — fall through to the
environment, then the default".  :meth:`resolve` applies steps 3–4 and
returns a fully-concrete config; ``VideoStore`` stores only resolved
configs, so ``store.cache_config`` etc. never contain ``None`` knobs.
"""
from __future__ import annotations

import dataclasses
import os
from dataclasses import dataclass
from typing import Optional

DEFAULT_CACHE_BYTES = 256 << 20  # 256 MiB

#: eviction policies: "reuse" = expected-reuse weight (observed re-access
#: frequency, LRU tiebreak), "lru" = the pre-predictive byte-budgeted LRU,
#: preserved bit-for-bit.
EVICTION_MODES = ("reuse", "lru")
TUNING_MODES = ("background", "inline", "off")
ADMISSION_MODES = ("policy", "gated")


def _env_int(name: str) -> Optional[int]:
    v = os.environ.get(name)
    return None if v is None or v == "" else int(v)


@dataclass(frozen=True)
class CacheConfig:
    """Tile-cache knobs (see ``core/tile_cache.py``).

    - ``budget_bytes`` — byte budget; ``0`` disables the cache entirely;
      ``None`` falls through to ``$REPRO_CACHE_BYTES`` then the 256 MiB
      default.
    - ``eviction`` — ``"reuse"`` (expected-reuse weighting) or ``"lru"``
      (the legacy policy, bit-for-bit); ``None`` falls through to
      ``$REPRO_CACHE_EVICTION`` then ``"reuse"``.
    - ``prefetch`` — predictively decode the next SOTs of a detected
      sliding-window scan onto the scheduler's worker pool.
    - ``prefetch_depth`` — how many SOTs ahead to prefetch.
    - ``block_packed`` — store ROI entries as (mask, packed pixels) instead
      of a zero-padded full-tile canvas, so the same byte budget holds many
      more subframe entries (served pixels stay bit-identical).
    """
    budget_bytes: Optional[int] = None
    eviction: Optional[str] = None
    prefetch: bool = False
    prefetch_depth: int = 2
    block_packed: bool = True

    def resolve(self) -> "CacheConfig":
        budget = self.budget_bytes
        if budget is None:
            budget = _env_int("REPRO_CACHE_BYTES")
        if budget is None:
            budget = DEFAULT_CACHE_BYTES
        eviction = (self.eviction
                    or os.environ.get("REPRO_CACHE_EVICTION") or "reuse")
        if eviction not in EVICTION_MODES:
            raise ValueError(f"cache eviction must be one of "
                             f"{EVICTION_MODES}, got {eviction!r}")
        if self.prefetch_depth < 1:
            raise ValueError("prefetch_depth must be >= 1")
        return CacheConfig(budget_bytes=int(budget), eviction=eviction,
                           prefetch=bool(self.prefetch),
                           prefetch_depth=int(self.prefetch_depth),
                           block_packed=bool(self.block_packed))

    def to_doc(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_doc(cls, doc: dict) -> "CacheConfig":
        return cls(**doc)


@dataclass(frozen=True)
class TuningConfig:
    """Physical-tuner knobs (see ``core/tuner.py``).

    - ``mode`` — ``"background"`` (async tuner thread), ``"inline"``
      (observe + retile inside the scan, the pre-tuner semantics), or
      ``"off"``.
    - ``admission`` — ``"policy"`` (apply every policy proposal) or
      ``"gated"`` (rank + gate proposals by their what-if net benefit).
    - ``max_log`` — workload-log bound (oldest observations drop first).
    """
    mode: str = "background"
    admission: str = "policy"
    max_log: int = 4096

    def resolve(self) -> "TuningConfig":
        if self.mode not in TUNING_MODES:
            raise ValueError(f"tuning mode must be one of {TUNING_MODES}, "
                             f"got {self.mode!r}")
        if self.admission not in ADMISSION_MODES:
            raise ValueError(f"tuner admission must be one of "
                             f"{ADMISSION_MODES}, got {self.admission!r}")
        return TuningConfig(mode=self.mode, admission=self.admission,
                            max_log=max(1, int(self.max_log)))

    def to_doc(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_doc(cls, doc: dict) -> "TuningConfig":
        return cls(**doc)


@dataclass(frozen=True)
class DecodeConfig:
    """Decode-path knobs (see ``core/storage.py``).

    - ``backend`` — ``"numpy"`` (per-tile oracle loop) or ``"batched"``
      (fused accelerator dispatches over the merged batch; bit-identical);
      ``None`` falls through to ``$REPRO_DECODE_BACKEND`` then ``"numpy"``.
    - ``roi`` — lower per-tile 8x8-block masks into plans so subframe scans
      decode only the blocks their boxes intersect (results bit-identical
      either way).
    - ``max_workers`` — decode worker-pool size; ``None`` sizes from the
      CPU count.
    """
    backend: Optional[str] = None
    roi: bool = True
    max_workers: Optional[int] = None

    def resolve(self) -> "DecodeConfig":
        # late import: storage has no dependency on this module
        from repro.core.storage import DECODE_BACKENDS

        backend = (self.backend
                   or os.environ.get("REPRO_DECODE_BACKEND") or "numpy")
        if backend not in DECODE_BACKENDS:
            raise ValueError(f"decode_backend must be one of "
                             f"{DECODE_BACKENDS}, got {backend!r}")
        workers = self.max_workers
        if workers is None:
            workers = min(8, os.cpu_count() or 4)
        return DecodeConfig(backend=backend, roi=bool(self.roi),
                            max_workers=int(workers))

    def to_doc(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_doc(cls, doc: dict) -> "DecodeConfig":
        return cls(**doc)
