"""Cost-model calibration (paper §4.1).

The paper fits ``C = beta*P + gamma*T`` on >1,400 (video, query object,
layout) decode measurements (R^2 = 0.996 on NVDEC) and prescribes re-fitting
per system.  This module measures *our* codec: it encodes sample videos under
a spread of uniform and non-uniform layouts, times tile decodes, and fits
(beta, gamma) — and analogously the re-encode model R(s, L).
"""
from __future__ import annotations

import time

import numpy as np

from repro.codec.encode import EncoderConfig, decode_tile, encode_tile
from repro.core.cost import (CostModel, calibrate, calibrate_encode,
                             calibrate_io)
from repro.core.layout import (TileLayout, fine_grained_layout,
                               single_tile_layout, uniform_layout)
from repro.data.video_gen import dense_spec, generate, sparse_spec


def _sample_layouts(H: int, W: int, detections) -> list[TileLayout]:
    layouts = [single_tile_layout(H, W)]
    for r, c in [(1, 2), (2, 2), (2, 3), (3, 3), (3, 5), (4, 4), (4, 6)]:
        layouts.append(uniform_layout(H, W, r, c))
    # non-uniform around each label on a few windows
    labels = {l for dets in detections[:32] for l, _ in dets}
    for label in sorted(labels):
        boxes = [b for dets in detections[:16] for l, b in dets if l == label]
        if boxes:
            layouts.append(fine_grained_layout(H, W, boxes))
    return layouts


def measure_decode_samples(enc_cfg: EncoderConfig, *, seeds=(0, 1),
                           n_frames: int = 32, height: int = 192,
                           width: int = 320, repeats: int = 2):
    """Returns [(pixels, tiles, seconds)] over layout x video samples."""
    samples: list[tuple[float, float, float]] = []
    for seed in seeds:
        for spec_fn in (sparse_spec, dense_spec):
            spec = spec_fn(seed=seed, n_frames=n_frames, height=height,
                           width=width)
            frames, dets = generate(spec)
            for layout in _sample_layouts(height, width, dets):
                encs = []
                for rect in layout.tile_rects():
                    y1, x1, y2, x2 = rect
                    encs.append(encode_tile(
                        np.ascontiguousarray(frames[:, y1:y2, x1:x2]), enc_cfg))
                # decode a prefix of tiles (1, half, all) to vary P and T
                for n_tiles in sorted({1, max(1, layout.n_tiles // 2),
                                       layout.n_tiles}):
                    chosen = encs[:n_tiles]
                    # warm
                    for e in chosen:
                        decode_tile(e, gop_indices=[0])
                    t0 = time.perf_counter()
                    for _ in range(repeats):
                        for e in chosen:
                            decode_tile(e)
                    dt = (time.perf_counter() - t0) / repeats
                    pixels = sum(e["h"] * e["w"] * e["n_frames"] for e in chosen)
                    samples.append((float(pixels), float(len(chosen)), dt))
    return samples


def measure_io_samples(enc_cfg: EncoderConfig, *, seed=0,
                       n_frames: int = 32, height: int = 192,
                       width: int = 320, repeats: int = 2):
    """``(masked_pixels, tiles, io_pixels, seconds)`` rows from
    block-masked (ROI-restricted) decodes: a single 8x8 block gathered
    out of tiles of varying size, across varying GOP prefixes, so the
    opened-but-not-decoded pixel gap spans a wide range while the
    gathered pixel count stays tiny.  Feeds :func:`calibrate_io`."""
    spec = sparse_spec(seed=seed, n_frames=n_frames, height=height,
                       width=width)
    frames, _ = generate(spec)
    samples: list[tuple[float, float, float, float]] = []
    for r, c in [(1, 1), (2, 2), (3, 3), (4, 6)]:
        layout = uniform_layout(height, width, r, c)
        y1, x1, y2, x2 = layout.tile_rects()[0]
        enc = encode_tile(np.ascontiguousarray(frames[:, y1:y2, x1:x2]),
                          enc_cfg)
        th, tw = y2 - y1, x2 - x1
        n_gops = max(1, n_frames // enc_cfg.gop)
        for k in sorted({1, max(1, n_gops // 2), n_gops}):
            gops = list(range(k))
            decode_tile(enc, gop_indices=gops, blocks=(0,))  # warm
            t0 = time.perf_counter()
            for _ in range(repeats):
                decode_tile(enc, gop_indices=gops, blocks=(0,))
            dt = (time.perf_counter() - t0) / repeats
            f_decoded = k * enc_cfg.gop
            samples.append((64.0 * f_decoded, float(k),
                            float(th * tw * f_decoded), dt))
    return samples


def measure_encode_samples(enc_cfg: EncoderConfig, *, seed=0,
                           n_frames: int = 32, height: int = 192,
                           width: int = 320):
    samples: list[tuple[float, float, float]] = []
    spec = sparse_spec(seed=seed, n_frames=n_frames, height=height, width=width)
    frames, dets = generate(spec)
    for layout in _sample_layouts(height, width, dets)[:8]:
        t0 = time.perf_counter()
        for rect in layout.tile_rects():
            y1, x1, y2, x2 = rect
            encode_tile(np.ascontiguousarray(frames[:, y1:y2, x1:x2]), enc_cfg)
        dt = time.perf_counter() - t0
        samples.append((float(height * width * n_frames),
                        float(layout.n_tiles), dt))
    return samples


def calibrated_cost_model(enc_cfg: EncoderConfig | None = None,
                          **kw) -> CostModel:
    """Measure + fit both the decode and encode linear models."""
    enc_cfg = enc_cfg or EncoderConfig()
    model = calibrate(measure_decode_samples(enc_cfg, **kw))
    model = calibrate_encode(measure_encode_samples(enc_cfg), model)
    model = calibrate_io(measure_io_samples(enc_cfg), model)
    return model
