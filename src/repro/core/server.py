"""VideoStoreServer: the cross-process serving front end.

TASM's wins live in shared physical state — one tuned tile layout, one
decoded-tile cache, one background tuner.  Before this module only threads
inside a single Python process could share them; every external client
re-decoded and re-tuned from cold.  ``VideoStoreServer`` draws the same
system boundary VSS puts between its storage server and analytics clients:
it owns ONE :class:`~repro.core.engine.VideoStore` and accepts concurrent
client connections over a Unix-domain or TCP socket speaking the
length-prefixed frames of ``wire.py``.

Cross-client merging: every scan RPC — from any connection — is submitted
to one shared :class:`~repro.core.scheduler.ServingSession`, whose
dispatcher micro-batches whatever is queued into a single ``execute_many``
call.  Scans from different client *processes* hitting the same
``(video, sot_id, epoch)`` therefore merge into one union-of-tiles decode
and share tile-cache entries, exactly like threads of one process: the
second client's repeat of a scan the first client already ran decodes zero
tiles.  The scheduler's serial-equivalence invariant makes every remote
result bit-identical to an in-process ``execute()`` of the same plan.

Protocol: request frames are ``{"id": n, "op": name, ...params}``;
responses ``{"id": n, "ok": True, "value": ...}`` or ``{"id": n, "ok":
False, "error": {"type", "message"}}``.  Ids multiplex one connection —
scan responses are written from future callbacks, so a client can pipeline
requests and a slow decode never blocks its neighbour's ping.  A malformed
or oversized frame gets an error frame (id ``None``) and closes only that
connection; the server — and every other client — keeps running.

Durable mutations (``ingest``/``add_detections``/``retile``/…) run inline
on the connection thread through the engine's own locking, so they
serialize against scans the same way in-process callers do.

Zero-copy transport: scan replies to same-host clients ride a
shared-memory :class:`~repro.core.shm.SegmentPool` — the reply's region
arrays are written once into a leased segment and only ``(segment,
offset, shape, dtype)`` descriptors cross the socket (``transport="shm"``,
negotiated per connection via a nonce probe that proves /dev/shm is
genuinely shared).  Remote/TCP peers, declined probes, and pool overflow
fall back to the npz payload automatically.  Reply *marshalling* (doc
building + payload packing) runs on the scheduler's worker pool, not the
serving session's dispatcher thread, so replies to many clients encode in
parallel on either transport.
"""
from __future__ import annotations

import dataclasses
import os
import pathlib
import queue
import socket
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

from repro.codec.encode import EncoderConfig
from repro.core import wire
from repro.core.cost import CostModel
from repro.core.engine import VideoStore
from repro.core.layout import TileLayout
from repro.core.policies import policy_from_spec
from repro.core.query import ScanPlan
from repro.core.shm import (SegmentPool, resolve_transport, shm_available,
                            DEFAULT_POOL_BYTES)


def _cost_model_from_doc(doc: Optional[dict]) -> Optional[CostModel]:
    if doc is None:
        return None
    cm = CostModel(beta=doc["beta"], gamma=doc["gamma"],
                   r_squared=doc.get("r_squared", 0.0))
    if doc.get("io_per_pixel") is not None:
        cm.io_per_pixel = doc["io_per_pixel"]
    if doc.get("encode_per_pixel") is not None:
        cm.encode_per_pixel = doc["encode_per_pixel"]
    if doc.get("encode_per_tile") is not None:
        cm.encode_per_tile = doc["encode_per_tile"]
    return cm


def _video_kw_from_doc(doc: dict) -> dict:
    """Decode the add_video/ingest per-video kwargs (encoder dict, policy
    spec, cost-model params, sot_len) into engine objects."""
    kw = {}
    if doc.get("encoder") is not None:
        kw["encoder"] = EncoderConfig(**doc["encoder"])
    if doc.get("policy") is not None:
        kw["policy"] = policy_from_spec(doc["policy"])
    if doc.get("cost_model") is not None:
        kw["cost_model"] = _cost_model_from_doc(doc["cost_model"])
    if doc.get("sot_len") is not None:
        kw["sot_len"] = int(doc["sot_len"])
    return kw


def _detections_from_doc(pairs) -> dict:
    return {int(f): [(label, tuple(int(c) for c in bbox))
                     for label, bbox in dets]
            for f, dets in pairs}


class _ConnState:
    """Per-connection serving state: the socket, its bounded reply queue,
    and the shared-memory lease identity.  The state object itself is the
    ``owner`` token segments are leased under, so reclaiming a dead
    connection's segments is an identity lookup, not bookkeeping."""

    __slots__ = ("sock", "outq", "shm", "closed")

    def __init__(self, sock: socket.socket):
        self.sock = sock
        # responses go through a bounded per-connection queue drained by a
        # writer thread: scan replies arrive from marshalling workers, and
        # a blocking sendall to ONE stalled client there would wedge every
        # other client's replies.  A full queue means the client stopped
        # reading — drop it.
        self.outq: queue.Queue = queue.Queue(maxsize=256)
        self.shm = False      # negotiated: replies may ride shared memory
        self.closed = False   # teardown begun: release, don't lease


class VideoStoreServer:
    """Serve one :class:`VideoStore` to many client processes.

    Exactly one of ``path`` (Unix-domain socket) or ``host`` (TCP; pass
    ``port=0`` for an ephemeral port, read it back from :attr:`address`)
    must be given.  Use as a context manager, or ``start()`` /
    ``stop()`` explicitly; :meth:`serve_forever` blocks until
    :meth:`stop` (e.g. from a signal handler) is called.

    ``transport`` — ``"auto"`` (default; ``$REPRO_TRANSPORT`` overrides)
    offers the shared-memory reply path to clients that prove they share
    /dev/shm, ``"shm"`` requires it (``start()`` raises when unavailable),
    ``"socket"`` disables it (every reply rides the npz payload).

    ``owns_store=True`` (default) closes the store on ``stop()``.
    """

    def __init__(self, store: VideoStore, *,
                 path: Optional[str] = None,
                 host: Optional[str] = None, port: int = 0,
                 max_frame_bytes: int = wire.DEFAULT_MAX_FRAME_BYTES,
                 codec: Optional[str] = None,
                 max_batch: int = 64,
                 transport: Optional[str] = None,
                 shm_max_bytes: int = DEFAULT_POOL_BYTES,
                 owns_store: bool = True):
        if (path is None) == (host is None):
            raise ValueError("give exactly one of path= (unix socket) or "
                             "host= (tcp)")
        self.store = store
        self.path = path
        self.host, self.port = host, port
        self.max_frame_bytes = int(max_frame_bytes)
        self.codec = codec  # None = wire.default_codec()
        self.max_batch = max_batch
        self.transport = resolve_transport(transport)
        self.shm_max_bytes = int(shm_max_bytes)
        self.owns_store = owns_store
        self._listener: Optional[socket.socket] = None
        self._session = None
        self._accept_thread: Optional[threading.Thread] = None
        self._conns: dict[socket.socket, _ConnState] = {}
        self._conn_lock = threading.Lock()
        self._shm_pool: Optional[SegmentPool] = None
        self._marshal_pool: Optional[ThreadPoolExecutor] = None
        self._marshal_lock = threading.Lock()
        self._stopped = threading.Event()
        self._cleanup_done = threading.Event()
        self._stop_lock = threading.Lock()
        self._stopper: Optional[threading.Thread] = None
        self._started = False

    # ---------------------------------------------------------- lifecycle
    @property
    def address(self):
        """Bound address: the socket path, or ``(host, port)`` for TCP."""
        if self.path is not None:
            return self.path
        assert self._listener is not None, "server not started"
        return self._listener.getsockname()[:2]

    def start(self) -> "VideoStoreServer":
        if self._started:
            raise RuntimeError("server already started")
        self._started = True
        if self.transport != "socket":
            # probe BEFORE binding so a refusal leaves no socket file
            if shm_available():
                self._shm_pool = SegmentPool(max_bytes=self.shm_max_bytes)
            elif self.transport == "shm":
                raise RuntimeError("transport='shm' but shared memory is "
                                   "unavailable on this host")
        if self.path is not None:
            p = pathlib.Path(self.path)
            if p.exists() and p.is_socket():
                # recover a STALE socket (unclean previous shutdown) but
                # refuse to hijack a live server's address: probe first
                probe = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
                probe.settimeout(1.0)
                try:
                    probe.connect(self.path)
                except OSError:
                    p.unlink()  # nobody answering: genuinely stale
                else:
                    raise OSError(
                        f"{self.path} is in use by a live server")
                finally:
                    probe.close()
            sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            sock.bind(self.path)
        else:
            sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            sock.bind((self.host, self.port))
        sock.listen(64)
        self._listener = sock
        self._session = self.store.serve(max_batch=self.max_batch)
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="tasm-server-accept", daemon=True)
        self._accept_thread.start()
        return self

    def serve_forever(self) -> None:
        """Block until :meth:`stop` has COMPLETED (not merely started):
        the shutdown RPC runs ``stop`` on a daemon thread, so returning on
        the stop *signal* would let the interpreter exit mid-cleanup —
        before the session drained, the store flushed, and the socket file
        was unlinked."""
        self._stopped.wait()
        self._cleanup_done.wait()

    def stop(self) -> None:
        """Stop accepting, close every connection, drain the shared serving
        session, and (when ``owns_store``) close the store.  Idempotent;
        concurrent callers block until the first caller's cleanup is
        done."""
        with self._stop_lock:
            already = self._stopped.is_set()
            if not already:
                self._stopped.set()
                self._stopper = threading.current_thread()
        if already:
            if self._stopper is threading.current_thread():
                # re-entrant: a second SIGTERM/SIGINT interrupted the
                # first handler's cleanup on this very thread — waiting
                # here would deadlock (only the interrupted outer frame
                # can finish the cleanup)
                return
            self._cleanup_done.wait()
            return
        if self._listener is not None:
            # closing a listener does NOT wake a thread blocked in
            # accept(); poke it with a throwaway connection so the accept
            # loop observes _stopped and exits promptly
            try:
                if self.path is not None:
                    poke = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
                    poke.settimeout(1.0)
                    poke.connect(self.path)
                else:
                    poke = socket.create_connection(
                        self._listener.getsockname()[:2], timeout=1.0)
                poke.close()
            except OSError:
                pass
            try:
                self._listener.close()
            except OSError:  # pragma: no cover - already closed
                pass
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5)
        with self._conn_lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.close()
            except OSError:  # pragma: no cover - already closed
                pass
        if self._session is not None:
            self._session.close()
        # only unlink a socket WE bound: a failed start() (e.g. the path
        # belongs to a live server) must not tear down someone else's
        if self.path is not None and self._listener is not None:
            try:
                pathlib.Path(self.path).unlink()
            except OSError:
                pass
        with self._marshal_lock:
            pool, self._marshal_pool = self._marshal_pool, None
        if pool is not None:
            pool.shutdown(wait=True)
        if self._shm_pool is not None:
            # after the session drained and marshal workers finished: no
            # new segments can be written, outstanding ones unlink here
            # (clients still mapping them keep valid pages)
            self._shm_pool.close()
        if self.owns_store:
            self.store.close()
        self._cleanup_done.set()

    def __enter__(self) -> "VideoStoreServer":
        return self.start() if not self._started else self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -------------------------------------------------------- connections
    def _accept_loop(self) -> None:
        assert self._listener is not None
        while not self._stopped.is_set():
            try:
                conn, _ = self._listener.accept()
            except OSError:  # listener closed by stop()
                return
            st = _ConnState(conn)
            with self._conn_lock:
                self._conns[conn] = st
            threading.Thread(target=self._serve_conn, args=(st,),
                             name="tasm-server-conn", daemon=True).start()

    def _serve_conn(self, st: _ConnState) -> None:
        conn = st.sock
        writer = threading.Thread(target=self._write_loop, args=(st,),
                                  name="tasm-server-write", daemon=True)
        writer.start()
        try:
            while not self._stopped.is_set():
                try:
                    req = wire.read_frame(conn,
                                          max_bytes=self.max_frame_bytes)
                except wire.ConnectionClosed:
                    return
                except wire.WireError as e:
                    # reply with an error frame instead of dying; the
                    # stream may be mid-garbage, so close THIS connection
                    self._send(st, wire.error_doc(None, e))
                    return
                self._dispatch(st, req)
        except OSError:
            return  # connection torn down under us (client gone / stop())
        finally:
            st.closed = True  # before release: a marshal job that leases
            #                   past this point sees the flag and releases
            st.outq.put(None)  # writer drains what's queued, then exits
            with self._conn_lock:
                self._conns.pop(conn, None)
                live = list(self._conns.values())
            if self._shm_pool is not None:
                # reclaim every lease the peer (cleanly closed, crashed,
                # or SIGKILLed alike) left behind, then sweep for strays
                # orphaned by earlier teardown races
                self._shm_pool.release_owner(st)
                self._shm_pool.sweep(live)

    def _write_loop(self, st: _ConnState) -> None:
        """Single writer per connection; only this thread (and only this
        connection) blocks when the peer stops reading."""
        broken = False
        while True:
            payload = st.outq.get()
            if payload is None:
                break
            if isinstance(payload, threading.Event):
                payload.set()  # flush marker: everything before it went out
                continue
            if broken:
                continue  # discard until the sentinel
            try:
                st.sock.sendall(wire._HEADER.pack(len(payload)) + payload)
            except OSError:
                broken = True
        try:
            st.sock.close()
        except OSError:
            pass

    def _segment_writer(self, st: _ConnState, leased: list):
        """Per-reply shared-memory writer for ``wire.dumps``, or ``None``
        when this connection's replies ride the npz payload.  Segment
        names written are recorded in ``leased`` so the caller can release
        them if the reply never reaches the client."""
        if self._shm_pool is None or not st.shm or st.closed:
            return None

        def write(arrays):
            doc = self._shm_pool.write(arrays, owner=st)
            if doc is not None:
                leased.append(doc["seg"])
            return doc

        return write

    @staticmethod
    def _stamp_marshalling(clean: dict, stats_objs: list,
                           transport: str, nbytes: int,
                           marshal_s: float) -> None:
        """Stamp marshalling accounting into the outgoing reply doc AND
        the live ScanStats objects (already appended to engine history by
        the scheduler), so `store.stats()` and the client's result agree.
        A multi-result reply (execute_many) splits cost evenly — the wire
        packs all its arrays as one payload, so per-result attribution
        finer than an even split would be fiction."""
        value = clean.get("value")
        docs = [value] if isinstance(value, dict) else \
            value if isinstance(value, list) else []
        share_s = marshal_s / max(len(stats_objs), 1)
        share_b = nbytes / max(len(stats_objs), 1)
        for stats, doc in zip(stats_objs, docs):
            stats.marshal_s = share_s
            stats.payload_bytes = share_b
            stats.transport = transport
            sdoc = doc.get("stats") if isinstance(doc, dict) else None
            if isinstance(sdoc, dict):
                sdoc["marshal_s"] = share_s
                sdoc["payload_bytes"] = share_b
                sdoc["transport"] = transport

    def _send(self, st: _ConnState, doc: dict,
              stats: Optional[list] = None) -> None:
        """Encode and enqueue one reply.  ``stats`` — the reply's live
        ScanStats objects — turns on marshalling accounting and makes the
        reply eligible for the shared-memory transport."""
        t0 = time.perf_counter()
        leased: list = []
        on_payload = None
        if stats:
            def on_payload(clean, transport, nbytes):
                self._stamp_marshalling(clean, stats, transport, nbytes,
                                        time.perf_counter() - t0)
        try:
            payload = wire.dumps(
                doc, codec=self.codec, max_bytes=self.max_frame_bytes,
                segment_writer=self._segment_writer(st, leased)
                if stats else None,
                on_payload=on_payload)
        except wire.WireError as e:
            # the RESPONSE broke the frame limit (e.g. a scan returned more
            # region bytes than max_frame_bytes): tell the client instead
            # of silently dropping the connection
            self._release_leases(st, leased)
            leased = []
            payload = wire.dumps(wire.error_doc(doc.get("id"), e),
                                 codec=self.codec,
                                 max_bytes=self.max_frame_bytes)
        delivered = False
        try:
            st.outq.put_nowait(payload)
            delivered = True
        except queue.Full:
            # slow consumer: hundreds of unread responses queued — cut it
            # loose rather than buffer unboundedly (its writer thread may
            # be stuck in sendall; shutdown() unsticks that too)
            try:
                st.sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                st.sock.close()
            except OSError:
                pass
        # leases racing connection teardown: _serve_conn sets st.closed
        # BEFORE release_owner, we re-check closed AFTER leasing — one of
        # the two sides is guaranteed to observe the other's write, so a
        # segment can't slip past both and leak
        if leased and (not delivered or st.closed):
            self._release_leases(st, leased)

    def _release_leases(self, st: _ConnState, names: list) -> None:
        if names and self._shm_pool is not None:
            self._shm_pool.release(names, owner=st)

    # -------------------------------------------------- reply marshalling
    def _offload_marshal(self, fn, *args) -> None:
        """Run a reply-marshalling job on the store's scheduler pool (the
        decode workers, idle between batches), falling back to a
        server-owned pool when the store has none (the cluster router
        duck-types the store surface without a scheduler), and to inline
        execution when the pools are draining at shutdown."""
        sched = getattr(self.store, "scheduler", None)
        try:
            if sched is not None:
                sched.offload(fn, *args)
                return
            with self._marshal_lock:
                if self._marshal_pool is None:
                    self._marshal_pool = ThreadPoolExecutor(
                        max_workers=max(os.cpu_count() or 1, 2),
                        thread_name_prefix="tasm-marshal")
                pool = self._marshal_pool
            pool.submit(fn, *args)
        except RuntimeError:  # racing shutdown: last replies go inline
            fn(*args)

    def _marshal_scan_reply(self, st: _ConnState, rid, res,
                            want_plan: bool) -> None:
        try:
            resp = wire.result_doc(rid, self._result_doc(res, want_plan))
        except BaseException as e:  # noqa: BLE001 - to client
            self._send(st, wire.error_doc(rid, e))
            return
        self._send(st, resp, stats=[res.stats])

    # ----------------------------------------------------------- dispatch
    def _dispatch(self, st: _ConnState, req) -> None:
        rid = req.get("id") if isinstance(req, dict) else None
        try:
            if not isinstance(req, dict) or "op" not in req:
                raise ValueError("request frame has no 'op'")
            op = req["op"]
            if op == "scan":
                # async: the response is written from the future callback,
                # so this connection can pipeline more requests meanwhile
                fut = self._session.submit(ScanPlan.from_doc(req["plan"]))
                want_plan = bool(req.get("want_plan", True))

                def _done(f, rid=rid):
                    try:
                        res = f.result()
                    except BaseException as e:  # noqa: BLE001 - to client
                        self._send(st, wire.error_doc(rid, e))
                        return
                    # the callback runs on the shared session's dispatcher
                    # thread — marshalling there would serialize every
                    # client's replies behind one GIL-bound loop, so hand
                    # the doc building + payload packing to the pool
                    self._offload_marshal(self._marshal_scan_reply,
                                          st, rid, res, want_plan)

                fut.add_done_callback(_done)
                return
            if op == "execute_many":
                # one submission wave through the shared session: same
                # micro-batch, results strictly in submission order
                futs = [self._session.submit(ScanPlan.from_doc(p))
                        for p in req["plans"]]
                want_plan = bool(req.get("want_plan", True))
                results = [f.result() for f in futs]
                value = [self._result_doc(r, want_plan) for r in results]
                self._send(st, wire.result_doc(rid, value),
                           stats=[r.stats for r in results])
                return
            if op in ("shm_probe", "shm_enable", "shm_release"):
                value = self._handle_shm(op, req, st)
            else:
                value = self._handle(op, req)
                if op == "ping":
                    value["transport"] = "shm" if st.shm else "npz"
                elif op == "stats" and isinstance(value, dict):
                    value["shm"] = self._shm_pool.stats() \
                        if self._shm_pool is not None \
                        else {"segments": 0, "bytes": 0}
        except BaseException as e:  # noqa: BLE001 - mapped to error frame
            self._send(st, wire.error_doc(rid, e))
            return
        self._send(st, wire.result_doc(rid, value))
        if req.get("op") == "shutdown":
            # stop from a helper thread (stop() tears down connection
            # machinery this thread is part of) — but only after the
            # writer has flushed the queued reply, else stop()'s
            # connection close races the send and the client sees EOF
            # instead of its acknowledgement
            flushed = threading.Event()
            st.outq.put(flushed)

            def _stop_after_flush():
                flushed.wait(timeout=10)  # a non-reading client can't
                self.stop()               # hold shutdown hostage

            threading.Thread(target=_stop_after_flush,
                             daemon=True).start()

    def _result_doc(self, res, want_plan: bool) -> dict:
        return res.to_doc(include_plan=want_plan)

    # ------------------------------------------------- shm lease protocol
    def _handle_shm(self, op: str, req: dict, st: _ConnState):
        """Transport negotiation + lease release.  ``shm_probe`` leases a
        nonce segment; the client proves it genuinely shares /dev/shm
        (same-host, same namespace — not a TCP peer with a coincidental
        segment name) by echoing the nonce through ``shm_enable``."""
        if op == "shm_release":
            if self._shm_pool is not None:
                self._shm_pool.release(
                    [str(n) for n in req.get("segments") or []], owner=st)
            return True
        if self._shm_pool is None or self.transport == "socket":
            if op == "shm_probe":
                return {"enabled": False}
            return False  # shm_enable against a socket-only server
        if op == "shm_probe":
            name, nbytes = self._shm_pool.probe(owner=st)
            return {"enabled": True, "segment": name, "nbytes": nbytes}
        # shm_enable: verify the nonce readback, then release the probe
        ok = self._shm_pool.verify(str(req.get("segment")),
                                   str(req.get("nonce")))
        self._shm_pool.release([str(req.get("segment"))], owner=st)
        if ok:
            st.shm = True
        return ok

    # ------------------------------------------------------------- ops
    def _handle(self, op: str, req: dict):
        store = self.store
        if op == "ping":
            # doubles as the router tier's node-health probe, so carry
            # enough state for a cheap liveness + capacity check
            return {"pong": True, "pid": os.getpid(),
                    "codec": self.codec or wire.default_codec(),
                    "videos": len(store)}
        if op == "videos":
            return store.videos()
        if op == "add_video":
            store.add_video(req["name"], **_video_kw_from_doc(req))
            return True
        if op == "ingest":
            dets = req.get("detections")
            layouts = req.get("initial_layouts")
            stats = store.ingest(
                req["name"], req["frames"],
                detections=None if dets is None
                else [[(label, tuple(int(c) for c in bbox))
                       for label, bbox in frame_dets]
                      for frame_dets in dets],
                initial_layouts=None if layouts is None
                else {int(s): TileLayout(tuple(h), tuple(w))
                      for s, h, w in layouts},
                **_video_kw_from_doc(req))
            doc = dataclasses.asdict(stats)
            # replica-aware acknowledgement: the post-ingest epoch table
            # rides along so a router writing K replicas can verify they
            # all landed on the same physical generation without a second
            # round-trip (pairs, not a dict — JSON would stringify int
            # keys)
            doc["epochs"] = [[s, e]
                             for s, e in store.epochs(req["name"]).items()]
            return doc
        if op == "add_detections":
            store.add_detections(req["video"],
                                 _detections_from_doc(req["pairs"]))
            return True
        if op == "add_metadata":
            store.add_metadata(req["video"], int(req["frame"]),
                               req["label"], int(req["x1"]), int(req["y1"]),
                               int(req["x2"]), int(req["y2"]))
            return True
        if op == "explain":
            return store.lower(ScanPlan.from_doc(req["plan"])).to_doc()
        if op == "retile":
            layout = TileLayout(tuple(int(h) for h in req["heights"]),
                                tuple(int(w) for w in req["widths"]))
            return store.retile(req["video"], int(req["sot_id"]), layout)
        if op == "drain_tuner":
            return dataclasses.asdict(store.drain_tuner(req.get("timeout")))
        if op == "tuner_stats":
            return dataclasses.asdict(store.tuner_stats())
        if op == "drain_prefetch":
            return dataclasses.asdict(store.drain_prefetch(
                req.get("timeout")))
        if op == "config":
            return store.config()
        if op == "epochs":
            return [[s, e] for s, e in store.epochs(req["video"]).items()]
        # -- replica streaming (the cluster repair data plane): each chunk
        # is one request/reply frame, so copies are resumable at chunk
        # granularity and ride the same wire/codec as everything else
        if op == "export_meta":
            return store.export_entry(req["video"])
        if op == "export_chunk":
            return store.export_tile(req["video"], int(req["sot_id"]),
                                     int(req["tile_idx"]))
        if op == "import_begin":
            return store.begin_import(req["video"])
        if op == "import_chunk":
            store.stage_import_chunk(req["video"], int(req["sot_id"]),
                                     int(req["epoch"]), int(req["tile_idx"]),
                                     req["enc"], str(req["checksum"]))
            return True
        if op == "import_commit":
            min_epochs = {int(s): int(e)
                          for s, e in (req.get("min_epochs") or [])}
            return store.commit_import(req["video"], req["doc"],
                                       min_epochs=min_epochs)
        if op == "import_abort":
            store.abort_import(req["video"])
            return True
        if op == "stats":
            return store.stats()
        if op == "shutdown":
            return True  # the dispatcher stops the server after replying
        raise ValueError(f"unknown op {op!r}")
