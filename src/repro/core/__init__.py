"""TASM core — the paper's primary contribution.

Tile layouts, cost model + what-if, B+-tree semantic index, KQKO optimizer,
incremental (lazy / more / regret) tiling policies, tile store, and the TASM
facade (SCAN / ADDMETADATA).
"""
from repro.core.cost import CostModel, calibrate, pixels_and_tiles, query_cost
from repro.core.layout import (
    TileLayout,
    coarse_grained_layout,
    fine_grained_layout,
    partition,
    single_tile_layout,
    uniform_layout,
)
from repro.core.policies import (
    KQKOPolicy,
    LazyPolicy,
    MorePolicy,
    NoTilingPolicy,
    PretileAllPolicy,
    RegretPolicy,
)
from repro.core.semantic_index import SemanticIndex
from repro.core.storage import TileStore
from repro.core.tasm import TASM, ScanResult, ScanStats
