"""TASM core — the paper's primary contribution.

Tile layouts, cost model + what-if, B+-tree semantic index, KQKO optimizer,
incremental (lazy / more / regret) tiling policies, tile store, and the
VideoStore engine: a multi-video catalog with a declarative scan-query
builder, an explicit plan/execute split, and a concurrent serving layer —
an epoch-keyed tile cache (``tile_cache.py``) plus a merging scan scheduler
(``scheduler.py``) behind ``execute``/``execute_many``/``serve``, with
policy-driven re-tiling moved off the scan path into the background
physical tuner (``tuner.py``; ``tuning="background"|"inline"|"off"``).
Cross-process serving: ``VideoStoreServer`` (``server.py``) exposes one
store over a Unix/TCP socket (``wire.py``) and ``RemoteVideoStore``
(``client.py``) mirrors the declarative surface, so many client processes
share one scheduler, tile cache, and tuner; same-host clients negotiate
the zero-copy shared-memory reply transport (``shm.py``), with npz
payloads as the remote/TCP fallback.  ``ClusterRouter`` (``cluster.py``)
scales that out across nodes with consistent-hash placement and
replicated failover, and the self-healing data plane (``repair.py``)
streams tiles node-to-node in resumable chunked waves to re-replicate
after permanent node loss (``repair``) and apply rebalance plans
(``rebalance(apply=True)``) off the serving path.  The deprecated
single-video ``TASM`` facade remains as a shim.
"""
from repro.core.client import (RemoteError, RemoteScanQuery,
                               RemoteServingSession, RemoteVideoStore)
from repro.core.cluster import (ClusterClient, ClusterRouter,
                                ClusterRouterServer, PlacementMap)
from repro.core.config import (CacheConfig, DecodeConfig, TuningConfig,
                               DEFAULT_CACHE_BYTES)
from repro.core.cost import (CostModel, calibrate, calibrate_io,
                             pixels_and_tiles, query_cost,
                             roi_pixels_and_tiles)
from repro.core.engine import IngestStats, VideoEntry, VideoStore
from repro.core.layout import (
    TileLayout,
    block_coverage,
    coarse_grained_layout,
    fine_grained_layout,
    partition,
    single_tile_layout,
    uniform_layout,
)
from repro.core.policies import (
    KQKOPolicy,
    LazyPolicy,
    MorePolicy,
    NoTilingPolicy,
    PretileAllPolicy,
    RegretPolicy,
)
from repro.core.repair import RepairJob, RepairStats, RepairWorker
from repro.core.query import (PhysicalPlan, ScanPlan, ScanQuery, ScanResult,
                              ScanStats, SOTScan, merge_results, split_plan)
from repro.core.scheduler import ScanScheduler, ServingSession
from repro.core.semantic_index import SemanticIndex
from repro.core.server import VideoStoreServer
from repro.core.shm import SegmentPool, shm_available
from repro.core.storage import TileStore
from repro.core.tasm import TASM
from repro.core.tile_cache import CacheStats, TileCache, WorkloadPredictor
from repro.core.tuner import PhysicalTuner, TunerStats
