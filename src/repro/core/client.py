"""RemoteVideoStore: the client half of the cross-process serving layer.

Mirrors the :class:`~repro.core.engine.VideoStore` declarative surface over
the ``wire.py`` protocol, so swapping an in-process store for a shared
server is a one-line change::

    store = RemoteVideoStore("/tmp/tasm.sock")          # unix socket
    store = RemoteVideoStore(host="10.0.0.5", port=7841)  # tcp

    res  = store.scan("cam0").labels("car").frames(0, 96).execute()
    plan = store.scan("cam0").labels("car").explain()     # no decode
    results = store.execute_many([q1, q2, q3])            # one merged batch
    with store.serve() as session:                        # concurrent submit
        futs = [session.submit(q) for q in queries]

Every client of one server shares its scheduler, tile cache, and
background tuner: queries from different client *processes* merge into
union-of-tiles decodes and warm each other's cache (the server funnels all
scan RPCs through one shared ``ServingSession``).  Results are
bit-identical to in-process ``execute()`` — region tuples, pixel crops
(npz round-trip preserves dtype/bits), and ScanStats all cross the wire.

Transport: with ``transport="auto"`` (default; ``$REPRO_TRANSPORT``
overrides) a unix-socket client negotiates the server's zero-copy
shared-memory reply path — region arrays arrive as read-only numpy views
onto server-written /dev/shm segments instead of bytes copied off the
socket — falling back silently to the npz payload when the server
declines (TCP, ``--transport socket``, no /dev/shm).  ``transport="shm"``
raises if negotiation fails; ``transport="socket"`` never negotiates.
Segment leases are refcounted: each view's garbage collection (or
``close()``) releases its segment back to the server.  Bits are identical
on either transport.

One socket, pipelined: requests carry ids; a reader thread resolves
response frames to their futures, so many in-flight scans share the
connection without head-of-line blocking on the server side (scan replies
are written from future callbacks there).  All public methods are
thread-safe.  Failures of the remote call re-raise locally — common
builtin exception types (KeyError, ValueError, …) are mapped back by name,
anything else surfaces as :class:`RemoteError`.
"""
from __future__ import annotations

import dataclasses
import socket
import threading
import time
import weakref
from collections import deque
from concurrent.futures import Future
from concurrent.futures import TimeoutError as _FutTimeout
from typing import Optional

import numpy as np

from repro.core import wire
from repro.core.config import CacheConfig, DecodeConfig, TuningConfig
from repro.core.shm import attach_segment, resolve_transport, shm_available
from repro.core.engine import IngestStats
from repro.core.policies import Policy, policy_spec
from repro.core.query import (PhysicalPlan, ScanPlan, ScanQuery, ScanResult)
from repro.core.tile_cache import CacheStats
from repro.core.tuner import TunerStats

#: server-raised exception types re-raised as themselves on the client
_ERROR_TYPES = {e.__name__: e for e in
                (KeyError, ValueError, TypeError, RuntimeError,
                 IndexError, NotImplementedError)}

#: ops safe to transparently re-send after a reconnect.  Mutations
#: (ingest/add_detections/retile/…) are NOT here: the server may have
#: applied one before the connection died, and re-sending would double
#: it — those surface the ConnectionError to the caller instead.
_IDEMPOTENT_OPS = frozenset({"ping", "videos", "stats", "explain",
                             "execute_many", "tuner_stats", "epochs",
                             "config", "drain_prefetch"})


def _parse_config_doc(doc: dict) -> dict:
    return {"cache": CacheConfig.from_doc(doc["cache"]),
            "tuning": TuningConfig.from_doc(doc["tuning"]),
            "decode": DecodeConfig.from_doc(doc["decode"])}


class RemoteError(RuntimeError):
    """A server-side failure with no local builtin counterpart."""


def _raise_remote(err: dict):
    etype, msg = err.get("type", "Error"), err.get("message", "")
    exc = _ERROR_TYPES.get(etype)
    if exc is KeyError:
        # str(KeyError("x")) is "'x'" — unwrap so the message doesn't
        # double-quote on the second raise
        raise KeyError(msg.strip("'\""))
    if exc is not None:
        raise exc(msg)
    raise RemoteError(f"{etype}: {msg}")


class RemoteScanQuery(ScanQuery):
    """The chainable builder, executing over the wire.  ``_clone`` keeps
    the subclass, so forked partial queries stay remote."""

    def explain(self) -> PhysicalPlan:
        return self._engine._explain(self.plan())

    def execute(self) -> ScanResult:
        return self._engine.execute(self.plan())

    def submit(self) -> Future:
        """Fire-and-collect: returns a Future resolving to the
        :class:`ScanResult` (the remote twin of session submission)."""
        return self._engine._submit_plan(self.plan())


class RemoteServingSession:
    """Client-side ``serve()`` session: ``submit`` returns a Future.

    There is no client-side batching to coordinate — every submission goes
    straight onto the shared connection and the SERVER micro-batches
    everything queued across all clients, which is exactly what makes
    cross-process merging work.  ``close`` waits for this session's
    outstanding futures."""

    def __init__(self, store: "RemoteVideoStore"):
        self._store = store
        self._futs: list[Future] = []
        self._lock = threading.Lock()
        self._closed = False

    def submit(self, query) -> Future:
        with self._lock:
            if self._closed:
                raise RuntimeError("serving session is closed")
            fut = self._store._submit_plan(self._store._as_plan(query))
            self._futs.append(fut)
            return fut

    def execute(self, query) -> ScanResult:
        return self.submit(query).result()

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            futs = list(self._futs)
        for f in futs:
            try:
                f.result()
            except Exception:  # noqa: BLE001 - surfaced via the future
                pass

    def __enter__(self) -> "RemoteServingSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class _SegmentLease:
    """One reply's shared-memory segment on the client side.

    Each top-level array built on the mapping registers a finalizer that
    derefs this lease; numpy's base-chain keeps a top-level array alive as
    long as any derived view of it exists, so the last deref really is the
    last reader.  ``deref`` runs in GC context — it may fire on ANY thread
    at ANY allocation, including while that thread holds the client's
    locks — so it must be lock-free: it only moves the lease onto the
    owning client's release deque (GIL-atomic append).  The client's
    janitor thread does the actual unmapping and the ``shm_release`` RPC."""

    __slots__ = ("name", "seg", "_tokens", "_done_buf")

    def __init__(self, name: str, seg, n_arrays: int, done_buf):
        self.name = name
        self.seg = seg
        self._tokens = [None] * n_arrays
        self._done_buf = done_buf

    def deref(self) -> None:
        try:
            self._tokens.pop()
        except IndexError:  # pragma: no cover - duplicate final deref
            return
        if not self._tokens:
            # racing final derefs may BOTH land here (pop then observe
            # empty) — the janitor dedupes by name, so that's harmless
            self._done_buf.append(self)


class RemoteVideoStore:
    """Connect to a :class:`~repro.core.server.VideoStoreServer`."""

    def __init__(self, path: Optional[str] = None, *,
                 host: Optional[str] = None, port: Optional[int] = None,
                 timeout: Optional[float] = None,
                 codec: Optional[str] = None,
                 max_frame_bytes: int = wire.DEFAULT_MAX_FRAME_BYTES,
                 want_plans: bool = True,
                 transport: Optional[str] = None,
                 retries: int = 0, retry_backoff: float = 0.05):
        """``retries`` > 0 turns on reconnect-with-retry for *idempotent*
        RPCs (scans, explain, stats, …): a ConnectionError tears the
        socket down, redials, and re-sends, backing off
        ``retry_backoff * attempt`` seconds between tries.  Mutations
        never retry — the server may have applied one before the
        connection died — so they surface the error.  The default 0
        keeps the legacy fail-fast behaviour.

        ``timeout`` is the connect timeout AND the per-RPC deadline: a
        call whose reply hasn't arrived within ``timeout`` seconds severs
        the connection and raises ``ConnectionClosed`` — a hung (not
        dead) node fails fast instead of blocking the calling thread
        forever, so a router can fail over.  ``None`` (default) waits
        indefinitely.  RPCs that legitimately block server-side
        (``drain_tuner(timeout=t)``) extend the deadline by their own
        wait."""
        if (path is None) == (host is None):
            raise ValueError("give exactly one of path= (unix socket) or "
                             "host=/port= (tcp)")
        if host is not None and port is None:
            raise ValueError("host= needs port= (tcp)")
        self.codec = codec
        self.max_frame_bytes = int(max_frame_bytes)
        self.want_plans = bool(want_plans)
        self.transport_mode = resolve_transport(transport)
        self.retries = int(retries)
        self.retry_backoff = float(retry_backoff)
        self._path, self._host, self._port = path, host, port
        self._timeout = timeout
        self._send_lock = threading.Lock()
        self._pending: dict[int, Future] = {}
        self._pending_lock = threading.Lock()
        self._dead: Optional[BaseException] = None
        self._next_id = 0
        self._closed = False
        self._last_ingest_epochs: dict[int, int] = {}
        self._leases: dict[str, _SegmentLease] = {}
        self._lease_lock = threading.Lock()
        # leases whose last view was GC'd, appended lock-free by
        # finalizers; drained (unmap + release RPC) by the janitor thread
        self._done_leases: deque = deque()
        self._janitor: Optional[threading.Thread] = None
        self._janitor_stop = threading.Event()
        self._transport = "npz"
        self._sock = self._connect()
        self._reader = self._start_reader()
        try:
            self._transport = self._negotiate_transport()
        except BaseException:
            self.close()
            raise

    # ------------------------------------------------------------ plumbing
    def _connect(self) -> socket.socket:
        if self._path is not None:
            sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            sock.settimeout(self._timeout)
            sock.connect(self._path)
        else:
            sock = socket.create_connection((self._host, self._port),
                                            timeout=self._timeout)
        # the socket itself stays blocking after connect: a recv timeout
        # would fire in the reader thread during any idle gap and poison
        # the connection.  The per-RPC deadline is enforced in _result()
        # instead — only calls with an outstanding reply are on the clock
        sock.settimeout(None)
        return sock

    def _start_reader(self) -> threading.Thread:
        t = threading.Thread(target=self._read_loop, args=(self._sock,),
                             name="tasm-client-reader", daemon=True)
        t.start()
        return t

    def _reconnect(self) -> None:
        """Tear down the dead connection and dial a fresh one.  Futures
        pending on the old connection were already failed by its reader's
        death sweep (joined here, so the sweep can't race the reset);
        requests sent afterwards ride the new socket."""
        with self._send_lock:
            if self._closed:
                raise RuntimeError("remote store is closed")
            try:
                self._sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                self._sock.close()
            except OSError:
                pass
            self._reader.join(timeout=5)
            self._sock = self._connect()  # may raise: _dead stays set
            with self._pending_lock:
                self._dead = None
            self._reader = self._start_reader()
        # leases from the old connection are already server-reclaimed (its
        # drop sweep); our mappings stay valid (POSIX unlink semantics) and
        # their finalizer releases turn into ignored unknown-name RPCs.
        # Negotiation is a normal RPC, so it must run OUTSIDE _send_lock.
        self._transport = self._negotiate_transport()

    # ---------------------------------------------------------- transport
    @property
    def transport(self) -> str:
        """What this connection's scan replies ride: ``"shm"`` or
        ``"npz"``."""
        return self._transport

    def _negotiate_transport(self) -> str:
        """Probe for the zero-copy reply path: attach the server's nonce
        segment, read the nonce back, and echo it through ``shm_enable`` —
        proof that both sides map the SAME /dev/shm (a remote peer, or a
        container with a private shm namespace, fails the readback and
        stays on npz).  ``transport="shm"`` escalates any failure;
        ``"auto"`` falls back silently; ``"socket"`` never probes."""
        mode = self.transport_mode
        if mode == "socket":
            return "npz"
        if mode == "auto" and (self._path is None or not shm_available()):
            return "npz"  # TCP peers don't share a host; don't even probe
        try:
            probe = self._result(self._request("shm_probe"), "shm_probe")
            if not probe.get("enabled"):
                raise RuntimeError(
                    "server declines shared-memory transport")
            seg = attach_segment(probe["segment"])
            try:
                nonce = bytes(seg.buf[:int(probe["nbytes"])]).hex()
            finally:
                seg.close()
            if not self._result(
                    self._request("shm_enable", segment=probe["segment"],
                                  nonce=nonce), "shm_enable"):
                raise RuntimeError("shared-memory nonce verification "
                                   "failed")
            return "shm"
        except Exception as e:  # noqa: BLE001 - fallback is the contract
            if mode == "shm":
                raise RuntimeError(
                    f"transport='shm' unavailable: {e}") from e
            return "npz"

    def _shm_read(self, shm_doc: dict) -> list:
        """``wire`` shm reader: map the reply's segment and build
        read-only array views onto it (zero copies).  Runs on the reader
        thread, so a bad descriptor poisons only this connection."""
        name = str(shm_doc["seg"])
        items = shm_doc.get("items") or []
        seg = attach_segment(name)
        if not items:  # degenerate: no arrays — nothing to hold the lease
            seg.close()
            self._release_segments([name])
            return []
        lease = _SegmentLease(name, seg, len(items), self._done_leases)
        views = []
        for off, shape, dtype in items:
            shape = tuple(int(s) for s in shape)
            count = 1
            for s in shape:
                count *= s
            a = np.frombuffer(seg.buf, dtype=np.dtype(str(dtype)),
                              count=count, offset=int(off))
            a.flags.writeable = False
            a = a.reshape(shape)
            weakref.finalize(a, lease.deref)
            views.append(a)
        with self._lease_lock:
            self._leases[name] = lease
            if self._janitor is None:
                self._janitor = threading.Thread(
                    target=self._janitor_loop,
                    name="tasm-client-janitor", daemon=True)
                self._janitor.start()
        return views

    def _janitor_loop(self) -> None:
        """Drain GC'd leases every 50 ms: unmap the segment and tell the
        server to unlink it.  A dedicated thread because finalizers must
        not unmap or RPC themselves — they fire mid-allocation on
        arbitrary threads, possibly while THAT thread holds the very
        locks the release path needs."""
        while not self._janitor_stop.wait(0.05):
            self._drain_done_leases()
        self._drain_done_leases()

    def _drain_done_leases(self) -> None:
        names = []
        seen = set()
        while True:
            try:
                lease = self._done_leases.popleft()
            except IndexError:
                break
            if lease.name in seen:  # racing final derefs may duplicate
                continue
            seen.add(lease.name)
            try:
                lease.seg.close()
            except BufferError:  # pragma: no cover - dealloc mid-flight
                self._done_leases.append(lease)  # retry next tick
                continue
            names.append(lease.name)
        if names:
            self._release_segments(names)

    def _release_segments(self, names: list) -> None:
        """Fire-and-forget lease release (a redundant release of an
        already-reclaimed name is ignored by the server).  Connection
        failures are swallowed — a dead connection's leases are reclaimed
        by the server's drop sweep."""
        with self._lease_lock:
            for n in names:
                self._leases.pop(n, None)
        try:
            self._request("shm_release", segments=list(names))
        except BaseException:  # noqa: BLE001 - best effort
            pass

    def _flush_leases(self) -> None:
        """Release every outstanding lease and wait briefly for the
        server to acknowledge — close() calls this BEFORE the socket goes
        down so a well-behaved exit leaves zero segments behind even if
        this process never runs another GC."""
        self._drain_done_leases()
        with self._lease_lock:
            names, self._leases = list(self._leases), {}
        if not names:
            return
        try:
            self._request("shm_release", segments=names).result(timeout=5)
        except BaseException:  # noqa: BLE001 - server sweep covers us
            pass

    def _with_retry(self, fn):
        """Run ``fn`` (which must be safe to repeat), reconnecting and
        re-trying on connection-level failures up to ``self.retries``
        times with linear backoff."""
        attempt = 0
        while True:
            try:
                return fn()
            except (wire.ConnectionClosed, wire.WireError, OSError):
                attempt += 1
                if attempt > self.retries:
                    raise
                time.sleep(self.retry_backoff * attempt)
                try:
                    self._reconnect()
                except OSError:
                    pass  # still down: next attempt fails fast, re-counts

    def _read_loop(self, sock: socket.socket) -> None:
        err: BaseException
        try:
            while True:
                resp = wire.read_frame(sock,
                                       max_bytes=self.max_frame_bytes,
                                       shm_reader=self._shm_read)
                rid = resp.get("id")
                with self._pending_lock:
                    fut = self._pending.pop(rid, None)
                if fut is not None:
                    if resp.get("ok"):
                        fut.set_result(resp.get("value"))
                    else:
                        try:
                            _raise_remote(resp.get("error") or {})
                        except BaseException as e:  # noqa: BLE001
                            fut.set_exception(e)
                # clear the loop locals NOW: left bound while blocked in
                # recv they would pin the reply's arrays (and their shm
                # leases) until the next frame happens to arrive
                fut = resp = None
        except BaseException as e:  # noqa: BLE001 - fail all pending
            err = e
        if isinstance(err, wire.ConnectionClosed):
            err = wire.ConnectionClosed("server closed the connection")
        with self._pending_lock:
            # _dead is set under the same lock that registers futures, so
            # a request can never slip into _pending after this sweep and
            # hang unresolved forever
            self._dead = err
            pending, self._pending = dict(self._pending), {}
        for fut in pending.values():
            fut.set_exception(err)

    def _request(self, op: str, **params) -> Future:
        fut: Future = Future()
        fut.set_running_or_notify_cancel()
        with self._send_lock:
            if self._closed:
                raise RuntimeError("remote store is closed")
            rid = self._next_id
            self._next_id += 1
            with self._pending_lock:
                if self._dead is not None:
                    # reader thread is gone — a write might still land in
                    # the OS buffer, but nothing will ever resolve the
                    # future: fail fast instead
                    raise wire.ConnectionClosed(
                        f"connection lost: {self._dead}")
                self._pending[rid] = fut
            try:
                wire.write_frame(self._sock, {"id": rid, "op": op, **params},
                                 codec=self.codec,
                                 max_bytes=self.max_frame_bytes)
            except BaseException:
                with self._pending_lock:
                    self._pending.pop(rid, None)
                raise
        return fut

    def _result(self, fut: Future, op: str, deadline=...):
        """Wait for an RPC reply, enforcing the per-RPC deadline.  A hung
        (not dead) node never replies and never drops the socket; without
        a deadline that blocks the calling thread — a router serving
        thread — forever.  On expiry the connection is severed (failing
        every pipelined call on it, exactly as if the node died) and
        ``ConnectionClosed`` surfaces so retry/failover machinery treats
        the node as down."""
        if deadline is ...:
            deadline = self._timeout
        if deadline is None:
            return fut.result()
        try:
            return fut.result(timeout=deadline)
        except _FutTimeout:
            try:
                self._sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            raise wire.ConnectionClosed(
                f"RPC {op!r} exceeded the {deadline}s deadline "
                f"(node hung?)") from None

    def _call(self, op: str, _deadline=..., **params):
        if self.retries and op in _IDEMPOTENT_OPS:
            return self._with_retry(
                lambda: self._result(self._request(op, **params), op,
                                     _deadline))
        return self._result(self._request(op, **params), op, _deadline)

    def close(self) -> None:
        with self._send_lock:
            if self._closed:
                return
        # release outstanding shm leases over the still-open connection
        # (idempotent if two closers race — the server ignores unknown
        # names); must precede _closed, which _request refuses
        self._flush_leases()
        with self._send_lock:
            if self._closed:
                return
            self._closed = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()
        self._reader.join(timeout=5)
        self._janitor_stop.set()
        if self._janitor is not None:
            self._janitor.join(timeout=5)

    def __enter__(self) -> "RemoteVideoStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # --------------------------------------------------------------- admin
    def ping(self) -> dict:
        return self._call("ping")

    def videos(self) -> list[str]:
        return self._call("videos")

    def __contains__(self, name: str) -> bool:
        return name in self.videos()

    def stats(self) -> dict:
        return self._call("stats")

    def epochs(self, video: str) -> dict[int, int]:
        """``{sot_id: layout epoch}`` on the server — the remote twin of
        :meth:`VideoStore.epochs` (replica consistency checks)."""
        return {int(s): int(e)
                for s, e in self._call("epochs", video=video)}

    @property
    def last_ingest_epochs(self) -> dict[int, int]:
        """Epoch table acknowledged by this client's most recent
        ``ingest`` (empty before any ingest)."""
        return dict(self._last_ingest_epochs)

    def shutdown_server(self) -> None:
        """Ask the server to stop (it replies, then shuts down)."""
        self._call("shutdown")

    @staticmethod
    def _video_kw_doc(encoder=None, policy=None, cost_model=None,
                      sot_len=None) -> dict:
        doc: dict = {}
        if encoder is not None:
            doc["encoder"] = dataclasses.asdict(encoder)
        if policy is not None:
            doc["policy"] = policy_spec(policy) \
                if isinstance(policy, Policy) else policy
        if cost_model is not None:
            doc["cost_model"] = {
                "beta": cost_model.beta, "gamma": cost_model.gamma,
                "r_squared": cost_model.r_squared,
                "io_per_pixel": cost_model.io_per_pixel,
                "encode_per_pixel": cost_model.encode_per_pixel,
                "encode_per_tile": cost_model.encode_per_tile}
        if sot_len is not None:
            doc["sot_len"] = int(sot_len)
        return doc

    def add_video(self, name: str, *, encoder=None, policy=None,
                  cost_model=None, sot_len=None) -> None:
        self._call("add_video", name=name,
                   **self._video_kw_doc(encoder, policy, cost_model,
                                        sot_len))

    def ingest(self, name: str, frames: np.ndarray, *, detections=None,
               initial_layouts=None, **video_kw) -> IngestStats:
        doc = self._call(
            "ingest", name=name, frames=np.ascontiguousarray(frames),
            detections=None if detections is None
            else [[[label, list(bbox)] for label, bbox in frame_dets]
                  for frame_dets in detections],
            initial_layouts=None if initial_layouts is None
            else [[int(s), list(lay.heights), list(lay.widths)]
                  for s, lay in initial_layouts.items()],
            **self._video_kw_doc(**video_kw))
        doc = dict(doc)
        # replica-aware ack: the server's post-ingest epoch table, kept
        # for callers (the cluster router) that verify replicas landed on
        # the same physical generation
        self._last_ingest_epochs = {
            int(s): int(e) for s, e in doc.pop("epochs", None) or []}
        return IngestStats(**doc)

    def add_detections(self, video: str, detections_by_frame: dict) -> None:
        self._call("add_detections", video=video,
                   pairs=[[int(f), [[label, list(bbox)]
                                    for label, bbox in dets]]
                          for f, dets in
                          sorted(detections_by_frame.items())])

    def add_metadata(self, video: str, frame: int, label: str,
                     x1: int, y1: int, x2: int, y2: int) -> None:
        self._call("add_metadata", video=video, frame=int(frame),
                   label=label, x1=int(x1), y1=int(y1), x2=int(x2),
                   y2=int(y2))

    # ---------------------------------------------------------------- scan
    def scan(self, videos, labels=None,
             frames: Optional[tuple[int, int]] = None) -> RemoteScanQuery:
        q = RemoteScanQuery(self, videos)
        if labels is not None:
            q = q.labels(labels)
        if frames is not None:
            q = q.frames(*frames)
        return q

    @staticmethod
    def _as_plan(query) -> ScanPlan:
        if isinstance(query, ScanQuery):
            return query.plan()
        if isinstance(query, ScanPlan):
            return query
        raise TypeError(f"cannot execute {type(query).__name__} remotely; "
                        "want ScanQuery or ScanPlan")

    def _submit_plan(self, plan: ScanPlan) -> Future:
        raw = self._request("scan", plan=plan.to_doc(),
                            want_plan=self.want_plans)
        fut: Future = Future()
        fut.set_running_or_notify_cancel()
        raw.add_done_callback(lambda f: _chain_result(
            f, fut, ScanResult.from_doc))
        return fut

    def execute(self, query) -> ScanResult:
        """Execute one scan (accepts a ScanQuery or logical ScanPlan).
        Scans are idempotent, so with ``retries`` set a dropped
        connection redials and re-sends; async ``submit()`` futures stay
        fail-fast (the caller owns their lifecycle)."""
        plan = self._as_plan(query)
        if self.retries:
            return self._with_retry(
                lambda: self._result(self._submit_plan(plan), "scan"))
        return self._result(self._submit_plan(plan), "scan")

    def execute_many(self, queries) -> list[ScanResult]:
        """One merged batch on the server (union-of-tiles decode across the
        batch), results in submission order — the remote twin of
        ``VideoStore.execute_many``."""
        docs = self._call(
            "execute_many",
            plans=[self._as_plan(q).to_doc() for q in queries],
            want_plan=self.want_plans)
        return [ScanResult.from_doc(d) for d in docs]

    def _explain(self, plan: ScanPlan) -> PhysicalPlan:
        return PhysicalPlan.from_doc(self._call("explain",
                                                plan=plan.to_doc()))

    def serve(self) -> RemoteServingSession:
        """Open a concurrent-submission session (server-side
        micro-batching merges across every client's in-flight scans)."""
        return RemoteServingSession(self)

    # -------------------------------------------------------------- tuning
    def retile(self, video: str, sot_id: int, new_layout) -> float:
        return self._call("retile", video=video, sot_id=int(sot_id),
                          heights=list(new_layout.heights),
                          widths=list(new_layout.widths))

    def drain_tuner(self, timeout: Optional[float] = None) -> TunerStats:
        # the server legitimately blocks for up to `timeout` before
        # replying — extend the per-RPC deadline by that wait
        dl = ... if self._timeout is None \
            else self._timeout + (timeout or 0.0)
        return TunerStats(**self._call("drain_tuner", timeout=timeout,
                                       _deadline=dl))

    def tuner_stats(self) -> TunerStats:
        return TunerStats(**self._call("tuner_stats"))

    def drain_prefetch(self, timeout: Optional[float] = None) -> CacheStats:
        """Remote twin of :meth:`VideoStore.drain_prefetch` — block until
        the server's predictive decodes land, return its cache stats."""
        dl = ... if self._timeout is None \
            else self._timeout + (timeout or 0.0)
        return CacheStats(**self._call("drain_prefetch", timeout=timeout,
                                       _deadline=dl))

    def config(self) -> dict:
        """The server's resolved runtime configuration as config objects:
        ``{"cache": CacheConfig, "tuning": TuningConfig,
        "decode": DecodeConfig}`` — the exact surface the server was
        started with (see ``core/config.py``).  Against a cluster router
        the reply is per node: ``{"nodes": {name: {...}|None}}``."""
        doc = self._call("config")
        if "nodes" in doc:      # router front end: one config set per node
            return {"nodes": {name: None if d is None
                              else _parse_config_doc(d)
                              for name, d in doc["nodes"].items()}}
        return _parse_config_doc(doc)

    # ----------------------------------------------------- replica streaming
    # The cluster repair data plane: each chunk is one request/reply RPC,
    # so copies are resumable at chunk granularity.  Called by the repair
    # worker (core/repair.py), not by applications.
    def export_meta(self, video: str) -> dict:
        """The source video's manifest doc (incl. its SOT epoch table)."""
        return self._call("export_meta", video=video)

    def export_chunk(self, video: str, sot_id: int, tile_idx: int) -> dict:
        """One encoded tile stream with its content checksum, stamped with
        the epoch it was read at (the caller re-streams on a mismatch)."""
        return self._call("export_chunk", video=video, sot_id=int(sot_id),
                          tile_idx=int(tile_idx))

    def import_begin(self, video: str) -> dict:
        """Open or resume the destination's staging namespace; returns
        the chunks already staged intact."""
        return self._call("import_begin", video=video)

    def import_chunk(self, video: str, sot_id: int, epoch: int,
                     tile_idx: int, enc: dict, checksum: str) -> None:
        """Stage one chunk (checksum re-verified server-side)."""
        self._call("import_chunk", video=video, sot_id=int(sot_id),
                   epoch=int(epoch), tile_idx=int(tile_idx), enc=enc,
                   checksum=checksum)

    def import_commit(self, video: str, doc: dict,
                      min_epochs: Optional[dict] = None) -> dict:
        """Atomically flip the staged copy live (after epoch-table and
        per-tile checksum verification)."""
        return self._call(
            "import_commit", video=video, doc=doc,
            min_epochs=[[int(s), int(e)]
                        for s, e in sorted((min_epochs or {}).items())])

    def import_abort(self, video: str) -> None:
        self._call("import_abort", video=video)


def _chain_result(src: Future, dst: Future, decode) -> None:
    try:
        dst.set_result(decode(src.result()))
    except BaseException as e:  # noqa: BLE001 - surfaced via the future
        dst.set_exception(e)
