"""PhysicalTuner: asynchronous physical-design tuning off the scan path.

TASM's headline property is that the storage manager tunes tile layouts
*dynamically* as the query workload evolves (paper §4.3–4.4).  Running each
policy-triggered re-tile synchronously inside the scan that triggered it —
the pre-tuner behaviour — makes the unlucky query pay the full re-encode
latency.  This module moves that work into a background subsystem, the way
VStore separates configuration "backfill" from query serving and the online
indexing framing of §4.4 presumes tuning is amortized off the critical path:

- **Observation emission** — the scheduler's per-SOT policy hooks no longer
  call ``policy.observe`` or ``engine._retile``.  In ``"background"`` mode
  they append a lightweight :class:`Observation` (video, sot_id, labels,
  frame range, requested boxes) to a *bounded* workload log and return
  immediately; queries are never charged re-encode time
  (``ScanStats.retile_s`` stays 0, tuning work shows up in
  :class:`TunerStats` instead).
- **Tuning loop** — a daemon thread drains the log in submission order,
  replays each observation through the video's policy (``observe`` is a pure
  proposal function: it may mutate policy runtime state but never touches
  tile data), **coalesces** repeated proposals for the same SOT keeping only
  the newest, scores each winner through the §4.1 what-if interface
  (estimated decode savings of the observed workload vs. the re-encode cost
  of adopting the layout — recorded in :class:`TunerStats`; admission is
  delegated to the policies' own alpha/regret gates so ``"background"``
  converges to the same layouts as ``"inline"``), and applies winners via
  the durable, lock-taking, epoch-bumping ``VideoStore`` retile path, so
  in-flight scans and the tile cache stay exactly as consistent as they are
  for a foreground ``retile``.
- **Crash-safe ordering** — a drained batch is only *removed* from the log
  after the resulting state (policy runtime state + new layouts) has been
  persisted to the video's manifest shard, so a flush can never drop an
  observation whose effects were not yet durable.
- **Modes** — ``"background"`` (the ``VideoStore`` default) as above;
  ``"inline"`` preserves the old synchronous semantics bit-for-bit (observe
  + retile inside the scan, charged to ``ScanStats.retile_s``) for policy
  convergence tests and per-query cost attribution benchmarks; ``"off"``
  disables query-driven tuning entirely (ingest-time pre-tiling still runs).

``VideoStore.drain_tuner()`` is the deterministic barrier: it returns once
every observation emitted before the call has been replayed, every surviving
proposal applied, and the resulting state persisted — tests and benchmarks
use it to compare ``"background"`` against ``"inline"`` exactly.

Coalescing tradeoff: a policy that resets internal bookkeeping when it
*proposes* (RegretPolicy zeroes the winning alternative's regret) cannot
tell that a superseded proposal was never re-encoded — within a batch the
newer proposal wins and the older one's reset regret is simply gone, so
under large unflushed backlogs background tuning can lag inline's
adoption schedule for such policies (the per-query ``drain_tuner()``
cadence reproduces inline exactly; see the ROADMAP open item on proposal
feedback).  Layout-*content* is unaffected: whatever layout is eventually
adopted produces bit-identical pixels regardless of the path taken.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, replace
from typing import TYPE_CHECKING, Optional

from repro.core.layout import TileLayout
from repro.core.policies import ALPHA, Policy, QueryInfo

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.engine import VideoEntry, VideoStore
    from repro.core.query import SOTScan

#: valid VideoStore ``tuning=`` modes
TUNING_MODES = ("background", "inline", "off")

#: valid ``admission=`` modes: "policy" trusts the policies' own
#: alpha/regret gates (background adopts exactly what inline would);
#: "gated" additionally scores every coalesced winner through the §4.1
#: what-if interface, DEFERS net-negative proposals
#: (est_savings < alpha * est_reencode, alpha from the proposing policy),
#: and applies the survivors ranked by net benefit — a budgeted tuner that
#: spends re-encode time where the observed workload says it pays off
ADMISSION_MODES = ("policy", "gated")

#: default bound on the workload log (observations, not bytes)
DEFAULT_MAX_LOG = 4096

#: idle worker threads exit after this long with an empty log (they restart
#: on the next observation), so a dropped-but-never-closed store is not
#: pinned in memory forever by its parked tuner thread
IDLE_EXIT_S = 5.0


@dataclass
class Observation:
    """One executed per-SOT query as recorded in the workload log.

    Deliberately *not* a :class:`~repro.core.policies.QueryInfo`: the SOT
    record is looked up at replay time, so the policy always sees the
    layout of record (a foreground retile may land between emission and
    replay), exactly as it would have inline.
    """
    video: str
    sot_id: int
    labels: tuple
    query_range: tuple
    boxes_by_frame: dict


@dataclass
class TunerStats:
    """Cumulative tuning accounting (see also ``ScanStats.retile_s``: in
    ``"background"`` mode queries are never charged re-encode time — it all
    lands here).

    - ``observed``/``dropped`` — observations appended to / evicted from the
      bounded workload log (an eviction means the tuner fell behind and the
      oldest workload evidence was discarded).
    - ``proposals`` — layouts returned by policy ``observe`` calls.
    - ``coalesced`` — proposals superseded by a newer proposal for the same
      SOT within one drain batch (their re-encode was skipped entirely).
    - ``applied``/``skipped`` — coalesced winners re-encoded vs. discarded
      as no-ops (the SOT already had the proposed layout, or the video/SOT
      disappeared before application).
    - ``deferred`` — winners rejected by ``admission="gated"`` as
      net-negative (``est_savings_s < alpha * est_reencode_s``); the
      proposing policy's ``on_superseded`` hook restores its bookkeeping,
      so a deferred retile re-proposes once more workload accumulates.
    - ``retile_s`` — seconds spent re-encoding applied retiles.
    - ``tuning_s`` — total wall seconds inside drain batches (replay +
      what-if scoring + re-encode); ``tuning_s - retile_s`` is the pure
      tuning overhead.
    - ``est_savings_s``/``est_reencode_s`` — §4.1 what-if scores of applied
      retiles: estimated decode seconds saved on the observed workload, and
      estimated re-encode cost paid.
    """
    observed: int = 0
    dropped: int = 0
    proposals: int = 0
    coalesced: int = 0
    applied: int = 0
    skipped: int = 0
    deferred: int = 0
    retile_s: float = 0.0
    tuning_s: float = 0.0
    est_savings_s: float = 0.0
    est_reencode_s: float = 0.0


class PhysicalTuner:
    """Background physical-design tuner owned by a :class:`VideoStore`.

    The scan path talks to it through :meth:`on_scan` (mode dispatch lives
    here so the scheduler stays a pure executor); everything else —
    :meth:`drain`, :meth:`pause`/:meth:`resume`, :meth:`stop`,
    :meth:`stats` — is control surface.
    """

    def __init__(self, engine: "VideoStore", mode: str = "background", *,
                 admission: str = "policy", max_log: int = DEFAULT_MAX_LOG):
        if mode not in TUNING_MODES:
            raise ValueError(f"unknown tuning mode {mode!r}; "
                             f"want one of {TUNING_MODES}")
        if admission not in ADMISSION_MODES:
            raise ValueError(f"unknown admission mode {admission!r}; "
                             f"want one of {ADMISSION_MODES}")
        self.engine = engine
        self.mode = mode
        self.admission = admission
        self.max_log = max(1, int(max_log))
        self._log: deque[Observation] = deque()
        #: the batch currently being replayed/applied: moved out of _log at
        #: take time (so bounded-log overflow can never evict a member of
        #: an in-flight batch) but still counted as backlog until its
        #: effects are persisted — drain()/crash-safe ordering see it
        self._inflight: list[Observation] = []
        self._cv = threading.Condition()
        self._thread: Optional[threading.Thread] = None
        self._stopping = False
        self._busy = False      # a drained batch is being replayed/applied
        self._paused = False
        self._stats = TunerStats()
        #: last exception a drain batch raised (a failing batch is dropped
        #: so tuning continues; the next drain() re-raises it)
        self.last_error: Optional[BaseException] = None

    # ------------------------------------------------------------ scan hook
    def on_scan(self, sot_scans: "list[SOTScan]") -> float:
        """Per-query policy hook, called by the scheduler (which holds its
        lock) once per finished plan.  Returns the re-encode seconds to
        charge to the query: >0 only in ``"inline"`` mode — background
        emission is O(1) per SOT and never re-encodes."""
        if not sot_scans:
            return 0.0
        # workload-log tap: the scheduler's prefetch predictor watches the
        # full query stream — unconditionally, because prediction needs to
        # see every scan, not just those whose policy listens, and works
        # even with tuning "off"/"inline".  No-op unless CacheConfig
        # enables prefetch; caller already holds the scheduler lock.
        self.engine.scheduler.note_scan(sot_scans)
        if self.mode == "off":
            return 0.0
        if self.mode == "inline":
            return self._observe_inline(sot_scans)
        emitted = False
        with self._cv:
            for ss in sot_scans:
                if not self._policy_listens(ss.video):
                    continue
                if len(self._log) >= self.max_log:
                    self._log.popleft()
                    self._stats.dropped += 1
                self._log.append(Observation(
                    video=ss.video, sot_id=ss.sot_id, labels=ss.labels,
                    query_range=ss.query_range,
                    boxes_by_frame=ss.boxes_by_frame))
                self._stats.observed += 1
                emitted = True
            if emitted:
                self._ensure_thread()
                self._cv.notify_all()
        return 0.0

    def _policy_listens(self, video: str) -> bool:
        """Skip emission for videos whose policy never reacts to queries
        (base ``observe``) — no point waking the tuner for NoTilingPolicy."""
        entry = self.engine._videos.get(video)
        return entry is not None and \
            type(entry.policy).observe is not Policy.observe

    def _observe_inline(self, sot_scans: "list[SOTScan]") -> float:
        """The pre-tuner synchronous path, bit-for-bit: observe + retile
        inside the scan, under the scheduler lock the caller holds."""
        engine = self.engine
        t0 = time.perf_counter()
        retile_s = 0.0
        for ss in sot_scans:
            # same filter as background emission, so TunerStats.observed
            # counts the same events in both modes
            if not self._policy_listens(ss.video):
                continue
            entry = engine._videos.get(ss.video)
            if entry is None:
                continue
            rec = entry.store.sots[ss.sot_id]
            qi = QueryInfo(ss.video, ss.labels, ss.query_range,
                           ss.boxes_by_frame, rec)
            proposal = entry.policy.observe(qi, entry.index, entry.store,
                                            entry.cost_model)
            with self._cv:
                self._stats.observed += 1
                # unlike the background path, proposal-less observes do NOT
                # dirty the shard: inline saves stay on the pre-tuner
                # cadence (retiles + close) so no full-shard rewrite lands
                # inside the timed scan path; the mutation is *noted* and
                # VideoStore.close() flushes it durably
                if entry.policy.stateful:
                    if proposal is not None:
                        engine._mark_dirty(ss.video)
                    else:
                        engine._stale_policy_state.add(ss.video)
                if proposal is not None:
                    self._stats.proposals += 1
            if proposal is not None:
                dt = engine._retile(ss.video, ss.sot_id, proposal)
                # resolved synchronously (applied, or already installed):
                # the policy's proposal bookkeeping is now legitimate
                entry.policy.on_applied(ss.sot_id, proposal)
                retile_s += dt
                with self._cv:
                    if dt:
                        self._stats.applied += 1
                        self._stats.retile_s += dt
                    else:
                        self._stats.skipped += 1
        with self._cv:
            self._stats.tuning_s += time.perf_counter() - t0
        return retile_s

    # ------------------------------------------------------------- control
    def stats(self) -> TunerStats:
        """Snapshot of the cumulative counters."""
        with self._cv:
            return replace(self._stats)

    @property
    def backlog(self) -> int:
        """Observations waiting in the workload log (including any batch
        currently being replayed)."""
        with self._cv:
            return len(self._log) + len(self._inflight)

    def pause(self) -> None:
        """Stop draining (observations keep accumulating).  A paused tuner
        lets tests build a multi-observation batch deterministically;
        :meth:`resume` before :meth:`drain`."""
        with self._cv:
            self._paused = True

    def resume(self) -> None:
        with self._cv:
            self._paused = False
            if self._log:
                self._ensure_thread()
            self._cv.notify_all()

    def drain(self, timeout: Optional[float] = None) -> None:
        """Barrier: block until every observation emitted before this call
        has been replayed, surviving proposals applied, and the resulting
        state persisted.  No-op in ``"inline"``/``"off"`` modes (there is
        nothing asynchronous to wait for).  Raises :class:`TimeoutError`
        on timeout; a paused tuner must be resumed first or the wait
        cannot finish."""
        if self.mode != "background":
            return
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            if self._log:
                self._ensure_thread()
                self._cv.notify_all()
            while self._log or self._busy:
                remaining = None if deadline is None \
                    else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"drain_tuner timed out with {len(self._log)} "
                        "observations outstanding")
                self._cv.wait(remaining)
            if self.last_error is not None:
                err, self.last_error = self.last_error, None
                raise err

    def stop(self) -> None:
        """Flush the remaining log, persist, and stop the worker thread.
        Idempotent; a later scan restarts the thread on demand.  Callers
        must NOT hold the scheduler lock (the flush needs to take it)."""
        with self._cv:
            self._stopping = True
            self._paused = False
            thread = self._thread
            self._cv.notify_all()
        if thread is not None:
            thread.join()
        # thread never ran (or died): flush whatever is left synchronously
        while True:
            batch = self._take_batch()
            if not batch:
                break
            self._process_batch(batch)
        with self._cv:
            self._thread = None
            self._stopping = False

    # -------------------------------------------------------------- worker
    def _ensure_thread(self) -> None:
        """Caller holds ``_cv``."""
        if self._stopping or (self._thread is not None
                              and self._thread.is_alive()):
            return
        self._thread = threading.Thread(target=self._run, name="tasm-tuner",
                                        daemon=True)
        self._thread.start()

    def _run(self) -> None:
        while True:
            with self._cv:
                idle_since = time.monotonic()
                while not self._stopping and (self._paused or not self._log):
                    self._cv.wait(IDLE_EXIT_S)
                    if not self._paused and not self._log \
                            and not self._stopping \
                            and time.monotonic() - idle_since >= IDLE_EXIT_S:
                        # idle exit: stop pinning the engine from a parked
                        # thread; _ensure_thread restarts us on demand
                        if self._thread is threading.current_thread():
                            self._thread = None
                        return
                if self._stopping and (self._paused or not self._log):
                    return
            batch = self._take_batch()
            if batch:
                self._process_batch(batch)

    def _take_batch(self) -> list[Observation]:
        """Move the whole backlog into the in-flight slot.  The entries
        stay part of :attr:`backlog` until their effects are persisted
        (crash-safe ordering), but live outside ``_log`` so a concurrent
        bounded-log overflow can only evict not-yet-taken observations."""
        with self._cv:
            if not self._log:
                return []
            self._busy = True
            self._inflight = list(self._log)
            self._log.clear()
            return self._inflight

    def _process_batch(self, batch: list[Observation]) -> None:
        """Replay one drained batch: observe in submission order, coalesce
        proposals per SOT (newest wins), score + apply, persist — then drop
        the batch from the log."""
        engine = self.engine
        t0 = time.perf_counter()
        proposals = coalesced = applied = skipped = deferred = 0
        retile_s = savings_s = reencode_s = 0.0
        # keyed (video, sot_id); insertion order = first-proposal order, so
        # application order is deterministic for a given batch.  The layout
        # is the NEWEST proposal (recorded with the epoch it was proposed
        # against); the observation list keeps every proposing query so the
        # what-if score reflects the whole observed workload
        pending: dict[tuple[str, int],
                      tuple[TileLayout, int, list[Observation]]] = {}
        # every pending proposal must reach exactly one feedback hook;
        # keys leave this set as they are resolved, and whatever an
        # aborted batch leaves behind is superseded in the error cleanup
        # (so RegretPolicy's zeroed regret is never simply lost)
        unresolved: set[tuple[str, int]] = set()
        err: Optional[BaseException] = None
        try:
            # replay phase: one lock hold PER observation (matching the
            # inline cadence), so concurrent scans interleave with the
            # replay of a large backlog instead of stalling behind it
            for obs in batch:
                with engine.scheduler.lock:
                    entry = engine._videos.get(obs.video)
                    if entry is None or obs.sot_id >= len(entry.store.sots):
                        continue  # video dropped since emission
                    rec = entry.store.sots[obs.sot_id]
                    qi = QueryInfo(obs.video, obs.labels, obs.query_range,
                                   obs.boxes_by_frame, rec)
                    proposal = entry.policy.observe(
                        qi, entry.index, entry.store, entry.cost_model)
                    if entry.policy.stateful:
                        engine._mark_dirty(obs.video)
                    if proposal is None:
                        continue
                    proposals += 1
                    key = (obs.video, obs.sot_id)
                    prev = pending.get(key)
                    if prev is not None:
                        coalesced += 1
                        # a *different* older layout will never re-encode:
                        # tell the policy so reset bookkeeping (RegretPolicy's
                        # zeroed regret) is restored instead of silently
                        # lost.  A re-proposal of the SAME layout is merely
                        # subsumed — the winner's eventual on_applied/
                        # on_superseded resolves every stacked proposal
                        if prev[0] != proposal:
                            entry.policy.on_superseded(obs.sot_id, prev[0])
                        prev[2].append(obs)
                        pending[key] = (proposal, rec.epoch, prev[2])
                    else:
                        pending[key] = (proposal, rec.epoch, [obs])
                    unresolved.add(key)
            # admission (``"gated"``): score every coalesced winner first,
            # defer the net-negative ones, and rank the survivors by net
            # benefit so a budgeted backlog re-encodes best-payoff-first.
            # ``"policy"`` applies in first-proposal order with no gate —
            # admission already happened inside the policies
            if self.admission == "gated":
                ranked = []
                for i, ((video, sot_id), (layout, epoch, obs_list)) in \
                        enumerate(pending.items()):
                    with engine.scheduler.lock:
                        entry = engine._videos.get(video)
                        if entry is None \
                                or sot_id >= len(entry.store.sots):
                            skipped += 1
                            unresolved.discard((video, sot_id))
                            continue
                        if entry.store.sots[sot_id].epoch != epoch:
                            # stale before scoring: a foreground retile
                            # won, so the current layout is a meaningless
                            # baseline — same skipped+superseded outcome
                            # the apply phase gives stale proposals
                            skipped += 1
                            entry.policy.on_superseded(sot_id, layout)
                            unresolved.discard((video, sot_id))
                            continue
                        saved, reenc = self._score(entry, sot_id, layout,
                                                   obs_list)
                        alpha = getattr(entry.policy, "alpha", ALPHA)
                        if saved < alpha * reenc:
                            deferred += 1
                            entry.policy.on_superseded(sot_id, layout)
                            unresolved.discard((video, sot_id))
                            continue
                    ranked.append((saved - alpha * reenc, -i,
                                   ((video, sot_id),
                                    (layout, epoch, obs_list),
                                    (saved, reenc))))
                ranked.sort(reverse=True)   # net benefit desc, ties FIFO
                order = [item for *_, item in ranked]
            else:
                order = [(k, v, None) for k, v in pending.items()]
            # apply phase: one lock hold PER re-encode, so concurrent
            # scans interleave between retiles instead of stalling for the
            # whole batch (epoch bumps keep interleaved plans consistent)
            for (video, sot_id), (layout, epoch, obs_list), score in order:
                with engine.scheduler.lock:
                    # NOTE: the key leaves `unresolved` only once its hook
                    # has fired (or no policy exists to notify) — if
                    # _retile/save below raises first, the error cleanup
                    # still supersedes this proposal instead of leaking it
                    entry = engine._videos.get(video)
                    if entry is None or sot_id >= len(entry.store.sots):
                        skipped += 1
                        unresolved.discard((video, sot_id))
                        continue
                    rec = entry.store.sots[sot_id]
                    if rec.epoch != epoch:
                        # a retile landed after this proposal was made:
                        # applying it would revert a newer foreground
                        # layout with a wasted re-encode — never applied,
                        # so the policy restores its bookkeeping
                        skipped += 1
                        entry.policy.on_superseded(sot_id, layout)
                        unresolved.discard((video, sot_id))
                        continue
                    if layout == rec.layout:
                        # already installed exactly this layout: the
                        # proposal's intent is satisfied without work
                        skipped += 1
                        entry.policy.on_applied(sot_id, layout)
                        unresolved.discard((video, sot_id))
                        continue
                    # gated mode already scored this winner and the epoch
                    # check above proves the inputs are unchanged: reuse it
                    # instead of paying the what-if walk a second time
                    saved, reenc = score if score is not None else \
                        self._score(entry, sot_id, layout, obs_list)
                    retile_s += engine._retile(video, sot_id, layout)
                    entry.policy.on_applied(sot_id, layout)
                    unresolved.discard((video, sot_id))
                    applied += 1
                    savings_s += saved
                    reencode_s += reenc
            with engine.scheduler.lock:
                if engine.dirty:
                    engine.save()  # BEFORE the batch leaves the backlog
        except Exception as e:   # noqa: BLE001 - keep the tuner alive
            err = e
            # resolve proposals the aborted batch never reached, so policy
            # bookkeeping is restored rather than leaked (best-effort: the
            # original error stays the one drain() re-raises)
            for key in unresolved:
                try:
                    with engine.scheduler.lock:
                        entry = engine._videos.get(key[0])
                        if entry is not None:
                            entry.policy.on_superseded(key[1],
                                                       pending[key][0])
                except Exception:   # noqa: BLE001 - cleanup must not mask
                    pass
        finally:
            # the batch is dropped even on failure (re-processing a batch
            # that raises would wedge the tuner); drain() re-raises the
            # recorded error so the failure is not silent
            with self._cv:
                self._inflight = []
                self._busy = False
                st = self._stats
                st.proposals += proposals
                st.coalesced += coalesced
                st.applied += applied
                st.skipped += skipped
                st.deferred += deferred
                st.retile_s += retile_s
                st.est_savings_s += savings_s
                st.est_reencode_s += reencode_s
                st.tuning_s += time.perf_counter() - t0
                if err is not None:
                    self.last_error = err
                self._cv.notify_all()

    def _score(self, entry: "VideoEntry", sot_id: int, layout: TileLayout,
               obs_list: "list[Observation]") -> tuple[float, float]:
        """§4.1 what-if score of adopting ``layout`` for one SOT: estimated
        decode seconds saved summed over every observation that proposed
        for the SOT this batch (the observed workload, not just the
        coalesced winner), and the estimated re-encode cost.  Recorded for
        observability — admission is the policies' job (alpha/regret
        gates), so background tuning adopts exactly what inline would."""
        walk = self.engine._sot_cost_walk
        saved = 0.0
        for obs in obs_list:
            cur = sum(c for rec, *_, c, _b in
                      walk(entry, obs.boxes_by_frame)
                      if rec.sot_id == sot_id)
            alt = sum(c for rec, *_, c, _b in
                      walk(entry, obs.boxes_by_frame,
                           layout_by_sot={sot_id: layout})
                      if rec.sot_id == sot_id)
            saved += cur - alt
        rec = entry.store.sots[sot_id]
        n_frames = rec.frame_end - rec.frame_start
        reenc = entry.cost_model.encode_cost(
            layout.total_pixels() * n_frames, layout.n_tiles)
        return saved, reenc
