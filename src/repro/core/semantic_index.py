"""Semantic index (paper §3.2–3.3).

A B+-tree clustered on (video, label, frame); leaf values are bounding boxes
plus the id of the tile layout epoch they map to (the "pointer to the
underlying tile on disk").  Populated incrementally through ``add`` — the
ADDMETADATA(video, frame, label, x1,y1,x2,y2) API — as detections arrive as a
byproduct of query execution.

Label predicates are CNF over labels (paper §3.1): a disjunctive clause
retrieves the union of its labels' boxes; a conjunction intersects the
regions of its clauses (pixel-level bbox intersection).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Optional, Sequence

from repro.core.btree import BPlusTree
from repro.core.layout import BBox

# CNF: conjunction of clauses; each clause is a tuple of alternative labels.
CNF = Sequence[Sequence[str]]


def parse_predicate(labels) -> CNF:
    """Accepts 'car', ['car','person'] (one disjunctive clause), or CNF."""
    if isinstance(labels, str):
        return ((labels,),)
    labels = list(labels)
    if labels and isinstance(labels[0], str):
        return (tuple(labels),)
    return tuple(tuple(c) for c in labels)


def _intersect(a: BBox, b: BBox) -> Optional[BBox]:
    y1 = max(a[0], b[0]); x1 = max(a[1], b[1])
    y2 = min(a[2], b[2]); x2 = min(a[3], b[3])
    if y1 < y2 and x1 < x2:
        return (y1, x1, y2, x2)
    return None


@dataclass
class Detection:
    bbox: BBox
    tile_epoch: int = -1  # which layout epoch the box is stored under


class SemanticIndex:
    """Clustered on (video, label, frame)."""

    def __init__(self, order: int = 32):
        self._tree = BPlusTree(order=order)
        self._labels: dict[str, set[str]] = {}

    def add(self, video: str, frame: int, label: str, bbox: BBox,
            tile_epoch: int = -1) -> None:
        self._tree.insert((video, label, frame), Detection(tuple(bbox), tile_epoch))
        self._labels.setdefault(video, set()).add(label)

    def add_metadata(self, video_id: str, frame: int, label: str,
                     x1: int, y1: int, x2: int, y2: int) -> None:
        """The paper's ADDMETADATA signature (x/y order as in §3.1)."""
        self.add(video_id, frame, label, (y1, x1, y2, x2))

    def labels(self, video: str) -> set[str]:
        return set(self._labels.get(video, set()))

    def boxes_for_label(self, video: str, label: str,
                        frame_range: Optional[tuple[int, int]] = None
                        ) -> dict[int, list[BBox]]:
        lo_f, hi_f = frame_range if frame_range else (0, 2 ** 60)
        out: dict[int, list[BBox]] = {}
        for (v, l, f), dets in self._tree.scan((video, label, lo_f),
                                               (video, label, hi_f)):
            out.setdefault(f, []).extend(d.bbox for d in dets)
        return out

    def query(self, video: str, labels, frame_range=None) -> dict[int, list[BBox]]:
        """CNF evaluation -> frame -> list of requested regions."""
        cnf = parse_predicate(labels)
        per_clause: list[dict[int, list[BBox]]] = []
        for clause in cnf:
            merged: dict[int, list[BBox]] = {}
            for label in clause:
                for f, boxes in self.boxes_for_label(video, label, frame_range).items():
                    merged.setdefault(f, []).extend(boxes)
            per_clause.append(merged)
        out = per_clause[0]
        for nxt in per_clause[1:]:
            conj: dict[int, list[BBox]] = {}
            for f, boxes in out.items():
                if f not in nxt:
                    continue
                inter = []
                for a in boxes:
                    for b in nxt[f]:
                        got = _intersect(a, b)
                        if got:
                            inter.append(got)
                if inter:
                    conj[f] = inter
            out = conj
        return out

    def frames_with_any(self, video: str, labels: Iterable[str],
                        frame_range=None) -> set[int]:
        out: set[int] = set()
        for label in labels:
            out.update(self.boxes_for_label(video, label, frame_range))
        return out

    def has_locations(self, video: str, labels: Iterable[str],
                      frame_range) -> bool:
        """True iff the index has at least one detection for every label in
        the given range (used by the lazy strategy, §4.3)."""
        return all(bool(self.boxes_for_label(video, l, frame_range))
                   for l in labels)

    def stats(self) -> dict:
        return {"entries": len(self._tree), "depth": self._tree.depth()}

    # -- persistence (engine manifest) --------------------------------------
    def dump(self, video: str) -> list:
        """JSON-serializable records for one video:
        ``[[frame, label, [y1,x1,y2,x2], tile_epoch], ...]`` in
        (label, frame) order."""
        out = []
        for label in sorted(self._labels.get(video, ())):
            for (v, l, f), dets in self._tree.scan((video, label, -1),
                                                   (video, label, 2 ** 60)):
                for d in dets:
                    out.append([f, l, list(d.bbox), d.tile_epoch])
        return out

    def load(self, video: str, records: Iterable) -> None:
        """Re-insert :meth:`dump` records for one video."""
        for frame, label, bbox, tile_epoch in records:
            self.add(video, int(frame), label, tuple(bbox), int(tile_epoch))
