"""Background repair/rebalance worker: the cluster's self-healing data
plane (modeled on the physical tuner's worker skeleton).

``ClusterRouter.repair()`` / ``rebalance(apply=True)`` enqueue
:class:`RepairJob`\\ s here; a daemon thread drains them OFF the serving
path, streaming one video per job node→node over dedicated connections
(never the router's shared serving channels, so bulk chunk frames cannot
head-of-line-block scans).  Each job:

1. opens (or resumes) the destination's staging namespace
   (``import_begin`` returns chunks already staged intact — a killed and
   restarted destination re-streams only what is missing);
2. streams every (SOT, tile) chunk with bounded retry + exponential
   backoff per chunk, rotating to another live source replica when one
   keeps failing;
3. detects a mid-copy foreground retile by epoch re-check — an exported
   chunk stamped with a different epoch than the manifest snapshot, or a
   final manifest re-fetch whose table moved — and re-streams the
   affected SOTs;
4. commits (``import_commit`` re-verifies every per-tile checksum and
   the epoch table against the router's expected generations — a
   pre-retile copy can never flip live), then asks the router to swap
   the placement assignment.  Until that flip, reads keep routing to the
   existing live replicas; a half-copied replica is never read.

Failures are bounded: a chunk that keeps failing past ``chunk_retries``
fails the JOB (status + error on the job record, surfaced through the
``repair_status`` RPC), never the worker thread.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, replace
from typing import Optional

from repro.core import wire

#: connection-level failures that trigger redial + per-chunk retry
_CONN_ERRORS = (wire.ConnectionClosed, wire.WireError, OSError)

#: worker thread exits after this much idle time (restarted on demand)
IDLE_EXIT_S = 5.0

#: a copy re-streams (manifest re-fetch after an epoch bump) at most this
#: many times — each pass otherwise makes progress, so only a foreground
#: retile loop racing the copy forever can hit it
MAX_PASSES = 50


@dataclass
class RepairJob:
    """One video copy: ``src`` node → ``dst`` node, with progress
    counters exposed through the ``repair_status`` RPC."""
    job_id: str
    video: str
    src: str
    dst: str
    kind: str = "replicate"     # "replicate" (heal K) | "move" (rebalance)
    #: nodes dropped from the assignment when the copy flips (the dead
    #: replicas this copy replaces)
    drop: tuple = ()
    #: "move" puts dst first (new primary); "replicate" appends it
    dst_primary: bool = False
    status: str = "queued"      # queued | running | done | failed
    chunks_total: int = 0
    chunks_done: int = 0
    bytes_copied: float = 0.0
    retries: int = 0            # chunk-level reconnect/retry count
    restreams: int = 0          # SOT re-streams forced by epoch bumps
    error: str = ""

    def describe(self) -> dict:
        return {"job_id": self.job_id, "video": self.video,
                "src": self.src, "dst": self.dst, "kind": self.kind,
                "drop": list(self.drop), "status": self.status,
                "chunks_total": self.chunks_total,
                "chunks_done": self.chunks_done,
                "bytes_copied": self.bytes_copied,
                "retries": self.retries, "restreams": self.restreams,
                "error": self.error}


@dataclass
class RepairStats:
    """Worker-lifetime accounting (jobs come and go; this accumulates)."""
    jobs_queued: int = 0
    jobs_done: int = 0
    jobs_failed: int = 0
    chunks_copied: int = 0
    bytes_copied: float = 0.0
    retries: int = 0
    restreams: int = 0
    copy_s: float = 0.0


def _doc_epochs(meta: dict) -> dict[int, int]:
    return {int(s["sot_id"]): int(s["epoch"]) for s in meta["sots"]}


def _n_tiles(sot_doc: dict) -> int:
    return len(sot_doc["heights"]) * len(sot_doc["widths"])


class _Chan:
    """One end of a copy: a dedicated node connection with bounded
    per-call retry + exponential backoff and redial-on-failure.  The
    source end additionally rotates to another live replica when a node
    keeps failing (``rotate`` returns the next candidate or None)."""

    def __init__(self, worker: "RepairWorker", job: RepairJob, name: str,
                 *, rotate=None):
        self.worker = worker
        self.job = job
        self.name = name
        self.rotate = rotate
        self._ch = None

    def call(self, fn):
        w = self.worker
        attempt = 0
        while True:
            try:
                if self._ch is None:
                    self._ch = w.router._dial_node(self.name)
                return fn(self._ch)
            except _CONN_ERRORS as e:
                self.drop()
                with w._cv:
                    self.job.retries += 1
                    w._stats.retries += 1
                attempt += 1
                if attempt > w.chunk_retries:
                    if self.rotate is not None:
                        nxt = self.rotate(self.name)
                        if nxt is not None:
                            self.name = nxt
                            attempt = 0
                            continue
                    raise
                time.sleep(w.backoff_s * (2 ** (attempt - 1)))

    def drop(self) -> None:
        ch, self._ch = self._ch, None
        if ch is not None:
            try:
                ch.close()
            except Exception:  # noqa: BLE001 - best-effort teardown
                pass

    def close(self) -> None:
        self.drop()


class RepairWorker:
    """FIFO job queue + on-demand daemon thread (the tuner's skeleton:
    condition variable, idle-exit, ``drain()`` barrier, synchronous
    ``stop()``)."""

    def __init__(self, router, *, chunk_retries: int = 4,
                 backoff_s: float = 0.05):
        self.router = router
        self.chunk_retries = int(chunk_retries)
        self.backoff_s = float(backoff_s)
        self._cv = threading.Condition()
        self._queue: deque[RepairJob] = deque()
        self._jobs: list[RepairJob] = []   # every job ever submitted
        self._busy = False
        self._stopping = False
        self._thread: Optional[threading.Thread] = None
        self._next_id = 1
        self._stats = RepairStats()
        self.last_error: Optional[BaseException] = None

    # ------------------------------------------------------------- intake
    def submit(self, video: str, src: str, dst: str, *,
               kind: str = "replicate", drop=(),
               dst_primary: bool = False) -> RepairJob:
        with self._cv:
            job = RepairJob(job_id=f"r{self._next_id}", video=video,
                            src=src, dst=dst, kind=kind, drop=tuple(drop),
                            dst_primary=dst_primary)
            self._next_id += 1
            self._queue.append(job)
            self._jobs.append(job)
            self._stats.jobs_queued += 1
            self._ensure_thread()
            self._cv.notify_all()
        return job

    def _ensure_thread(self) -> None:
        # caller holds _cv
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(target=self._run,
                                            name="tasm-repair",
                                            daemon=True)
            self._thread.start()

    # ---------------------------------------------------------- the worker
    def _run(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._stopping:
                    if not self._cv.wait(timeout=IDLE_EXIT_S):
                        if not self._queue:   # idle: exit, restart on demand
                            self._thread = None
                            return
                if self._stopping and not self._queue:
                    self._thread = None
                    return
                job = self._queue.popleft()
                self._busy = True
                job.status = "running"
            t0 = time.perf_counter()
            try:
                self._run_job(job)
                with self._cv:
                    job.status = "done"
                    self._stats.jobs_done += 1
            except BaseException as e:  # noqa: BLE001 - keep worker alive
                with self._cv:
                    job.status = "failed"
                    job.error = f"{type(e).__name__}: {e}"
                    self._stats.jobs_failed += 1
                    self.last_error = e
            finally:
                with self._cv:
                    self._stats.copy_s += time.perf_counter() - t0
                    self._busy = False
                    self._cv.notify_all()

    def _run_job(self, job: RepairJob) -> None:
        router = self.router
        video = job.video
        if not job.src:
            raise RuntimeError(
                f"no live replica of {video!r} to copy from")
        tried = {job.dst, *job.drop}
        src = _Chan(self, job, job.src,
                    rotate=lambda cur: router._repair_source(
                        video, exclude=tried | {cur}))
        dst = _Chan(self, job, job.dst)
        try:
            try:
                begun = dst.call(lambda ch: ch.import_begin(video))
            except ValueError:
                # destination already holds the video (an earlier copy
                # committed but the flip was lost): verify its generation
                # and just flip placement
                have = dst.call(lambda ch: ch.epochs(video))
                expected = router.expected_epochs(video)
                if all(have.get(s, -1) >= e for s, e in expected.items()):
                    router._apply_repair(job)
                    return
                raise RuntimeError(
                    f"node {job.dst} already holds {video!r} at older "
                    f"epochs; drop it there before repairing")
            staged = {(int(s), int(e), int(t)): sha
                      for s, e, t, sha in begun["staged"]}
            meta = src.call(lambda ch: ch.export_meta(video))
            for _ in range(MAX_PASSES):
                expected = router.expected_epochs(video)
                if any(_doc_epochs(meta).get(s, -1) < e
                       for s, e in expected.items()):
                    # the snapshot pre-dates a retile the router already
                    # acknowledged — refresh before streaming stale chunks
                    self._count_restream(job)
                    time.sleep(self.backoff_s)
                    meta = src.call(lambda ch: ch.export_meta(video))
                    continue
                if self._stream_pass(job, src, dst, meta, staged):
                    # epoch bump seen mid-stream: refresh and re-stream
                    meta = src.call(lambda ch: ch.export_meta(video))
                    continue
                # every chunk staged for this snapshot; one last manifest
                # re-fetch catches a retile that landed while we streamed
                meta2 = src.call(lambda ch: ch.export_meta(video))
                if _doc_epochs(meta2) != _doc_epochs(meta):
                    self._count_restream(job)
                    meta = meta2
                    continue
                try:
                    dst.call(lambda ch: ch.import_commit(
                        video, meta,
                        min_epochs=router.expected_epochs(video)))
                except ValueError as e:
                    msg = str(e)
                    if "stale" in msg:
                        # retile raced the commit window: stream the bump
                        self._count_restream(job)
                        meta = src.call(lambda ch: ch.export_meta(video))
                        continue
                    if "not staged" in msg:
                        # destination restarted and lost (in-memory)
                        # staging: resync what survived and re-stream
                        begun = dst.call(lambda ch: ch.import_begin(video))
                        staged = {(int(s), int(e), int(t)): sha
                                  for s, e, t, sha in begun["staged"]}
                        continue
                    raise
                router._apply_repair(job)
                return
            raise RuntimeError(
                f"copy of {video!r} to {job.dst} kept racing retiles; "
                f"gave up after {MAX_PASSES} passes")
        finally:
            src.close()
            dst.close()

    def _stream_pass(self, job: RepairJob, src: _Chan, dst: _Chan,
                     meta: dict, staged: dict) -> bool:
        """Stream every chunk the manifest snapshot expects that isn't
        staged yet.  Returns True if an epoch bump was detected (caller
        refreshes the manifest and re-streams)."""
        sots = meta["sots"]
        with self._cv:
            job.chunks_total = sum(_n_tiles(s) for s in sots)
            job.chunks_done = sum(
                1 for s in sots for t in range(_n_tiles(s))
                if (int(s["sot_id"]), int(s["epoch"]), t) in staged)
        for s in sots:
            sid, ep = int(s["sot_id"]), int(s["epoch"])
            for t in range(_n_tiles(s)):
                if (sid, ep, t) in staged:
                    continue
                for attempt in range(self.chunk_retries + 1):
                    chunk = src.call(
                        lambda ch, sid=sid, t=t: ch.export_chunk(job.video,
                                                                 sid, t))
                    if int(chunk["epoch"]) != ep:
                        # mid-copy foreground retile on this SOT
                        self._count_restream(job)
                        return True
                    try:
                        dst.call(lambda ch, sid=sid, ep=ep, t=t, c=chunk:
                                 ch.import_chunk(job.video, sid, ep, t,
                                                 c["enc"], c["checksum"]))
                    except ValueError as e:
                        # the destination recomputed the checksum and the
                        # chunk arrived torn: re-export and re-send
                        if "torn" not in str(e) or \
                                attempt >= self.chunk_retries:
                            raise
                        with self._cv:
                            job.retries += 1
                            self._stats.retries += 1
                        continue
                    break
                staged[(sid, ep, t)] = chunk["checksum"]
                nbytes = float(chunk["enc"]["size_bytes"])
                with self._cv:
                    job.chunks_done += 1
                    job.bytes_copied += nbytes
                    self._stats.chunks_copied += 1
                    self._stats.bytes_copied += nbytes
        return False

    def _count_restream(self, job: RepairJob) -> None:
        with self._cv:
            job.restreams += 1
            self._stats.restreams += 1

    # ------------------------------------------------------------ plumbing
    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every queued job finished (done or failed).  Raises
        ``TimeoutError`` if they don't settle in time; re-raises the most
        recent job failure once (cleared after raising)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while self._queue or self._busy:
                left = None if deadline is None \
                    else deadline - time.monotonic()
                if left is not None and left <= 0:
                    raise TimeoutError(
                        f"repair queue not drained after {timeout}s "
                        f"({len(self._queue)} queued, busy={self._busy})")
                self._cv.wait(timeout=left)
            err, self.last_error = self.last_error, None
        if err is not None:
            raise err

    def stop(self) -> None:
        """Stop accepting progress: finish the running job, leave the
        rest queued, join the thread."""
        with self._cv:
            self._stopping = True
            self._cv.notify_all()
            t = self._thread
        if t is not None:
            t.join(timeout=60)

    def jobs(self) -> list[dict]:
        with self._cv:
            return [j.describe() for j in self._jobs]

    def stats(self) -> RepairStats:
        with self._cv:
            return replace(self._stats)
