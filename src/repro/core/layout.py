"""Tile layouts (paper §2, §3.4).

A layout L = (n_r, n_c, heights, widths) partitions every frame of a SOT
along a *regular grid* (rows/columns span the whole frame — irregular layouts
are not in the HEVC spec).  The untiled video is the 1x1 layout ω.

Three constructors:
- ``uniform_layout``       (§3.4.1)
- ``fine_grained_layout``  (§3.4.2, Fig. 4a): boundaries bracket merged object
  intervals on each axis so no boundary crosses a box and non-intersecting
  boxes land in separate tiles.
- ``coarse_grained_layout``(§3.4.2, Fig. 4b): one large tile spanning the
  union of all boxes.

All boundaries are snapped to the codec block grid and respect a minimum tile
dimension (our scaled-down analogue of HEVC's minimum tile size).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Sequence

import numpy as np

BBox = tuple[int, int, int, int]  # (y1, x1, y2, x2) half-open

ALIGN = 8          # codec block size: boundaries must be multiples
MIN_TILE = 32      # minimum tile height/width (scaled-down HEVC constraint)


@dataclass(frozen=True)
class TileLayout:
    heights: tuple[int, ...]
    widths: tuple[int, ...]

    @property
    def n_rows(self) -> int:
        return len(self.heights)

    @property
    def n_cols(self) -> int:
        return len(self.widths)

    @property
    def n_tiles(self) -> int:
        return self.n_rows * self.n_cols

    @property
    def frame_height(self) -> int:
        return sum(self.heights)

    @property
    def frame_width(self) -> int:
        return sum(self.widths)

    def row_offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.heights)])

    def col_offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.widths)])

    def tile_rect(self, idx: int) -> BBox:
        r, c = divmod(idx, self.n_cols)
        ro, co = self.row_offsets(), self.col_offsets()
        return (int(ro[r]), int(co[c]), int(ro[r + 1]), int(co[c + 1]))

    def tile_rects(self) -> list[BBox]:
        return [self.tile_rect(i) for i in range(self.n_tiles)]

    def tile_pixels(self, idx: int) -> int:
        y1, x1, y2, x2 = self.tile_rect(idx)
        return (y2 - y1) * (x2 - x1)

    def total_pixels(self) -> int:
        return self.frame_height * self.frame_width

    def tiles_intersecting(self, box: BBox) -> list[int]:
        """Indices of tiles overlapping the (half-open) box."""
        y1, x1, y2, x2 = box
        ro, co = self.row_offsets(), self.col_offsets()
        r0 = int(np.searchsorted(ro, y1, side="right") - 1)
        r1 = int(np.searchsorted(ro, max(y2 - 1, y1), side="right") - 1)
        c0 = int(np.searchsorted(co, x1, side="right") - 1)
        c1 = int(np.searchsorted(co, max(x2 - 1, x1), side="right") - 1)
        r0, r1 = max(r0, 0), min(r1, self.n_rows - 1)
        c0, c1 = max(c0, 0), min(c1, self.n_cols - 1)
        return [r * self.n_cols + c
                for r in range(r0, r1 + 1) for c in range(c0, c1 + 1)]

    # -- 8x8 block granularity (ROI-restricted decode) ---------------------
    # Tile boundaries are ALIGN(=8)-aligned, so every tile decomposes into
    # whole codec blocks; block indices are tile-local, row-major over the
    # tile's (h/8, w/8) block grid — exactly the order the codec's
    # ``_to_blocks`` flattens them.
    def tile_blocks(self, idx: int, block: int = ALIGN) -> int:
        """Number of codec blocks in tile ``idx``."""
        y1, x1, y2, x2 = self.tile_rect(idx)
        return ((y2 - y1) // block) * ((x2 - x1) // block)

    def blocks_intersecting(self, idx: int, box: BBox,
                            block: int = ALIGN) -> list[int]:
        """Tile-local indices of the 8x8 blocks of tile ``idx`` that the
        (half-open, frame-coordinate) box overlaps."""
        ty1, tx1, ty2, tx2 = self.tile_rect(idx)
        y1, x1 = max(box[0], ty1), max(box[1], tx1)
        y2, x2 = min(box[2], ty2), min(box[3], tx2)
        if y1 >= y2 or x1 >= x2:
            return []
        nbx = (tx2 - tx1) // block
        r0, r1 = (y1 - ty1) // block, (y2 - 1 - ty1) // block
        c0, c1 = (x1 - tx1) // block, (x2 - 1 - tx1) // block
        return [r * nbx + c
                for r in range(r0, r1 + 1) for c in range(c0, c1 + 1)]

    def boundary_crosses(self, box: BBox) -> bool:
        """True if any internal tile boundary cuts through the box."""
        y1, x1, y2, x2 = box
        for b in self.row_offsets()[1:-1]:
            if y1 < b < y2:
                return True
        for b in self.col_offsets()[1:-1]:
            if x1 < b < x2:
                return True
        return False

    def describe(self) -> str:
        return f"{self.n_rows}x{self.n_cols}"


def block_coverage(layout: TileLayout, boxes_by_frame,
                   block: int = ALIGN) -> dict[int, tuple[int, ...] | None]:
    """Per-tile block-coverage mask of a set of requested boxes.

    Returns ``tile_idx -> mask`` for every tile any box intersects, where a
    mask is a sorted tuple of tile-local block indices — or ``None`` when
    the boxes cover every block of the tile (the full-tile decode fast
    path).  This is the unit the ROI-restricted decode contract threads
    from plan lowering through the scheduler and tile cache down to
    ``decode_tile(blocks=...)``.
    """
    # per-tile block bitmap + numpy slice marking: a box covers a
    # rectangular block range, so marking it is O(1) slices instead of a
    # per-block python loop (full-frame boxes would otherwise enumerate
    # every block of every tile on every frame of the plan)
    grids: dict[int, np.ndarray] = {}
    rects: dict[int, BBox] = {}
    for boxes in boxes_by_frame.values():
        for box in boxes:
            for t in layout.tiles_intersecting(box):
                rect = rects.get(t)
                if rect is None:
                    rect = rects[t] = layout.tile_rect(t)
                ty1, tx1, ty2, tx2 = rect
                y1, x1 = max(box[0], ty1), max(box[1], tx1)
                y2, x2 = min(box[2], ty2), min(box[3], tx2)
                if y1 >= y2 or x1 >= x2:
                    continue
                g = grids.get(t)
                if g is None:
                    g = grids[t] = np.zeros(((ty2 - ty1) // block,
                                             (tx2 - tx1) // block), bool)
                g[(y1 - ty1) // block:(y2 - 1 - ty1) // block + 1,
                  (x1 - tx1) // block:(x2 - 1 - tx1) // block + 1] = True
    return {t: None if g.all() else tuple(np.flatnonzero(g.ravel()).tolist())
            for t, g in grids.items()}


def single_tile_layout(height: int, width: int) -> TileLayout:
    """ω — the untiled video."""
    return TileLayout((height,), (width,))


def uniform_layout(height: int, width: int, rows: int, cols: int,
                   align: int = ALIGN) -> TileLayout:
    """Equal tiles (±alignment rounding; the last row/col absorbs remainder)."""
    rows = max(1, min(rows, height // align))
    cols = max(1, min(cols, width // align))

    def split(total: int, n: int) -> tuple[int, ...]:
        base = (total // n) // align * align
        base = max(base, align)
        sizes = [base] * n
        sizes[-1] = total - base * (n - 1)
        assert sizes[-1] >= align, (total, n, sizes)
        return tuple(sizes)

    return TileLayout(split(height, rows), split(width, cols))


# --------------------------------------------------------------------------
# Non-uniform layouts around bounding boxes
# --------------------------------------------------------------------------
def _merge_intervals(iv: list[tuple[int, int]]) -> list[tuple[int, int]]:
    if not iv:
        return []
    iv = sorted(iv)
    out = [list(iv[0])]
    for s, e in iv[1:]:
        if s <= out[-1][1]:
            out[-1][1] = max(out[-1][1], e)
        else:
            out.append([s, e])
    return [(s, e) for s, e in out]


def _axis_cuts(intervals: list[tuple[int, int]], total: int, *,
               align: int, min_tile: int) -> tuple[int, ...]:
    """Cut positions bracketing merged intervals, aligned, respecting minimum
    tile size, and never cutting through an interval."""
    merged = _merge_intervals(intervals)
    cuts = {0, total}
    for s, e in merged:
        cuts.add(max(0, (s // align) * align))        # snap start down
        cuts.add(min(total, -(-e // align) * align))  # snap end up
    # a snapped edge of one interval may land inside a neighbouring interval
    # when the gap between them is < align: drop any cut that crosses a box
    cuts = {c for c in cuts if not any(s < c < e for s, e in merged)} | {0, total}
    ordered = sorted(cuts)
    # enforce min tile size by dropping offending internal cuts (dropping a
    # cut merges tiles and can never cut a box)
    ok = [ordered[0]]
    for c in ordered[1:-1]:
        if c - ok[-1] >= min_tile and total - c >= min_tile:
            ok.append(c)
    ok.append(total)
    sizes = tuple(b - a for a, b in zip(ok[:-1], ok[1:]))
    assert sum(sizes) == total
    return sizes


def fine_grained_layout(height: int, width: int, boxes: Iterable[BBox], *,
                        align: int = ALIGN, min_tile: int = MIN_TILE) -> TileLayout:
    boxes = list(boxes)
    if not boxes:
        return single_tile_layout(height, width)
    hs = _axis_cuts([(b[0], b[2]) for b in boxes], height,
                    align=align, min_tile=min_tile)
    ws = _axis_cuts([(b[1], b[3]) for b in boxes], width,
                    align=align, min_tile=min_tile)
    return TileLayout(hs, ws)


def coarse_grained_layout(height: int, width: int, boxes: Iterable[BBox], *,
                          align: int = ALIGN, min_tile: int = MIN_TILE) -> TileLayout:
    boxes = list(boxes)
    if not boxes:
        return single_tile_layout(height, width)
    y1 = min(b[0] for b in boxes)
    y2 = max(b[2] for b in boxes)
    x1 = min(b[1] for b in boxes)
    x2 = max(b[3] for b in boxes)
    hs = _axis_cuts([(y1, y2)], height, align=align, min_tile=min_tile)
    ws = _axis_cuts([(x1, x2)], width, align=align, min_tile=min_tile)
    return TileLayout(hs, ws)


def partition(height: int, width: int, boxes: Iterable[BBox], *,
              granularity: str = "fine", align: int = ALIGN,
              min_tile: int = MIN_TILE) -> TileLayout:
    """PARTITION(s, O) from the paper: non-uniform layout around boxes."""
    fn = fine_grained_layout if granularity == "fine" else coarse_grained_layout
    return fn(height, width, boxes, align=align, min_tile=min_tile)
