"""VideoStore: the multi-video storage engine with a concurrent serving
layer (paper §3, Fig. 2, scaled up).

:class:`VideoStore` is a *catalog*: many named videos, each with its own
physical configuration (:class:`EncoderConfig`, tiling :class:`Policy`,
calibrated :class:`CostModel`, :class:`TileStore`, :class:`SemanticIndex`),
behind one declarative query surface::

    store = VideoStore(store_root="/data/tasm")
    store.add_video("cam0", encoder=EncoderConfig(gop=16), policy=RegretPolicy())
    store.ingest("cam0", frames)
    store.add_detections("cam0", dets_by_frame)
    res  = store.scan("cam0").labels("car").frames(0, 96).execute()
    plan = store.scan(["cam0", "cam1"]).labels("car").explain()  # no decode

Plan/execute split: the builder produces a logical :class:`ScanPlan`;
:meth:`VideoStore.lower` turns it into a :class:`PhysicalPlan` (the exact
SOTs and tile indices to decode, costed through the §4.1 what-if
interface).  Execution then goes through the **serving layer**:

- **Tile cache** (``core/tile_cache.py``) — a byte-budgeted, workload-
  predictive cache of decoded tile arrays keyed ``(video, sot_id, epoch,
  tile_idx)``.  Every tile fetch consults it before decoding, so
  overlapping scans stop re-decoding shared tiles; the epoch in the key
  means a ``retile`` invalidates naturally and the cache can never serve
  pre-retile pixels.  Configure it with ``VideoStore(cache=CacheConfig(
  budget_bytes=..., eviction=..., prefetch=..., block_packed=...))``
  (``budget_bytes=0`` disables); under ``prefetch`` the tuner's workload
  tap detects sliding-window scans and decodes the next SOTs ahead of the
  client (:meth:`drain_prefetch` is the deterministic barrier).
- **Scan scheduler** (``core/scheduler.py``) — :meth:`execute` is a thin
  client of a :class:`ScanScheduler` that accepts physical plans from
  concurrent callers, merges SOTScans targeting the same ``(video, sot_id,
  epoch)`` into one decode with the union of tile indices on a shared
  worker pool, and fans per-query results back out.  Batch submission:
  :meth:`execute_many`; concurrent submission: ``with store.serve() as s:
  s.submit(query)``.  Region assembly and policy hooks stay deterministic
  and bit-identical per query (plans finish strictly in submission order;
  a mid-batch retile triggers a re-fetch at the new epoch).
- **Physical tuner** (``core/tuner.py``) — policy-driven re-tiling runs in
  a background subsystem instead of inside the scan that triggered it.
  Under ``TuningConfig(mode="background")`` (the default) the scheduler's
  policy hooks
  only *emit observations* into a bounded workload log; a tuner thread
  replays them through the policies, coalesces proposals per SOT (newest
  wins), scores them through the §4.1 what-if interface, and applies
  winners via the durable, lock-taking, epoch-bumping retile path —
  queries are never charged re-encode time (``ScanStats.retile_s`` stays 0;
  see :meth:`tuner_stats`).  ``mode="inline"`` preserves the synchronous
  semantics bit-for-bit; ``mode="off"`` disables query-driven tuning.
  :meth:`drain_tuner` is the deterministic barrier for tests/benchmarks.

Knob surface: the serving knobs group into three config objects —
``VideoStore(cache=CacheConfig(...), tuning=TuningConfig(...),
decode=DecodeConfig(...))`` (see ``core/config.py`` for every field and
the explicit > deprecated-alias > environment > default precedence).  The
pre-config kwargs (``tile_cache_bytes``, ``tuning=<str>``,
``tuner_admission``, ``roi_decode``, ``decode_backend``) keep working for
one release as 1:1 aliases that emit ``DeprecationWarning``.

Persistence: with ``store_root`` set, durable state is sharded per video —
a small catalog file (``<root>/catalog.json``: version + video names) plus
one manifest per video (``<root>/<video>/manifest.json`` holding its
encoder, policy spec *and runtime state*, cost model, SOT records and
semantic-index entries).  A durable mutation to one video re-serializes
only that video's shard, not the whole catalog.  The v1 monolithic
``<root>/manifest.json`` is migrated on open (shards are written, the old
file is kept as ``*.v1.bak``), and v2 shards (no policy runtime state) are
adopted and rewritten as v3; every format reopens and serves scans without
re-ingesting.  Since v3, policy runtime state (accumulated regret, seen
labels) persists per shard, so a reopened store resumes tuning where it
left off instead of cold.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import shutil
import time
import warnings
from dataclasses import dataclass, field
from typing import Iterator, Optional

import numpy as np

from repro.codec.encode import EncoderConfig
from repro.core.config import CacheConfig, DecodeConfig, TuningConfig
from repro.core.cost import CostModel, pixels_and_tiles, roi_pixels_and_tiles
from repro.core.layout import TileLayout
from repro.core.policies import (NoTilingPolicy, Policy, policy_from_spec,
                                 policy_spec)
from repro.core.query import (PhysicalPlan, ScanPlan, ScanQuery, ScanResult,
                              ScanStats, SOTScan)
from repro.core.scheduler import ScanScheduler, ServingSession
from repro.core.semantic_index import SemanticIndex
from repro.core.storage import SOTRecord, TileStore, tile_checksum
from repro.core.tile_cache import CacheStats, TileCache
from repro.core.tuner import PhysicalTuner, TunerStats

#: valid what-if cost granularities: "tile" = standard full-tile decoder
#: (the basis for layout decisions), "block" = actual ROI-restricted decode
GRANULARITIES = ("tile", "block")

CATALOG_NAME = "catalog.json"      # v2+: version + video names, O(#videos)
MANIFEST_NAME = "manifest.json"    # v2+: per-video shard; v1: the monolith
IMPORT_DIR_NAME = ".import"        # staging namespace for replica copies
MANIFEST_VERSION = 3               # v3: + per-video policy runtime state
COMPAT_SHARD_VERSIONS = (2, MANIFEST_VERSION)   # v2 adopted, rewritten as v3
LEGACY_MANIFEST_VERSION = 1


@dataclass
class IngestStats:
    """Unified ingest accounting (one contract for every ingest path).

    - ``encode_s``  — seconds encoding the incoming frames (always paid).
    - ``pretile_s`` — *extra* seconds re-tiling beyond the plain encode
      (policy-driven pre-tiling).  0.0 when layouts arrive with the video
      (edge tiling: the camera already paid for them) or nothing pre-tiles.
    """
    encode_s: float = 0.0
    pretile_s: float = 0.0

    @property
    def total_s(self) -> float:
        return self.encode_s + self.pretile_s


@dataclass
class VideoEntry:
    """One catalog entry: a video plus its physical configuration."""
    name: str
    encoder: EncoderConfig
    policy: Policy
    cost_model: CostModel
    store: TileStore
    index: SemanticIndex
    frame_hw: Optional[tuple[int, int]] = None
    history: list = field(default_factory=list)


def _deprecated_kwarg(name: str, replacement: str) -> None:
    warnings.warn(
        f"VideoStore({name}=...) is deprecated and will be removed next "
        f"release; use {replacement}", DeprecationWarning, stacklevel=4)


def _resolve_configs(cache, tuning, decode, *, tile_cache_bytes,
                     tuner_admission, roi_decode, decode_backend,
                     max_decode_workers):
    """Fold the deprecated per-knob kwargs into the three config objects
    and resolve them (env overrides + defaults; see ``core/config.py``).
    Each alias maps 1:1 onto one config field; passing an alias together
    with the config object it folds into is an error, never a silent
    pick.  ``max_decode_workers`` predates the sprawl and stays accepted
    without a warning (it equals ``DecodeConfig(max_workers=...)``)."""
    if tile_cache_bytes is not None:
        if cache is not None:
            raise ValueError("pass cache=CacheConfig(...) or "
                             "tile_cache_bytes=..., not both")
        _deprecated_kwarg("tile_cache_bytes",
                          "cache=CacheConfig(budget_bytes=...)")
        cache = CacheConfig(budget_bytes=tile_cache_bytes)
    cache = (cache if cache is not None else CacheConfig()).resolve()

    if isinstance(tuning, str):
        _deprecated_kwarg("tuning=<mode string>",
                          "tuning=TuningConfig(mode=...)")
        tuning = TuningConfig(mode=tuning,
                              admission=tuner_admission or "policy")
        if tuner_admission is not None:
            _deprecated_kwarg("tuner_admission",
                              "tuning=TuningConfig(admission=...)")
    elif tuner_admission is not None:
        if tuning is not None:
            raise ValueError("pass tuning=TuningConfig(...) or "
                             "tuner_admission=..., not both")
        _deprecated_kwarg("tuner_admission",
                          "tuning=TuningConfig(admission=...)")
        tuning = TuningConfig(admission=tuner_admission)
    tuning = (tuning if tuning is not None else TuningConfig()).resolve()

    legacy = {}
    if roi_decode is not None:
        _deprecated_kwarg("roi_decode", "decode=DecodeConfig(roi=...)")
        legacy["roi"] = roi_decode
    if decode_backend is not None:
        _deprecated_kwarg("decode_backend",
                          "decode=DecodeConfig(backend=...)")
        legacy["backend"] = decode_backend
    if max_decode_workers is not None:
        legacy["max_workers"] = max_decode_workers
    if legacy:
        if decode is not None:
            raise ValueError(
                f"pass decode=DecodeConfig(...) or the per-knob kwargs "
                f"({', '.join(sorted(legacy))}), not both")
        decode = DecodeConfig(**legacy)
    decode = (decode if decode is not None else DecodeConfig()).resolve()
    return cache, tuning, decode


class VideoStore:
    """Catalog of videos + declarative scan queries served through a
    cached, merging scheduler."""

    def __init__(self, store_root: Optional[str] = None, *,
                 default_encoder: Optional[EncoderConfig] = None,
                 default_policy: Optional[Policy] = None,
                 default_cost_model: Optional[CostModel] = None,
                 cache: Optional[CacheConfig] = None,
                 tuning: "Optional[TuningConfig | str]" = None,
                 decode: Optional[DecodeConfig] = None,
                 autoload: bool = True,
                 # deprecated keyword aliases (one release; each maps 1:1
                 # onto a config field — see _resolve_configs)
                 max_decode_workers: Optional[int] = None,
                 tile_cache_bytes: Optional[int] = None,
                 tuner_admission: Optional[str] = None,
                 roi_decode: Optional[bool] = None,
                 decode_backend: Optional[str] = None):
        cache_cfg, tuning_cfg, decode_cfg = _resolve_configs(
            cache, tuning, decode,
            tile_cache_bytes=tile_cache_bytes,
            tuner_admission=tuner_admission, roi_decode=roi_decode,
            decode_backend=decode_backend,
            max_decode_workers=max_decode_workers)
        #: resolved config objects (every knob concrete; see core/config.py
        #: for the explicit > alias > env > default precedence)
        self.cache_config = cache_cfg
        self.tuning_config = tuning_cfg
        self.decode_config = decode_cfg
        self.root = pathlib.Path(store_root) if store_root else None
        self.default_encoder = default_encoder or EncoderConfig()
        self.default_policy = default_policy
        self.default_cost_model = default_cost_model
        self.max_decode_workers = decode_cfg.max_workers
        self._videos: dict[str, VideoEntry] = {}
        # replica-import staging for in-memory stores (on-disk stores stage
        # under <root>/.import/<video>/ so a killed destination can resume)
        self._import_mem: dict[str, dict[tuple, tuple]] = {}
        self.history: list[ScanStats] = []
        self._dirty_videos: set[str] = set()
        # videos whose policy runtime state mutated without dirtying the
        # shard (inline observes with no proposal); flushed by close()
        self._stale_policy_state: set[str] = set()
        self._catalog_dirty = False
        self.tile_cache = TileCache(config=cache_cfg)
        self.scheduler = ScanScheduler(self, cache=self.tile_cache)
        # ROI-restricted decode: lowering threads per-tile 8x8-block masks
        # into the plan, so subframe scans decode only the blocks their
        # boxes intersect.  False restores PR-3 full-tile decode (results
        # are bit-identical either way; the flag may be flipped at runtime
        # and only affects plans lowered afterwards)
        self.roi_decode = decode_cfg.roi
        # decode backend="numpy"|"batched": how TileStore.decode_tiles runs —
        # the per-tile numpy oracle loop, or fused accelerator dispatches
        # over the whole merged batch (bit-identical; see codec/batch.py).
        self.decode_backend = decode_cfg.backend
        # tuning mode="background"|"inline"|"off": where policy-driven
        # retiling runs (async tuner thread / inside the scan / nowhere);
        # admission="policy"|"gated": whether the background tuner
        # additionally gates + ranks proposals by their what-if net benefit
        self.tuner = PhysicalTuner(self, mode=tuning_cfg.mode,
                                   admission=tuning_cfg.admission,
                                   max_log=tuning_cfg.max_log)
        if self.root is not None and autoload:
            if self.catalog_path.exists():
                self._load_catalog()
            elif self.legacy_manifest_path.exists():
                self._migrate_v1()

    # ------------------------------------------------------------- catalog
    @property
    def catalog_path(self) -> pathlib.Path:
        assert self.root is not None
        return self.root / CATALOG_NAME

    @property
    def legacy_manifest_path(self) -> pathlib.Path:
        """The v1 monolithic manifest (pre-sharding)."""
        assert self.root is not None
        return self.root / MANIFEST_NAME

    def video_manifest_path(self, name: str) -> pathlib.Path:
        assert self.root is not None
        return self.root / name / MANIFEST_NAME

    def videos(self) -> list[str]:
        return sorted(self._videos)

    def video(self, name: str) -> VideoEntry:
        try:
            return self._videos[name]
        except KeyError:
            raise KeyError(f"unknown video {name!r}; catalog has "
                           f"{self.videos()}") from None

    def __contains__(self, name: str) -> bool:
        return name in self._videos

    def __len__(self) -> int:
        return len(self._videos)

    def __iter__(self) -> Iterator[str]:
        return iter(self.videos())

    def add_video(self, name: str, *,
                  encoder: Optional[EncoderConfig] = None,
                  policy: Optional[Policy] = None,
                  cost_model: Optional[CostModel] = None,
                  sot_len: Optional[int] = None) -> VideoEntry:
        if name in self._videos:
            raise ValueError(f"video {name!r} already in catalog")
        enc = encoder or self.default_encoder
        if policy is None:
            # clone the default so stateful policies (regret accumulators)
            # never share state across videos
            policy = (policy_from_spec(self.default_policy.spec())
                      if self.default_policy else NoTilingPolicy())
        entry = VideoEntry(
            name=name, encoder=enc, policy=policy,
            cost_model=cost_model or self.default_cost_model or CostModel(),
            store=TileStore(name, enc,
                            root=str(self.root) if self.root else None,
                            sot_len=sot_len,
                            decode_backend=self.decode_backend),
            index=SemanticIndex())
        self._videos[name] = entry
        self._catalog_dirty = True
        self._dirty_videos.add(name)
        return entry

    def drop_video(self, name: str) -> None:
        with self.scheduler.lock:
            entry = self.video(name)
            del self._videos[name]
            self._dirty_videos.discard(name)
            self._stale_policy_state.discard(name)
            self.tile_cache.invalidate(video=name)
            if self.root is not None:
                # catalog first: a crash after it lands leaves only an
                # orphaned shard directory (harmless), never a catalog
                # pointing at a missing shard (unopenable store)
                self._catalog_dirty = True
                self.save()
                d = self.root / entry.name
                if d.exists():
                    shutil.rmtree(d)   # tiles + the video's manifest shard

    # ---------------------------------------------------------- dirtiness
    def _mark_dirty(self, *names: str) -> None:
        self._dirty_videos.update(names)

    @property
    def dirty(self) -> bool:
        return bool(self._dirty_videos or self._catalog_dirty)

    # -------------------------------------------------------------- ingest
    def ingest(self, name: str, frames: np.ndarray, *, detections=None,
               initial_layouts: Optional[dict[int, TileLayout]] = None,
               **video_kw) -> IngestStats:
        """Encode ``frames`` into video ``name`` (auto-registered if absent).

        ``detections``: per-frame ``[(label, bbox)]`` preloading the semantic
        index before the policy's ``on_ingest`` runs (eager/edge strategies).
        ``initial_layouts``: sot_id -> layout applied at encode time (the
        edge-tiling path); when given, the policy's ``on_ingest`` is skipped.
        Returns :class:`IngestStats` — see its docstring for the contract.
        """
        with self.scheduler.lock:   # no scan observes a half-ingested video
            entry = self._videos.get(name)
            if entry is None:
                entry = self.add_video(name, **video_kw)
            elif video_kw:
                raise ValueError(
                    f"video {name!r} already configured; per-video kwargs "
                    f"{sorted(video_kw)} only apply on first ingest")
            if entry.store.sots:
                # appending footage needs sot_id offsetting the store does
                # not do; a second ingest would collide sot_ids 0..n-1 with
                # the existing records and duplicate every scan's regions
                raise ValueError(
                    f"video {name!r} already has ingested frames; "
                    "re-ingest/append is not supported")
            entry.frame_hw = frames.shape[1:]
            if detections is not None:
                for f, dets in enumerate(detections):
                    for label, bbox in dets:
                        entry.index.add(name, f, label, bbox)
            stats = IngestStats()
            if initial_layouts:
                stats.encode_s = entry.store.ingest(
                    frames, layouts=dict(initial_layouts))
            else:
                # encode untiled first so the store has SOT records for the
                # policy
                stats.encode_s = entry.store.ingest(frames, layouts=None)
                pre = entry.policy.on_ingest(entry.index, entry.store, name,
                                             entry.frame_hw)
                for sot_id, layout in (pre or {}).items():
                    stats.pretile_s += entry.store.retile(sot_id, layout)
            self._mark_dirty(name)
            self.save()
        return stats

    # ------------------------------------------------------------ metadata
    def add_metadata(self, video: str, frame: int, label: str,
                     x1: int, y1: int, x2: int, y2: int) -> None:
        """The paper's ADDMETADATA(v, f, label, x1, y1, x2, y2); durable —
        the mutation is persisted before returning."""
        with self.scheduler.lock:
            self.video(video).index.add_metadata(video, frame, label,
                                                 x1, y1, x2, y2)
            self._mark_dirty(video)
            self.save()

    def add_detections(self, video: str, detections_by_frame: dict) -> None:
        with self.scheduler.lock:
            entry = self.video(video)
            for f, dets in detections_by_frame.items():
                for label, bbox in dets:
                    entry.index.add(video, f, label, bbox)
            self._mark_dirty(video)
            self.save()

    # ---------------------------------------------------------------- scan
    def scan(self, videos, labels=None,
             frames: Optional[tuple[int, int]] = None) -> ScanQuery:
        """Start a scan-query builder over one video or a list of videos.

        ``labels``/``frames`` are optional shortcuts for the corresponding
        builder calls: ``store.scan("cam0", "car", (0, 96))``.
        """
        q = ScanQuery(self, videos)
        if labels is not None:
            q = q.labels(labels)
        if frames is not None:
            q = q.frames(*frames)
        return q

    # ---------------------------------------------------------- plan/lower
    def lower(self, plan: ScanPlan) -> PhysicalPlan:
        """Lower a logical plan to the exact SOTs + tile indices to decode,
        costing each SOT through the what-if interface.  Pure: touches only
        the semantic index, never tile data.  Takes the scheduler lock so a
        concurrent ingest/add_detections can't mutate the B+-trees under a
        running index scan."""
        with self.scheduler.lock:
            return self._lower(plan)

    def _sot_cost_walk(self, entry: VideoEntry, boxes_by_frame: dict,
                       layout_by_sot: Optional[dict[int, TileLayout]] = None,
                       granularity: str = "tile"):
        """The shared SOT-walking cost loop of the §4.1 what-if interface:
        for each SOT overlapping the boxed frames, restrict the boxes to
        the SOT and cost them under its layout (or a hypothetical override
        from ``layout_by_sot``).  Yields ``(rec, epoch, layout, local,
        est_pixels, est_tiles, est_cost_s, blocks_by_tile)``.  Callers:
        :meth:`_lower` (physical planning), :meth:`what_if` (hypothetical
        layouts), and the :class:`~repro.core.tuner.PhysicalTuner`
        (proposal scoring).  Caller must hold the scheduler lock.

        ``granularity``: ``"tile"`` charges a standard full-tile decoder
        (``pixels_and_tiles``; ``blocks_by_tile`` is None) — the basis for
        layout decisions, since block-granular pixels are layout-invariant;
        ``"block"`` charges the engine's actual ROI-restricted decode and
        yields the per-tile block-coverage masks the plan carries."""
        if granularity not in GRANULARITIES:
            raise ValueError(f"unknown cost granularity {granularity!r}; "
                             f"want one of {GRANULARITIES}")
        if not boxes_by_frame:
            return
        f_lo, f_hi = min(boxes_by_frame), max(boxes_by_frame) + 1
        for rec in entry.store.sots_in_range(f_lo, f_hi):
            span = (rec.frame_start, rec.frame_end)
            local = {f: b for f, b in boxes_by_frame.items()
                     if span[0] <= f < span[1]}
            if not local:
                continue
            # epoch BEFORE layout: engine-level retiles hold the scheduler
            # lock we're under, but store-level retile() calls bypass it —
            # if one interleaves (it installs the layout, then bumps the
            # epoch), reading the epoch first leaves the caller's SOTScan
            # detectably stale, and execution recomputes its tiles against
            # the layout of record
            epoch = rec.epoch
            layout = rec.layout
            if layout_by_sot is not None:
                layout = layout_by_sot.get(rec.sot_id, layout)
            bbt = None
            if granularity == "block":
                # io_pixels feeds the third cost-model term: tile opens
                # decompress the full coefficient stream even when the ROI
                # gathers few blocks (0-cost when io_per_pixel is
                # uncalibrated, so legacy stores estimate as before)
                p, t, iop, bbt = roi_pixels_and_tiles(
                    layout, local, gop=entry.encoder.gop, sot_frames=span)
                cost = entry.cost_model.cost(p, t, iop)
            else:
                p, t = pixels_and_tiles(layout, local, gop=entry.encoder.gop,
                                        sot_frames=span)
                cost = entry.cost_model.cost(p, t)
            yield (rec, epoch, layout, local, p, t, cost, bbt)

    def _lower(self, plan: ScanPlan) -> PhysicalPlan:
        pplan = PhysicalPlan(logical=plan)
        remaining = plan.limit
        for name in plan.videos:
            entry = self.video(name)
            if plan.cnf == ():   # all-labels sentinel from .labels()
                all_labels = tuple(sorted(entry.index.labels(name)))
                if not all_labels:
                    continue
                cnf = (all_labels,)
            else:
                cnf = plan.cnf
            flat_labels = tuple(sorted({l for clause in cnf for l in clause}))
            t0 = time.perf_counter()
            boxes_by_frame = entry.index.query(name, cnf, plan.frame_range)
            pplan.lookup_s += time.perf_counter() - t0
            if remaining is not None:
                boxes_by_frame = _apply_limit(boxes_by_frame, remaining)
                remaining -= sum(len(b) for b in boxes_by_frame.values())
            if not boxes_by_frame:
                continue
            qrange = plan.frame_range or (min(boxes_by_frame),
                                          max(boxes_by_frame) + 1)
            gran = "block" if self.roi_decode else "tile"
            for rec, epoch, layout, local, p, t, cost, bbt in \
                    self._sot_cost_walk(entry, boxes_by_frame,
                                        granularity=gran):
                if bbt is not None:
                    needed = set(bbt)
                else:
                    needed = set()
                    for f, boxes in local.items():
                        for box in boxes:
                            needed.update(layout.tiles_intersecting(box))
                pplan.sot_scans.append(SOTScan(
                    video=name, sot_id=rec.sot_id, epoch=epoch,
                    tile_idxs=tuple(sorted(needed)),
                    n_frames=max(local) - rec.frame_start + 1,
                    boxes_by_frame=local, query_range=qrange,
                    labels=flat_labels, est_pixels=p, est_tiles=t,
                    est_cost_s=cost, blocks_by_tile=bbt or {}))
        return pplan

    # -------------------------------------------------------------- execute
    def execute(self, pplan: PhysicalPlan) -> ScanResult:
        """Run a physical plan through the serving layer (cached, merged
        decodes on the shared worker pool; deterministic region assembly;
        per-SOT policy hooks)."""
        return self.scheduler.execute(pplan)

    def execute_many(self, plans) -> list[ScanResult]:
        """Execute several scans as one batch: SOTScans targeting the same
        ``(video, sot_id, epoch)`` are merged into one decode (union of tile
        indices), so each shared tile is decoded at most once.  Accepts
        :class:`ScanQuery`, :class:`ScanPlan` or :class:`PhysicalPlan`
        items; results come back in submission order, each bit-identical to
        a serial :meth:`execute` of the same plan."""
        return self.scheduler.execute_many(plans)

    def serve(self, **kw) -> ServingSession:
        """Open a concurrent serving session (micro-batching dispatcher)::

            with store.serve() as session:
                futs = [session.submit(q) for q in queries]
                results = [f.result() for f in futs]
        """
        return self.scheduler.session(**kw)

    def drain_tuner(self, timeout: Optional[float] = None) -> TunerStats:
        """Deterministic tuning barrier: block until every observation
        emitted before this call has been replayed through the policies,
        every surviving proposal applied, and the resulting state
        persisted.  No-op under ``tuning="inline"``/``"off"``.  Returns a
        :class:`TunerStats` snapshot."""
        self.tuner.drain(timeout)
        return self.tuner.stats()

    def tuner_stats(self) -> TunerStats:
        """Snapshot of the physical tuner's cumulative accounting
        (observations, coalesced/applied/skipped retiles, tuning and
        re-encode seconds)."""
        return self.tuner.stats()

    def drain_prefetch(self, timeout: Optional[float] = None) -> CacheStats:
        """Deterministic prefetch barrier: block until every predictive
        decode enqueued before this call has completed (no-op unless
        ``CacheConfig.prefetch``).  Returns a :class:`CacheStats`
        snapshot, so callers can assert on ``prefetch_issued`` etc."""
        self.scheduler.drain_prefetch(timeout)
        return self.tile_cache.stats()

    def config(self) -> dict:
        """The resolved runtime configuration as wire-ready documents
        (``{"cache": ..., "tuning": ..., "decode": ...}``) — the same
        surface ``RemoteVideoStore.config()`` and the router expose.
        ``decode.roi`` reflects the live ``roi_decode`` flag (it may be
        flipped at runtime)."""
        return {"cache": self.cache_config.to_doc(),
                "tuning": self.tuning_config.to_doc(),
                "decode": {**self.decode_config.to_doc(),
                           "roi": bool(self.roi_decode)}}

    def close(self) -> None:
        """Stop the tuner thread (flushing its workload log), flush dirty
        durable state, and release the decode worker pool.  The store
        remains usable; a later scan re-creates both on demand."""
        # outside the scheduler lock: the tuner's flush needs to take it
        self.tuner.stop()
        with self.scheduler.lock:
            # inline observes mutate stateful-policy runtime state without
            # dirtying the shard (no full rewrite per query); flush the
            # noted remainder so a reopened store resumes exactly
            self._mark_dirty(*(self._stale_policy_state & set(self._videos)))
            if self.dirty:
                self.save()
        self.scheduler.shutdown()

    def __enter__(self) -> "VideoStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -------------------------------------------------------------- retile
    def retile(self, video: str, sot_id: int, new_layout: TileLayout
               ) -> float:
        """Durably re-tile one SOT through the serving layer: takes the
        scheduler's lock (no scan observes a half-retiled SOT), bumps the
        epoch, purges stale cache entries, persists the video's shard.
        Returns re-encode seconds (0.0 if the layout is unchanged)."""
        with self.scheduler.lock:
            dt = self._retile(video, sot_id, new_layout)
            if self.dirty:
                self.save()
        return dt

    def _retile(self, video: str, sot_id: int, new_layout: TileLayout
                ) -> float:
        """Retile without persisting (scheduler policy-hook path; the batch
        saves once at the end).  Caller must hold ``scheduler.lock``."""
        entry = self.video(video)
        dt = entry.store.retile(sot_id, new_layout)
        if dt:
            rec = entry.store.sots[sot_id]
            self.tile_cache.invalidate(video, sot_id,
                                       before_epoch=rec.epoch)
            self._mark_dirty(video)
        return dt

    # -------------------------------------------------------------- what-if
    def what_if(self, video: str, labels,
                layout_by_sot: dict[int, TileLayout],
                t_range: Optional[tuple[int, int]] = None,
                granularity: str = "tile") -> float:
        """§4.1 what-if interface: estimated cost of a query under alternate
        layouts, without touching tile data.  Locked like :meth:`lower`, so
        concurrent durable mutations can't shift the B+-trees mid-scan.

        ``granularity="tile"`` (default) models a standard full-tile
        decoder — the cost that *layout decisions* compare, used by the
        policies' alpha/regret gates and the tuner's proposal scoring.
        ``granularity="block"`` models the engine's ROI-restricted decode
        (what a scan actually pays; matches ``explain().est_cost_s`` when
        ``roi_decode`` is on).  Block-granular pixel cost is
        layout-invariant — tile boundaries are 8-aligned — which is exactly
        why it cannot replace the tile-granular cost for choosing layouts."""
        with self.scheduler.lock:
            entry = self.video(video)
            boxes_by_frame = entry.index.query(video, labels, t_range)
            return sum(cost for *_, cost, _bbt in self._sot_cost_walk(
                entry, boxes_by_frame, layout_by_sot=layout_by_sot,
                granularity=granularity))

    def epochs(self, video: str) -> dict[int, int]:
        """``{sot_id: layout epoch}`` snapshot for one video.  A retile
        bumps the SOT's epoch, so two stores holding the same video serve
        the same physical layout generation iff these tables match — the
        check the cluster router runs before reading from a replica."""
        with self.scheduler.lock:
            return {r.sot_id: r.epoch
                    for r in self.video(video).store.sots}

    # ------------------------------------------------------ repair copy path
    # Node->node replica streaming (the cluster's repair/rebalance data
    # plane).  The source side is read-only (`export_entry` snapshots the
    # manifest doc, `export_tile` one encoded tile stream at its current
    # epoch); the destination stages chunks under a temp namespace keyed by
    # video, verifies each chunk's sha256 on arrival AND again at commit,
    # and only `commit_import` makes the video visible — the catalog write
    # is the commit point, so a SIGKILL anywhere mid-copy leaves zero torn
    # state (stray staging files are re-verified or discarded on resume).

    def export_entry(self, name: str) -> dict:
        """The video's manifest-shard doc (encoder, policy + runtime state,
        cost model, semantic index, SOT/epoch table) — the metadata leg of
        a replica copy, fetched last so the epoch table it carries reflects
        every chunk already streamed."""
        with self.scheduler.lock:
            return {"version": MANIFEST_VERSION, "name": name,
                    **self._entry_doc(self.video(name))}

    def export_tile(self, name: str, sot_id: int, tile_idx: int) -> dict:
        """One encoded tile stream at its current epoch, with a content
        checksum.  Reads run off-lock so exports never stall serving; a
        foreground retile racing the read is detected by an epoch re-check
        and the read retries against the new generation."""
        for _ in range(8):
            with self.scheduler.lock:
                entry = self.video(name)
                if not 0 <= sot_id < len(entry.store.sots):
                    raise ValueError(f"video {name!r} has no SOT {sot_id}")
                rec = entry.store.sots[sot_id]
                if not 0 <= tile_idx < rec.layout.n_tiles:
                    raise ValueError(
                        f"SOT {sot_id} of {name!r} has no tile {tile_idx} "
                        f"(layout {rec.layout.describe()})")
                epoch = rec.epoch
            try:
                enc = entry.store._read_tile(rec, tile_idx)
            except (KeyError, FileNotFoundError):
                continue    # retile raced the read: retry at the new epoch
            with self.scheduler.lock:
                if rec.epoch != epoch:
                    continue
            return {"sot_id": sot_id, "epoch": epoch, "tile_idx": tile_idx,
                    "enc": {"kq": list(enc["kq"]), "pq": list(enc["pq"]),
                            "h": enc["h"], "w": enc["w"], "gop": enc["gop"],
                            "qp": enc["qp"], "n_frames": enc["n_frames"],
                            "size_bytes": float(enc["size_bytes"])},
                    "checksum": tile_checksum(enc)}
        raise RuntimeError(f"export of {name!r} SOT {sot_id} kept racing "
                           f"retiles; giving up after 8 attempts")

    def _import_dir(self, name: str) -> pathlib.Path:
        assert self.root is not None
        return self.root / IMPORT_DIR_NAME / name

    def begin_import(self, name: str) -> dict:
        """Open — or resume — the staging namespace for an incoming replica
        copy.  Returns every chunk already staged and intact
        (``{"staged": [[sot_id, epoch, tile_idx, checksum], ...]}``) so a
        retried repair re-streams only what is missing; torn leftovers from
        a killed destination are verified against their stored checksum and
        discarded."""
        with self.scheduler.lock:
            if name in self._videos:
                raise ValueError(
                    f"video {name!r} already exists on this node")
            staged = []
            if self.root is None:
                for (s, e, t), (_enc, sha) in sorted(
                        self._import_mem.get(name, {}).items()):
                    staged.append([s, e, t, sha])
                return {"staged": staged}
            d = self._import_dir(name)
            d.mkdir(parents=True, exist_ok=True)
            for f in sorted(d.iterdir()):
                if f.name.startswith("."):  # tmp torn by a mid-write kill
                    f.unlink(missing_ok=True)
                    continue
                chunk = _load_staged_tile(f)
                if chunk is None:           # unreadable or checksum-torn
                    f.unlink(missing_ok=True)
                    continue
                s, e, t, _enc, sha = chunk
                staged.append([s, e, t, sha])
            return {"staged": staged}

    def stage_import_chunk(self, name: str, sot_id: int, epoch: int,
                           tile_idx: int, enc: dict, checksum: str) -> None:
        """Land one streamed tile chunk in the staging namespace.  The
        checksum is recomputed over the decoded payload — a chunk torn in
        flight is rejected here, before it can ever reach a commit."""
        enc = {"kq": list(enc["kq"]), "pq": list(enc["pq"]),
               "h": int(enc["h"]), "w": int(enc["w"]),
               "gop": int(enc["gop"]), "qp": int(enc["qp"]),
               "n_frames": int(enc["n_frames"]),
               "size_bytes": float(enc["size_bytes"])}
        got = tile_checksum(enc)
        if got != checksum:
            raise ValueError(
                f"checksum mismatch staging {name!r} SOT {sot_id} tile "
                f"{tile_idx} (epoch {epoch}): chunk arrived torn")
        with self.scheduler.lock:
            if name in self._videos:
                raise ValueError(
                    f"video {name!r} already exists on this node")
            if self.root is None:
                self._import_mem.setdefault(name, {})[
                    (int(sot_id), int(epoch), int(tile_idx))] = (enc, checksum)
                return
        d = self._import_dir(name)
        d.mkdir(parents=True, exist_ok=True)
        final = d / f"s{int(sot_id)}_e{int(epoch)}_t{int(tile_idx)}.npz"
        tmp = d / f".{final.name}.tmp"
        members = {}
        for g in range(len(enc["kq"])):
            members[f"kq_{g}"] = enc["kq"][g]
            members[f"pq_{g}"] = enc["pq"][g]
        with open(tmp, "wb") as fh:  # handle, not name: numpy would
            np.savez_compressed(     # append ".npz" to the tmp name
                fh,
                meta=np.array([enc["h"], enc["w"], enc["gop"], enc["qp"],
                               enc["n_frames"]]),
                size=np.array([enc["size_bytes"]]),
                key=np.array([sot_id, epoch, tile_idx], dtype=np.int64),
                sha=np.frombuffer(checksum.encode(),
                                  dtype=np.uint8).copy(),
                **members)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, final)

    def _staged_chunk(self, name: str, sot_id: int, epoch: int,
                      tile_idx: int):
        """The staged enc for one chunk, re-verified, or None."""
        if self.root is None:
            got = self._import_mem.get(name, {}).get(
                (sot_id, epoch, tile_idx))
            return got[0] if got else None
        f = self._import_dir(name) / f"s{sot_id}_e{epoch}_t{tile_idx}.npz"
        if not f.exists():
            return None
        chunk = _load_staged_tile(f)
        return chunk[3] if chunk else None

    def commit_import(self, name: str, doc: dict,
                      min_epochs: Optional[dict] = None) -> dict:
        """Flip a fully staged replica copy live, atomically.  Verifies the
        doc's epoch table against ``min_epochs`` (the router's expected
        generations — a pre-retile copy never commits), re-verifies every
        tile's checksum from staging, then installs the entry and persists
        shard + catalog; the catalog write is the commit point.  Idempotent:
        re-committing a video already present at >= epochs is a no-op."""
        with self.scheduler.lock:
            doc_epochs = {int(s["sot_id"]): int(s["epoch"])
                          for s in doc["sots"]}
            if name in self._videos:
                have = {r.sot_id: r.epoch
                        for r in self._videos[name].store.sots}
                if all(have.get(s, -1) >= e for s, e in doc_epochs.items()):
                    self._discard_import(name)
                    return {"ok": True, "already": True,
                            "epochs": sorted(have.items())}
                raise ValueError(
                    f"video {name!r} already exists at older epochs; "
                    f"drop it before re-importing")
            for s, e in (min_epochs or {}).items():
                if doc_epochs.get(int(s), -1) < int(e):
                    raise ValueError(
                        f"import of {name!r} is stale: SOT {s} staged at "
                        f"epoch {doc_epochs.get(int(s), -1)} < required {e}")
            tiles = {}
            for s in doc["sots"]:
                n_tiles = len(s["heights"]) * len(s["widths"])
                for t in range(n_tiles):
                    key = (int(s["sot_id"]), int(s["epoch"]), t)
                    enc = self._staged_chunk(name, *key)
                    if enc is None:
                        raise ValueError(
                            f"cannot commit {name!r}: SOT {key[0]} tile {t} "
                            f"(epoch {key[1]}) is not staged intact")
                    tiles[key] = enc
            entry = self._entry_from_doc(name, doc, tiles=tiles)
            self._videos[name] = entry
            self._catalog_dirty = True
            self._dirty_videos.add(name)
            self.save()
            self._discard_import(name)
            return {"ok": True, "already": False,
                    "epochs": sorted(doc_epochs.items())}

    def abort_import(self, name: str) -> None:
        """Drop the staging namespace for a cancelled copy."""
        with self.scheduler.lock:
            self._discard_import(name)

    def _discard_import(self, name: str) -> None:
        self._import_mem.pop(name, None)
        if self.root is not None:
            d = self._import_dir(name)
            if d.exists():
                shutil.rmtree(d, ignore_errors=True)

    # ---------------------------------------------------------------- stats
    def storage_bytes(self, video: Optional[str] = None) -> float:
        if video is not None:
            return self.video(video).store.storage_bytes()
        return float(sum(e.store.storage_bytes()
                         for e in self._videos.values()))

    def stats(self) -> dict:
        """JSON-able engine-wide accounting snapshot: catalog membership,
        per-video decode/storage counters, and tile-cache stats.  This is
        the ``stats`` RPC of the socket front end (``core/server.py``), and
        what benchmarks use to assert cross-client cache sharing (a warm
        repeat leaves ``tiles_decoded_total`` unchanged)."""
        with self.scheduler.lock:
            per_video = {
                name: {"n_sots": len(e.store.sots),
                       "labels": sorted(e.index.labels(name)),
                       "tiles_decoded_total": e.store.tiles_decoded_total,
                       "pixels_decoded_total": e.store.pixels_decoded_total,
                       "storage_bytes": e.store.storage_bytes(),
                       "queries": len(e.history)}
                for name, e in self._videos.items()}
            # reply-marshalling accounting: per-query ScanStats objects in
            # history are stamped IN PLACE by the serving layer after the
            # reply ships, so served queries show up here with their
            # transport and packing cost (in-process queries contribute 0)
            by_transport: dict[str, int] = {}
            marshal_s = payload_bytes = 0.0
            for s in self.history:
                marshal_s += s.marshal_s
                payload_bytes += s.payload_bytes
                if s.transport:
                    by_transport[s.transport] = \
                        by_transport.get(s.transport, 0) + 1
            return {"videos": self.videos(),
                    "queries": len(self.history),
                    "storage_bytes": self.storage_bytes(),
                    "tiles_decoded_total": sum(
                        v["tiles_decoded_total"] for v in per_video.values()),
                    "pixels_decoded_total": sum(
                        v["pixels_decoded_total"]
                        for v in per_video.values()),
                    "per_video": per_video,
                    "marshalling": {"marshal_s": marshal_s,
                                    "payload_bytes": payload_bytes,
                                    "by_transport": by_transport},
                    "cache": dataclasses.asdict(self.tile_cache.stats())}

    # ------------------------------------------------------------- manifest
    def save(self, *, full: bool = False) -> None:
        """Persist durable state when backed by disk: the shards of dirty
        videos plus, when membership changed, the catalog file.  Each write
        is atomic (tmp + rename); ``full=True`` rewrites everything.
        Takes the scheduler lock, so saves never race a batch's end-of-run
        save or a concurrent durable mutation."""
        with self.scheduler.lock:
            if self.root is None:
                self._dirty_videos.clear()
                self._stale_policy_state.clear()
                self._catalog_dirty = False
                return
            self.root.mkdir(parents=True, exist_ok=True)
            names = set(self._videos) if full \
                else self._dirty_videos & set(self._videos)
            for name in sorted(names):
                doc = {"version": MANIFEST_VERSION, "name": name,
                       **self._entry_doc(self._videos[name])}
                _atomic_write_json(self.video_manifest_path(name), doc)
            self._stale_policy_state -= names  # state now durable
            if full or self._catalog_dirty or not self.catalog_path.exists():
                _atomic_write_json(self.catalog_path,
                                   {"version": MANIFEST_VERSION,
                                    "videos": self.videos()})
            self._dirty_videos.clear()
            self._catalog_dirty = False

    def _entry_doc(self, e: VideoEntry) -> dict:
        cm = e.cost_model
        return {
            "encoder": dataclasses.asdict(e.encoder),
            "sot_len": e.store.sot_len,
            "frame_hw": list(e.frame_hw) if e.frame_hw else None,
            "policy": policy_spec(e.policy),
            "cost_model": {"beta": cm.beta, "gamma": cm.gamma,
                           "r_squared": cm.r_squared,
                           "io_per_pixel": cm.io_per_pixel,
                           "encode_per_pixel": cm.encode_per_pixel,
                           "encode_per_tile": cm.encode_per_tile},
            "policy_state": e.policy.state_dict(),   # v3: runtime state
            "sots": [{"sot_id": r.sot_id, "frame_start": r.frame_start,
                      "frame_end": r.frame_end, "epoch": r.epoch,
                      "size_bytes": r.size_bytes,
                      "heights": list(r.layout.heights),
                      "widths": list(r.layout.widths)}
                     for r in e.store.sots],
            "index": e.index.dump(e.name),
        }

    def _entry_from_doc(self, name: str, v: dict, *,
                        tiles: Optional[dict] = None) -> VideoEntry:
        enc = EncoderConfig(**v["encoder"])
        cmd = v["cost_model"]
        cm = CostModel(beta=cmd["beta"], gamma=cmd["gamma"],
                       r_squared=cmd["r_squared"])
        # additive since the io-term PR: older shards simply lack it (0.0)
        cm.io_per_pixel = cmd.get("io_per_pixel", 0.0)
        cm.encode_per_pixel = cmd["encode_per_pixel"]
        cm.encode_per_tile = cmd["encode_per_tile"]
        policy = policy_from_spec(v["policy"])
        # v3 persists policy runtime state; a v2 shard has none (cold start)
        policy.load_state(v.get("policy_state") or {})
        entry = VideoEntry(
            name=name, encoder=enc, policy=policy,
            cost_model=cm,
            store=TileStore(name, enc,
                            root=str(self.root) if self.root else None,
                            sot_len=v["sot_len"],
                            decode_backend=self.decode_backend),
            index=SemanticIndex(),
            frame_hw=tuple(v["frame_hw"]) if v["frame_hw"] else None)
        records = [
            SOTRecord(s["sot_id"], s["frame_start"], s["frame_end"],
                      TileLayout(tuple(s["heights"]), tuple(s["widths"])),
                      epoch=s["epoch"], size_bytes=s["size_bytes"])
            for s in v["sots"]]
        if tiles is None:
            # catalog reopen: tile data already in its on-disk home
            entry.store.restore(records)
        else:
            # replica import: materialize every tile stream from the staged
            # chunks (works for in-memory and on-disk stores alike), then
            # register the records
            for rec in records:
                for t in range(rec.layout.n_tiles):
                    entry.store._write_tile(
                        rec, t, tiles[(rec.sot_id, rec.epoch, t)])
                entry.store._register(rec)
        entry.index.load(name, v["index"])
        return entry

    def _load_catalog(self) -> None:
        doc = json.loads(self.catalog_path.read_text())
        if doc.get("version") not in COMPAT_SHARD_VERSIONS:
            raise ValueError(f"unsupported catalog version "
                             f"{doc.get('version')!r} in {self.catalog_path}")
        migrate = doc.get("version") != MANIFEST_VERSION
        for name in doc["videos"]:
            v = json.loads(self.video_manifest_path(name).read_text())
            if v.get("version") not in COMPAT_SHARD_VERSIONS:
                raise ValueError(
                    f"unsupported manifest version {v.get('version')!r} "
                    f"for video {name!r}")
            self._videos[name] = self._entry_from_doc(name, v)
            if v.get("version") != MANIFEST_VERSION:
                migrate = True
                self._dirty_videos.add(name)
        if migrate:
            # v2 -> v3 migration on open: rewrite old shards (policy state
            # starts cold — v2 never recorded it) and stamp the catalog v3
            self._catalog_dirty = True
            self.save()

    def _migrate_v1(self) -> None:
        """Adopt a v1 monolithic manifest and rewrite it as v2 per-video
        shards + catalog.  The old file is kept as ``manifest.json.v1.bak``;
        tile data is untouched (no re-ingest)."""
        legacy = self.legacy_manifest_path
        doc = json.loads(legacy.read_text())
        ver = doc.get("version")
        if ver != LEGACY_MANIFEST_VERSION:
            raise ValueError(f"cannot migrate manifest version {ver!r} "
                             f"at {legacy}")
        for name, v in doc["videos"].items():
            self._videos[name] = self._entry_from_doc(name, v)
        self._dirty_videos = set(self._videos)
        self._catalog_dirty = True
        self.save()
        legacy.rename(legacy.parent / (legacy.name + ".v1.bak"))


# ------------------------------------------------------------------ helpers
def _load_staged_tile(path: pathlib.Path):
    """Read one staged import chunk back and re-verify it against its
    stored checksum.  Returns ``(sot_id, epoch, tile_idx, enc, sha)`` or
    ``None`` for anything unreadable or torn (a SIGKILLed destination can
    leave both) — callers discard those and re-stream."""
    try:
        with np.load(path) as z:
            sot_id, epoch, tile_idx = (int(x) for x in z["key"])
            h, w, gop, qp, n_frames = (int(x) for x in z["meta"])
            n_gops = n_frames // gop
            enc = {"kq": [z[f"kq_{g}"] for g in range(n_gops)],
                   "pq": [z[f"pq_{g}"] for g in range(n_gops)],
                   "h": h, "w": w, "gop": gop, "qp": qp,
                   "n_frames": n_frames,
                   "size_bytes": float(z["size"][0])}
            sha = z["sha"].tobytes().decode()
        if tile_checksum(enc) != sha:
            return None
        return sot_id, epoch, tile_idx, enc, sha
    except Exception:
        return None


def _atomic_write_json(path: pathlib.Path, doc: dict) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.parent / f".{path.name}.tmp"
    tmp.write_text(json.dumps(doc, indent=1))
    tmp.rename(path)


def _apply_limit(boxes_by_frame: dict[int, list], limit: int
                 ) -> dict[int, list]:
    """Keep at most ``limit`` regions, frames ascending (deterministic)."""
    out: dict[int, list] = {}
    left = limit
    for f in sorted(boxes_by_frame):
        if left <= 0:
            break
        take = boxes_by_frame[f][:left]
        out[f] = take
        left -= len(take)
    return out
