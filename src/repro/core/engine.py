"""VideoStore: the multi-video storage engine (paper §3, Fig. 2, scaled up).

Where the seed exposed a per-video ``TASM`` facade, :class:`VideoStore` is a
*catalog*: many named videos, each with its own physical configuration
(:class:`EncoderConfig`, tiling :class:`Policy`, calibrated
:class:`CostModel`, :class:`TileStore`, :class:`SemanticIndex`), behind one
declarative query surface::

    store = VideoStore(store_root="/data/tasm")
    store.add_video("cam0", encoder=EncoderConfig(gop=16), policy=RegretPolicy())
    store.ingest("cam0", frames)
    store.add_detections("cam0", dets_by_frame)
    res  = store.scan("cam0").labels("car").frames(0, 96).execute()
    plan = store.scan(["cam0", "cam1"]).labels("car").explain()  # no decode

Plan/execute split: the builder produces a logical :class:`ScanPlan`;
:meth:`VideoStore.lower` turns it into a :class:`PhysicalPlan` (the exact
SOTs and tile indices to decode, costed through the §4.1 what-if interface);
:meth:`VideoStore.execute` batches the planned tile decodes across SOTs
through a thread pool, assembles regions deterministically (identical pixels
and ordering to the old serial loop), then runs the per-SOT policy hooks.

Persistence: with ``store_root`` set, the catalog writes a JSON manifest
(``<root>/manifest.json``) holding every video's encoder, policy spec, cost
model, SOT records (frame spans, layouts, epochs, sizes) and semantic-index
entries.  A ``VideoStore(store_root=...)`` in a fresh process reopens the
manifest and serves scans without re-ingesting.  Policy *state* (e.g.
accumulated regret) is intentionally not persisted — policies restart cold.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import shutil
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Iterator, Optional

import numpy as np

from repro.codec.encode import EncoderConfig
from repro.core.cost import CostModel, pixels_and_tiles
from repro.core.layout import BBox, TileLayout
from repro.core.policies import (NoTilingPolicy, Policy, QueryInfo,
                                 policy_from_spec, policy_spec)
from repro.core.query import (PhysicalPlan, ScanPlan, ScanQuery, ScanResult,
                              ScanStats, SOTScan)
from repro.core.semantic_index import SemanticIndex
from repro.core.storage import SOTRecord, TileStore

MANIFEST_NAME = "manifest.json"
MANIFEST_VERSION = 1


@dataclass
class IngestStats:
    """Unified ingest accounting (one contract for every ingest path).

    - ``encode_s``  — seconds encoding the incoming frames (always paid).
    - ``pretile_s`` — *extra* seconds re-tiling beyond the plain encode
      (policy-driven pre-tiling).  0.0 when layouts arrive with the video
      (edge tiling: the camera already paid for them) or nothing pre-tiles.
    """
    encode_s: float = 0.0
    pretile_s: float = 0.0

    @property
    def total_s(self) -> float:
        return self.encode_s + self.pretile_s


@dataclass
class VideoEntry:
    """One catalog entry: a video plus its physical configuration."""
    name: str
    encoder: EncoderConfig
    policy: Policy
    cost_model: CostModel
    store: TileStore
    index: SemanticIndex
    frame_hw: Optional[tuple[int, int]] = None
    history: list = field(default_factory=list)


class VideoStore:
    """Catalog of videos + declarative scan queries with plan/execute split."""

    def __init__(self, store_root: Optional[str] = None, *,
                 default_encoder: Optional[EncoderConfig] = None,
                 default_policy: Optional[Policy] = None,
                 default_cost_model: Optional[CostModel] = None,
                 max_decode_workers: Optional[int] = None,
                 autoload: bool = True):
        self.root = pathlib.Path(store_root) if store_root else None
        self.default_encoder = default_encoder or EncoderConfig()
        self.default_policy = default_policy
        self.default_cost_model = default_cost_model
        self.max_decode_workers = max_decode_workers or min(
            8, os.cpu_count() or 4)
        self._videos: dict[str, VideoEntry] = {}
        self.history: list[ScanStats] = []
        self._dirty = False
        if self.root is not None and autoload and self.manifest_path.exists():
            self._load_manifest()

    # ------------------------------------------------------------- catalog
    @property
    def manifest_path(self) -> pathlib.Path:
        assert self.root is not None
        return self.root / MANIFEST_NAME

    def videos(self) -> list[str]:
        return sorted(self._videos)

    def video(self, name: str) -> VideoEntry:
        try:
            return self._videos[name]
        except KeyError:
            raise KeyError(f"unknown video {name!r}; catalog has "
                           f"{self.videos()}") from None

    def __contains__(self, name: str) -> bool:
        return name in self._videos

    def __len__(self) -> int:
        return len(self._videos)

    def __iter__(self) -> Iterator[str]:
        return iter(self.videos())

    def add_video(self, name: str, *,
                  encoder: Optional[EncoderConfig] = None,
                  policy: Optional[Policy] = None,
                  cost_model: Optional[CostModel] = None,
                  sot_len: Optional[int] = None) -> VideoEntry:
        if name in self._videos:
            raise ValueError(f"video {name!r} already in catalog")
        enc = encoder or self.default_encoder
        if policy is None:
            # clone the default so stateful policies (regret accumulators)
            # never share state across videos
            policy = (policy_from_spec(self.default_policy.spec())
                      if self.default_policy else NoTilingPolicy())
        entry = VideoEntry(
            name=name, encoder=enc, policy=policy,
            cost_model=cost_model or self.default_cost_model or CostModel(),
            store=TileStore(name, enc,
                            root=str(self.root) if self.root else None,
                            sot_len=sot_len),
            index=SemanticIndex())
        self._videos[name] = entry
        return entry

    def drop_video(self, name: str) -> None:
        entry = self.video(name)
        del self._videos[name]
        if self.root is not None:
            d = self.root / entry.name
            if d.exists():
                shutil.rmtree(d)
            self.save()

    # -------------------------------------------------------------- ingest
    def ingest(self, name: str, frames: np.ndarray, *, detections=None,
               initial_layouts: Optional[dict[int, TileLayout]] = None,
               **video_kw) -> IngestStats:
        """Encode ``frames`` into video ``name`` (auto-registered if absent).

        ``detections``: per-frame ``[(label, bbox)]`` preloading the semantic
        index before the policy's ``on_ingest`` runs (eager/edge strategies).
        ``initial_layouts``: sot_id -> layout applied at encode time (the
        edge-tiling path); when given, the policy's ``on_ingest`` is skipped.
        Returns :class:`IngestStats` — see its docstring for the contract.
        """
        entry = self._videos.get(name)
        if entry is None:
            entry = self.add_video(name, **video_kw)
        elif video_kw:
            raise ValueError(
                f"video {name!r} already configured; per-video kwargs "
                f"{sorted(video_kw)} only apply on first ingest")
        entry.frame_hw = frames.shape[1:]
        if detections is not None:
            for f, dets in enumerate(detections):
                for label, bbox in dets:
                    entry.index.add(name, f, label, bbox)
        stats = IngestStats()
        if initial_layouts:
            stats.encode_s = entry.store.ingest(frames, layouts=dict(initial_layouts))
        else:
            # encode untiled first so the store has SOT records for the policy
            stats.encode_s = entry.store.ingest(frames, layouts=None)
            pre = entry.policy.on_ingest(entry.index, entry.store, name,
                                         entry.frame_hw)
            for sot_id, layout in (pre or {}).items():
                stats.pretile_s += entry.store.retile(sot_id, layout)
        self._dirty = True
        self.save()
        return stats

    # ------------------------------------------------------------ metadata
    def add_metadata(self, video: str, frame: int, label: str,
                     x1: int, y1: int, x2: int, y2: int) -> None:
        """The paper's ADDMETADATA(v, f, label, x1, y1, x2, y2)."""
        self.video(video).index.add_metadata(video, frame, label,
                                             x1, y1, x2, y2)
        self._dirty = True

    def add_detections(self, video: str, detections_by_frame: dict) -> None:
        entry = self.video(video)
        for f, dets in detections_by_frame.items():
            for label, bbox in dets:
                entry.index.add(video, f, label, bbox)
        self._dirty = True
        self.save()

    # ---------------------------------------------------------------- scan
    def scan(self, videos, labels=None,
             frames: Optional[tuple[int, int]] = None) -> ScanQuery:
        """Start a scan-query builder over one video or a list of videos.

        ``labels``/``frames`` are optional shortcuts for the corresponding
        builder calls: ``store.scan("cam0", "car", (0, 96))``.
        """
        q = ScanQuery(self, videos)
        if labels is not None:
            q = q.labels(labels)
        if frames is not None:
            q = q.frames(*frames)
        return q

    # ---------------------------------------------------------- plan/lower
    def lower(self, plan: ScanPlan) -> PhysicalPlan:
        """Lower a logical plan to the exact SOTs + tile indices to decode,
        costing each SOT through the what-if interface.  Pure: touches only
        the semantic index, never tile data."""
        pplan = PhysicalPlan(logical=plan)
        remaining = plan.limit
        for name in plan.videos:
            entry = self.video(name)
            if plan.cnf == ():   # all-labels sentinel from .labels()
                all_labels = tuple(sorted(entry.index.labels(name)))
                if not all_labels:
                    continue
                cnf = (all_labels,)
            else:
                cnf = plan.cnf
            flat_labels = tuple(sorted({l for clause in cnf for l in clause}))
            t0 = time.perf_counter()
            boxes_by_frame = entry.index.query(name, cnf, plan.frame_range)
            pplan.lookup_s += time.perf_counter() - t0
            if remaining is not None:
                boxes_by_frame = _apply_limit(boxes_by_frame, remaining)
                remaining -= sum(len(b) for b in boxes_by_frame.values())
            if not boxes_by_frame:
                continue
            f_lo = min(boxes_by_frame)
            f_hi = max(boxes_by_frame) + 1
            qrange = plan.frame_range or (f_lo, f_hi)
            for rec in entry.store.sots_in_range(f_lo, f_hi):
                span = (rec.frame_start, rec.frame_end)
                local = {f: b for f, b in boxes_by_frame.items()
                         if span[0] <= f < span[1]}
                if not local:
                    continue
                needed: set[int] = set()
                for f, boxes in local.items():
                    for box in boxes:
                        needed.update(rec.layout.tiles_intersecting(box))
                p, t = pixels_and_tiles(rec.layout, local,
                                        gop=entry.encoder.gop,
                                        sot_frames=span)
                pplan.sot_scans.append(SOTScan(
                    video=name, sot_id=rec.sot_id, epoch=rec.epoch,
                    tile_idxs=tuple(sorted(needed)),
                    n_frames=max(local) - rec.frame_start + 1,
                    boxes_by_frame=local, query_range=qrange,
                    labels=flat_labels, est_pixels=p, est_tiles=t,
                    est_cost_s=entry.cost_model.cost(p, t)))
        return pplan

    # -------------------------------------------------------------- execute
    def execute(self, pplan: PhysicalPlan) -> ScanResult:
        """Run a physical plan: batched tile decodes across SOTs (thread
        pool), deterministic region assembly, then per-SOT policy hooks."""
        plan = pplan.logical
        stats = ScanStats(lookup_s=pplan.lookup_s)
        for ss in pplan.sot_scans:
            stats.pixels_decoded += ss.est_pixels
            stats.tiles_decoded += ss.est_tiles

        regions_by_video: dict[str, list] = {v: [] for v in plan.videos}
        if plan.decode and pplan.sot_scans:
            t0 = time.perf_counter()
            if len(pplan.sot_scans) == 1:
                decoded = [self._decode_one(pplan.sot_scans[0])]
            else:
                workers = min(self.max_decode_workers, len(pplan.sot_scans))
                with ThreadPoolExecutor(max_workers=workers) as pool:
                    decoded = list(pool.map(self._decode_one,
                                            pplan.sot_scans))
            stats.decode_s = time.perf_counter() - t0
            # deterministic assembly, in plan order (same ordering as the
            # old serial loop: SOTs ascending, frames ascending within each)
            for ss, (tiles, layout) in zip(pplan.sot_scans, decoded):
                rec = self.video(ss.video).store.sots[ss.sot_id]
                out = regions_by_video[ss.video]
                for f, boxes in sorted(ss.boxes_by_frame.items()):
                    rel = f - rec.frame_start
                    for box in boxes:
                        out.append((f, box, _crop(layout, tiles, rel, box)))

        # policy hooks, serially per SOT (policies mutate shared state)
        for ss in pplan.sot_scans:
            entry = self.video(ss.video)
            rec = entry.store.sots[ss.sot_id]
            qi = QueryInfo(ss.video, ss.labels, ss.query_range,
                           ss.boxes_by_frame, rec)
            new_layout = entry.policy.observe(qi, entry.index, entry.store,
                                              entry.cost_model)
            if new_layout is not None:
                stats.retile_s += entry.store.retile(rec.sot_id, new_layout)
                self._dirty = True

        regions: list = []
        if len(plan.videos) == 1:
            regions = regions_by_video[plan.videos[0]]
        else:
            for v in plan.videos:
                regions.extend((v, f, box, px)
                               for f, box, px in regions_by_video[v])
        stats.regions = len(regions)
        self.history.append(stats)
        for v in plan.videos:
            self.video(v).history.append(stats)
        if self._dirty:
            self.save()
        return ScanResult(regions=regions, stats=stats, plan=pplan,
                          regions_by_video=regions_by_video)

    def _decode_one(self, ss: SOTScan):
        """Decode one planned SOT's tile streams.  If the SOT was re-tiled
        since planning (stale epoch), recompute the needed tiles against the
        current layout."""
        entry = self.video(ss.video)
        rec = entry.store.sots[ss.sot_id]
        tile_idxs = ss.tile_idxs
        if rec.epoch != ss.epoch:
            needed: set[int] = set()
            for boxes in ss.boxes_by_frame.values():
                for box in boxes:
                    needed.update(rec.layout.tiles_intersecting(box))
            tile_idxs = tuple(sorted(needed))
        tiles = entry.store.decode_tiles(ss.sot_id, tile_idxs,
                                         n_frames=ss.n_frames)
        return tiles, rec.layout

    # -------------------------------------------------------------- what-if
    def what_if(self, video: str, labels,
                layout_by_sot: dict[int, TileLayout],
                t_range: Optional[tuple[int, int]] = None) -> float:
        """§4.1 what-if interface: estimated cost of a query under alternate
        layouts, without touching tile data."""
        entry = self.video(video)
        boxes_by_frame = entry.index.query(video, labels, t_range)
        total = 0.0
        for rec in entry.store.sots:
            span = (rec.frame_start, rec.frame_end)
            local = {f: b for f, b in boxes_by_frame.items()
                     if span[0] <= f < span[1]}
            if not local:
                continue
            layout = layout_by_sot.get(rec.sot_id, rec.layout)
            p, t = pixels_and_tiles(layout, local, gop=entry.encoder.gop,
                                    sot_frames=span)
            total += entry.cost_model.cost(p, t)
        return total

    # ---------------------------------------------------------------- stats
    def storage_bytes(self, video: Optional[str] = None) -> float:
        if video is not None:
            return self.video(video).store.storage_bytes()
        return float(sum(e.store.storage_bytes()
                         for e in self._videos.values()))

    # ------------------------------------------------------------- manifest
    def save(self) -> None:
        """Write the catalog manifest (atomic) when backed by disk."""
        if self.root is None:
            self._dirty = False
            return
        self.root.mkdir(parents=True, exist_ok=True)
        doc = {"version": MANIFEST_VERSION,
               "videos": {name: self._entry_doc(e)
                          for name, e in self._videos.items()}}
        tmp = self.manifest_path.with_suffix(".tmp")
        tmp.write_text(json.dumps(doc, indent=1))
        tmp.rename(self.manifest_path)
        self._dirty = False

    def _entry_doc(self, e: VideoEntry) -> dict:
        cm = e.cost_model
        return {
            "encoder": dataclasses.asdict(e.encoder),
            "sot_len": e.store.sot_len,
            "frame_hw": list(e.frame_hw) if e.frame_hw else None,
            "policy": policy_spec(e.policy),
            "cost_model": {"beta": cm.beta, "gamma": cm.gamma,
                           "r_squared": cm.r_squared,
                           "encode_per_pixel": cm.encode_per_pixel,
                           "encode_per_tile": cm.encode_per_tile},
            "sots": [{"sot_id": r.sot_id, "frame_start": r.frame_start,
                      "frame_end": r.frame_end, "epoch": r.epoch,
                      "size_bytes": r.size_bytes,
                      "heights": list(r.layout.heights),
                      "widths": list(r.layout.widths)}
                     for r in e.store.sots],
            "index": e.index.dump(e.name),
        }

    def _load_manifest(self) -> None:
        doc = json.loads(self.manifest_path.read_text())
        assert doc.get("version") == MANIFEST_VERSION, doc.get("version")
        for name, v in doc["videos"].items():
            enc = EncoderConfig(**v["encoder"])
            cmd = v["cost_model"]
            cm = CostModel(beta=cmd["beta"], gamma=cmd["gamma"],
                           r_squared=cmd["r_squared"])
            cm.encode_per_pixel = cmd["encode_per_pixel"]
            cm.encode_per_tile = cmd["encode_per_tile"]
            entry = VideoEntry(
                name=name, encoder=enc, policy=policy_from_spec(v["policy"]),
                cost_model=cm,
                store=TileStore(name, enc, root=str(self.root),
                                sot_len=v["sot_len"]),
                index=SemanticIndex(),
                frame_hw=tuple(v["frame_hw"]) if v["frame_hw"] else None)
            entry.store.restore([
                SOTRecord(s["sot_id"], s["frame_start"], s["frame_end"],
                          TileLayout(tuple(s["heights"]), tuple(s["widths"])),
                          epoch=s["epoch"], size_bytes=s["size_bytes"])
                for s in v["sots"]])
            entry.index.load(name, v["index"])
            self._videos[name] = entry


# ------------------------------------------------------------------ helpers
def _apply_limit(boxes_by_frame: dict[int, list], limit: int
                 ) -> dict[int, list]:
    """Keep at most ``limit`` regions, frames ascending (deterministic)."""
    out: dict[int, list] = {}
    left = limit
    for f in sorted(boxes_by_frame):
        if left <= 0:
            break
        take = boxes_by_frame[f][:left]
        out[f] = take
        left -= len(take)
    return out


def _crop(layout: TileLayout, tiles: dict[int, np.ndarray],
          rel_frame: int, box: BBox) -> np.ndarray:
    """Assemble the pixels of ``box`` from decoded tiles of one frame
    (bit-identical to the old serial TASM path)."""
    y1, x1, y2, x2 = box
    out = np.zeros((y2 - y1, x2 - x1), dtype=np.float32)
    for t in layout.tiles_intersecting(box):
        if t not in tiles:
            continue
        ty1, tx1, ty2, tx2 = layout.tile_rect(t)
        iy1, ix1 = max(y1, ty1), max(x1, tx1)
        iy2, ix2 = min(y2, ty2), min(x2, tx2)
        if iy1 >= iy2 or ix1 >= ix2:
            continue
        out[iy1 - y1:iy2 - y1, ix1 - x1:ix2 - x1] = \
            tiles[t][rel_frame, iy1 - ty1:iy2 - ty1, ix1 - tx1:ix2 - tx1]
    return out
