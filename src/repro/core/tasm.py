"""DEPRECATED single-video facade over the :class:`VideoStore` engine.

The seed of this repo exposed TASM (paper §3, Fig. 2) as a per-video object
with a positional ``scan()``.  The storage manager is now an engine-level
catalog — ``repro.core.engine.VideoStore`` — managing many named videos, a
persistent on-disk manifest, and a declarative query builder with an explicit
plan/execute split::

    # old (still works, emits DeprecationWarning)
    tasm = TASM("cam0", enc, policy=RegretPolicy())
    tasm.ingest(frames)
    res = tasm.scan("car", (0, 96))

    # new
    store = VideoStore(store_root=...)
    store.add_video("cam0", encoder=enc, policy=RegretPolicy())
    store.ingest("cam0", frames)
    res  = store.scan("cam0").labels("car").frames(0, 96).execute()
    plan = store.scan("cam0").labels("car").frames(0, 96).explain()

This module keeps the old constructor signature as a thin shim over a
one-video ``VideoStore`` so external callers migrate at their own pace.
``ScanStats``/``ScanResult`` now live in ``repro.core.query`` and are
re-exported here.  Differences from the seed facade:

- ``ingest`` returns :class:`~repro.core.engine.IngestStats` (one unified
  contract: ``encode_s`` = encoding seconds, always paid; ``pretile_s`` =
  extra policy-driven re-tiling seconds, 0.0 when layouts arrive with the
  video).  The seed returned retile-seconds on the policy path but
  encode-seconds on the ``initial_layouts`` path.
- tile decodes are batched across SOTs through the engine's thread pool;
  regions and pixels are bit-identical to the seed's serial loop.
"""
from __future__ import annotations

import warnings
from typing import Optional

import numpy as np

from repro.codec.encode import EncoderConfig
from repro.core.config import TuningConfig
from repro.core.cost import CostModel
from repro.core.engine import IngestStats, VideoStore
from repro.core.layout import TileLayout
from repro.core.policies import Policy
from repro.core.query import ScanResult, ScanStats  # noqa: F401 (re-export)


class TASM:
    """Deprecated one-video shim over :class:`VideoStore`."""

    def __init__(self, video: str, encoder: Optional[EncoderConfig] = None, *,
                 policy: Optional[Policy] = None,
                 cost_model: Optional[CostModel] = None,
                 sot_len: Optional[int] = None,
                 store_root: Optional[str] = None):
        warnings.warn(
            "TASM is deprecated; use repro.core.engine.VideoStore "
            "(catalog + store.scan(video).labels(...).frames(...).execute())",
            DeprecationWarning, stacklevel=2)
        # autoload=False keeps the seed facade's semantics: a reused
        # store_root is re-encoded, not adopted from its manifest.
        # mode="inline" likewise: the seed retiled synchronously inside
        # scan(), and this shim stays bit-for-bit compatible with that
        self._engine = VideoStore(store_root=store_root, autoload=False,
                                  tuning=TuningConfig(mode="inline"))
        self._entry = self._engine.add_video(
            video, encoder=encoder, policy=policy, cost_model=cost_model,
            sot_len=sot_len)
        self.video = video

    # -- configuration passthrough ------------------------------------------
    @property
    def engine(self) -> VideoStore:
        return self._engine

    @property
    def encoder(self) -> EncoderConfig:
        return self._entry.encoder

    @property
    def policy(self) -> Policy:
        return self._entry.policy

    @policy.setter
    def policy(self, p: Policy) -> None:
        self._entry.policy = p

    @property
    def cost_model(self) -> CostModel:
        return self._entry.cost_model

    @property
    def index(self):
        return self._entry.index

    @property
    def store(self):
        return self._entry.store

    @property
    def frame_hw(self):
        return self._entry.frame_hw

    @property
    def history(self) -> list[ScanStats]:
        return self._entry.history

    # -- old API, delegating -------------------------------------------------
    def ingest(self, frames: np.ndarray, *, detections=None,
               initial_layouts: Optional[dict[int, TileLayout]] = None
               ) -> IngestStats:
        """Encode the video; see ``VideoStore.ingest`` for the contract."""
        return self._engine.ingest(self.video, frames, detections=detections,
                                   initial_layouts=initial_layouts)

    def add_metadata(self, video_id: str, frame: int, label: str,
                     x1: int, y1: int, x2: int, y2: int) -> None:
        """ADDMETADATA through the engine, so it is locked and durable."""
        self._engine.add_metadata(video_id, frame, label, x1, y1, x2, y2)

    def add_detections(self, detections_by_frame: dict[int, list]) -> float:
        """Bulk-add (label, bbox) detections; returns 0 (timed by caller)."""
        self._engine.add_detections(self.video, detections_by_frame)
        return 0.0

    def scan(self, labels, t_range: Optional[tuple[int, int]] = None,
             *, decode: bool = True) -> ScanResult:
        """SCAN(video, L, T).  labels: str | [str] | CNF."""
        q = self._engine.scan(self.video).labels(labels).decode(decode)
        if t_range is not None:
            q = q.frames(*t_range)
        return q.execute()

    def what_if(self, labels, layout_by_sot: dict[int, TileLayout],
                t_range=None) -> float:
        """§4.1 what-if interface (delegates to the engine)."""
        return self._engine.what_if(self.video, labels, layout_by_sot,
                                    t_range)

    def storage_bytes(self) -> float:
        return self._engine.storage_bytes(self.video)
