"""TASM facade (paper §3, Fig. 2): the storage-manager API a VDBMS sits on.

    tasm = TASM(video_id, encoder_cfg, policy=RegretPolicy(), ...)
    tasm.ingest(frames, detections=...)            # optional pre-detections
    res = tasm.scan(labels="car", t_range=(0, 96)) # SCAN(v, L, T)
    tasm.add_metadata(video, frame, label, x1,y1,x2,y2)

``scan`` looks the predicate up in the semantic index, decodes only the tile
streams containing the requested regions, returns the cropped pixels, and
lets the installed policy re-tile SOTs afterwards (incremental tiling).  All
timings (index lookup, decode, re-tile, detection) are tracked per query so
the benchmark harness reproduces the paper's cumulative-cost figures.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Iterable, Optional

import numpy as np

from repro.codec.encode import EncoderConfig
from repro.core.cost import CostModel, pixels_and_tiles
from repro.core.layout import BBox, TileLayout
from repro.core.policies import NoTilingPolicy, Policy, QueryInfo
from repro.core.semantic_index import SemanticIndex, parse_predicate
from repro.core.storage import TileStore


@dataclass
class ScanStats:
    lookup_s: float = 0.0
    decode_s: float = 0.0
    retile_s: float = 0.0
    detect_s: float = 0.0
    pixels_decoded: float = 0.0
    tiles_decoded: float = 0.0
    regions: int = 0

    @property
    def query_s(self) -> float:
        """Paper's per-query time: index lookup + decode."""
        return self.lookup_s + self.decode_s

    @property
    def total_s(self) -> float:
        return self.lookup_s + self.decode_s + self.retile_s + self.detect_s


@dataclass
class ScanResult:
    regions: list  # (frame, bbox, pixel array)
    stats: ScanStats


class TASM:
    def __init__(self, video: str, encoder: Optional[EncoderConfig] = None, *,
                 policy: Optional[Policy] = None,
                 cost_model: Optional[CostModel] = None,
                 sot_len: Optional[int] = None,
                 store_root: Optional[str] = None):
        self.video = video
        self.encoder = encoder or EncoderConfig()
        self.policy = policy or NoTilingPolicy()
        self.cost_model = cost_model or CostModel()
        self.index = SemanticIndex()
        self.store = TileStore(video, self.encoder, root=store_root,
                               sot_len=sot_len)
        self.frame_hw: Optional[tuple[int, int]] = None
        self.history: list[ScanStats] = []

    # ------------------------------------------------------------------ ingest
    def ingest(self, frames: np.ndarray, *, detections=None,
               initial_layouts: Optional[dict[int, TileLayout]] = None) -> float:
        """Encode the video.  detections: per-frame [(label, bbox)] to preload
        the semantic index (eager / edge strategies).  The policy's
        ``on_ingest`` may install initial layouts (pre-tiling)."""
        self.frame_hw = frames.shape[1:]
        if detections is not None:
            for f, dets in enumerate(detections):
                for label, bbox in dets:
                    self.index.add(self.video, f, label, bbox)
        # ingest untiled first so the store has SOT records for the policy
        layouts = dict(initial_layouts or {})
        if not layouts:
            # policy may pre-tile using whatever the index knows
            tmp_layouts = None
            self.store.ingest(frames, layouts=None)
            tmp_layouts = self.policy.on_ingest(self.index, self.store,
                                                self.video, self.frame_hw)
            t_retile = 0.0
            for sot_id, layout in (tmp_layouts or {}).items():
                t_retile += self.store.retile(sot_id, layout)
            return t_retile
        return self.store.ingest(frames, layouts=layouts)

    # ---------------------------------------------------------------- metadata
    def add_metadata(self, video_id: str, frame: int, label: str,
                     x1: int, y1: int, x2: int, y2: int) -> None:
        self.index.add_metadata(video_id, frame, label, x1, y1, x2, y2)

    def add_detections(self, detections_by_frame: dict[int, list]) -> float:
        """Bulk-add (label, bbox) detections; returns 0 (timed by caller)."""
        for f, dets in detections_by_frame.items():
            for label, bbox in dets:
                self.index.add(self.video, f, label, bbox)
        return 0.0

    # -------------------------------------------------------------------- scan
    def scan(self, labels, t_range: Optional[tuple[int, int]] = None,
             *, decode: bool = True) -> ScanResult:
        """SCAN(video, L, T).  labels: str | [str] | CNF."""
        stats = ScanStats()
        cnf = parse_predicate(labels)
        flat_labels = tuple(sorted({l for clause in cnf for l in clause}))

        t0 = time.perf_counter()
        boxes_by_frame = self.index.query(self.video, cnf, t_range)
        stats.lookup_s = time.perf_counter() - t0

        regions: list = []
        f_lo = min(boxes_by_frame) if boxes_by_frame else 0
        f_hi = max(boxes_by_frame) + 1 if boxes_by_frame else 0
        touched = self.store.sots_in_range(f_lo, f_hi) if boxes_by_frame else []

        for rec in touched:
            span = (rec.frame_start, rec.frame_end)
            local = {f: b for f, b in boxes_by_frame.items()
                     if span[0] <= f < span[1]}
            if not local:
                continue
            p, t = pixels_and_tiles(rec.layout, local, gop=self.encoder.gop,
                                    sot_frames=span)
            stats.pixels_decoded += p
            stats.tiles_decoded += t

            if decode:
                needed: set[int] = set()
                for f, boxes in local.items():
                    for box in boxes:
                        needed.update(rec.layout.tiles_intersecting(box))
                last_rel = max(local) - rec.frame_start + 1
                t1 = time.perf_counter()
                tiles = self.store.decode_tiles(rec.sot_id, sorted(needed),
                                                n_frames=last_rel)
                stats.decode_s += time.perf_counter() - t1
                for f, boxes in sorted(local.items()):
                    rel = f - rec.frame_start
                    for box in boxes:
                        regions.append(
                            (f, box, self._crop(rec.layout, tiles, rel, box)))

            # policy hook (per SOT)
            qi = QueryInfo(self.video, flat_labels,
                           t_range or (f_lo, f_hi), local, rec)
            new_layout = self.policy.observe(qi, self.index, self.store,
                                             self.cost_model)
            if new_layout is not None:
                stats.retile_s += self.store.retile(rec.sot_id, new_layout)

        stats.regions = len(regions)
        self.history.append(stats)
        return ScanResult(regions=regions, stats=stats)

    def _crop(self, layout: TileLayout, tiles: dict[int, np.ndarray],
              rel_frame: int, box: BBox) -> np.ndarray:
        """Assemble the pixels of `box` from decoded tiles of one frame."""
        y1, x1, y2, x2 = box
        out = np.zeros((y2 - y1, x2 - x1), dtype=np.float32)
        for t in layout.tiles_intersecting(box):
            if t not in tiles:
                continue
            ty1, tx1, ty2, tx2 = layout.tile_rect(t)
            iy1, ix1 = max(y1, ty1), max(x1, tx1)
            iy2, ix2 = min(y2, ty2), min(x2, tx2)
            if iy1 >= iy2 or ix1 >= ix2:
                continue
            out[iy1 - y1:iy2 - y1, ix1 - x1:ix2 - x1] = \
                tiles[t][rel_frame, iy1 - ty1:iy2 - ty1, ix1 - tx1:ix2 - tx1]
        return out

    # -------------------------------------------------------------------- misc
    def storage_bytes(self) -> float:
        return self.store.storage_bytes()

    def what_if(self, labels, layout_by_sot: dict[int, TileLayout],
                t_range=None) -> float:
        """§4.1 what-if interface: estimated cost of a query under alternate
        layouts, without touching the store."""
        boxes_by_frame = self.index.query(self.video, labels, t_range)
        total = 0.0
        for rec in self.store.sots:
            span = (rec.frame_start, rec.frame_end)
            local = {f: b for f, b in boxes_by_frame.items()
                     if span[0] <= f < span[1]}
            if not local:
                continue
            layout = layout_by_sot.get(rec.sot_id, rec.layout)
            p, t = pixels_and_tiles(layout, local, gop=self.encoder.gop,
                                    sot_frames=span)
            total += self.cost_model.cost(p, t)
        return total
