"""Spatial grid index over bounding boxes (paper §3.2's suggested extension:
"A spatial index could further accelerate queries containing conjunctive
predicates by efficiently computing the intersection of bounding boxes
before fetching tiles").

A uniform grid (cell lists) per (video, frame): conjunctive CNF evaluation
only tests box pairs sharing a grid cell instead of the full cross product —
O(n·k) instead of O(n·m) when boxes are sparse.  Plugged into SemanticIndex
as an optional accelerator; equivalence with the brute-force path is property
tested.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Iterable, Optional

from repro.core.layout import BBox


def _intersect(a: BBox, b: BBox) -> Optional[BBox]:
    y1 = max(a[0], b[0]); x1 = max(a[1], b[1])
    y2 = min(a[2], b[2]); x2 = min(a[3], b[3])
    if y1 < y2 and x1 < x2:
        return (y1, x1, y2, x2)
    return None


class SpatialGrid:
    """A uniform grid over one frame's boxes."""

    def __init__(self, cell: int = 64):
        self.cell = cell
        self._cells: dict[tuple[int, int], list[int]] = defaultdict(list)
        self._boxes: list[BBox] = []

    def add(self, box: BBox) -> int:
        idx = len(self._boxes)
        self._boxes.append(box)
        y1, x1, y2, x2 = box
        for cy in range(y1 // self.cell, (max(y2 - 1, y1)) // self.cell + 1):
            for cx in range(x1 // self.cell, (max(x2 - 1, x1)) // self.cell + 1):
                self._cells[(cy, cx)].append(idx)
        return idx

    def candidates(self, box: BBox) -> set[int]:
        y1, x1, y2, x2 = box
        out: set[int] = set()
        for cy in range(y1 // self.cell, (max(y2 - 1, y1)) // self.cell + 1):
            for cx in range(x1 // self.cell, (max(x2 - 1, x1)) // self.cell + 1):
                out.update(self._cells.get((cy, cx), ()))
        return out

    def intersections(self, box: BBox) -> list[BBox]:
        out = []
        for i in sorted(self.candidates(box)):
            got = _intersect(box, self._boxes[i])
            if got:
                out.append(got)
        return out


def conjunctive_intersections(clause_a: Iterable[BBox], clause_b: Iterable[BBox],
                              *, cell: int = 64) -> list[BBox]:
    """All pairwise intersections between two box sets, grid-accelerated.

    Result order/content matches the brute-force nested loop (deduplicated,
    sorted) — verified by property test against the SemanticIndex path.
    """
    grid = SpatialGrid(cell=cell)
    bs = list(clause_b)
    for b in bs:
        grid.add(b)
    out: set[BBox] = set()
    for a in clause_a:
        out.update(grid.intersections(a))
    return sorted(out)


def brute_force_intersections(clause_a, clause_b) -> list[BBox]:
    out: set[BBox] = set()
    for a in clause_a:
        for b in clause_b:
            got = _intersect(a, b)
            if got:
                out.add(got)
    return sorted(out)
