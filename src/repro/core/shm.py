"""Shared-memory segment pool for zero-copy serving (``server.py``).

The socket transport of ``wire.py`` copies every region crop four times on
its way to a local client: ndarray -> npz blob -> socket -> client buffer
-> ndarray.  For clients on the SAME host none of those copies is needed:
the server writes each reply's arrays once into a
``multiprocessing.shared_memory`` segment and ships only ``(segment,
offset, shape, dtype)`` descriptors over the socket; the client maps the
segment and builds numpy views directly onto the shared pages.  Bits are
preserved exactly — a memcpy into shared pages is as lossless as the npz
round-trip — so results stay bit-identical to in-process ``execute()``.

Lifecycle (refcounted lease): one segment per reply, owned by the server's
:class:`SegmentPool` and *leased* to the connection the reply went to.
The client releases the lease with an ``shm_release`` RPC once the last
view is garbage-collected (or on ``close()``); the server then unlinks the
segment.  POSIX shm semantics make this safe against races: ``unlink``
removes the *name*, but pages stay valid until the last process unmaps
them, so a client still holding views keeps reading good data even after
the server reclaimed the name.  Segments are never re-used — "recycle"
means unlink — which keeps the protocol free of generation counters.

Crash-safety: every segment records its owning connection, so a client
that vanishes without releasing (SIGKILL, dropped socket) is reclaimed by
the server's connection-drop sweep.  CPython's resource tracker would
normally fight this ownership model — attaching processes register the
segment and unlink it on exit (bpo-39959) — so :func:`attach_segment`
untracks client-side mappings and the pool tolerates an already-unlinked
name.
"""
from __future__ import annotations

import functools
import os
import threading
import time
from typing import Any, Optional

import numpy as np

try:  # denied on some sandboxes (/dev/shm unavailable) — probe, don't die
    from multiprocessing import shared_memory as _shared_memory
except ImportError:  # pragma: no cover - environment-dependent
    _shared_memory = None

#: transport modes accepted by the server, the client, and $REPRO_TRANSPORT
TRANSPORTS = ("auto", "shm", "socket")

#: default pool budget; ``write`` falls back to npz when it would overflow
DEFAULT_POOL_BYTES = 1 << 30  # 1 GiB

_ALIGN = 64  # cache-line align each array within its segment

#: names created by a pool in THIS process.  ``attach_segment`` must skip
#: its resource-tracker unregister for these: in-process clients (tests,
#: quickstart) share the creator's tracker, where create+attach collapse
#: to ONE registration — unregistering on attach would strip it and make
#: the pool's eventual unlink a double-unregister (tracker stderr noise).
_OWNED_NAMES: set = set()


def resolve_transport(value: Optional[str],
                      env: str = "REPRO_TRANSPORT") -> str:
    """Resolve a transport request: explicit ``value`` wins, then the
    ``$REPRO_TRANSPORT`` override, then ``"auto"``.  Rejected values raise
    (mirrors ``wire.default_codec``'s ``REPRO_WIRE`` contract)."""
    if value is None:
        value = os.environ.get(env) or "auto"
        origin = f"{env}={value!r}"
    else:
        origin = f"transport={value!r}"
    if value not in TRANSPORTS:
        raise ValueError(f"{origin}; want auto|shm|socket")
    return value


@functools.lru_cache(maxsize=1)
def shm_available() -> bool:
    """True when this host can create (and map) POSIX shared memory."""
    if _shared_memory is None:
        return False
    try:
        seg = _shared_memory.SharedMemory(create=True, size=1)
    except Exception:  # noqa: BLE001 - any failure means "no shm here"
        return False
    try:
        seg.close()
        seg.unlink()
    except Exception:  # noqa: BLE001 - best-effort cleanup
        pass
    return True


if _shared_memory is not None:
    class _MappedSegment(_shared_memory.SharedMemory):
        """Client-side mapping whose *destructor* tolerates live exports.

        ``close()`` still raises BufferError while numpy views hold the
        buffer — the client's janitor relies on that to retry — but at
        interpreter shutdown the teardown order of a lease and its views
        is arbitrary, and a plain SharedMemory.__del__ sprays
        "Exception ignored ... BufferError" to stderr when it loses the
        race.  The pages are reclaimed by the kernel either way."""

        def __del__(self):
            try:
                super().__del__()
            except BufferError:
                pass


def attach_segment(name: str):
    """Map an existing segment by name (client side).  The mapping is
    UNREGISTERED from this process's resource tracker: the tracker would
    otherwise unlink the server-owned name when this process exits
    (bpo-39959), yanking the segment out from under every other client."""
    if _shared_memory is None:
        raise RuntimeError("shared memory is unavailable on this host")
    seg = _MappedSegment(name=name)
    if name not in _OWNED_NAMES:
        try:
            from multiprocessing import resource_tracker
            resource_tracker.unregister(getattr(seg, "_name", seg.name),
                                        "shared_memory")
        except Exception:  # noqa: BLE001 - tracker varies by version
            pass
    return seg


def _unlink(seg) -> None:
    try:
        seg.unlink()
    except FileNotFoundError:
        pass  # a crashed client's tracker got there first — same outcome
    except OSError:  # pragma: no cover - platform-dependent
        pass


def _align(n: int) -> int:
    return (n + _ALIGN - 1) & ~(_ALIGN - 1)


class _Segment:
    __slots__ = ("shm", "size", "owner", "nonce", "created")

    def __init__(self, shm, size: int, owner):
        self.shm = shm
        self.size = size
        self.owner = owner
        self.nonce: Optional[bytes] = None
        self.created = time.monotonic()


class SegmentPool:
    """Server-owned pool of leased shared-memory segments.

    ``write`` allocates one fresh segment per reply and copies the arrays
    in (64-byte aligned); ``release`` unlinks by name.  ``owner`` is an
    opaque per-connection token: ``release`` with an owner only honours
    names leased to that owner (a client cannot release its neighbour's
    segments), and ``release_owner``/``sweep`` reclaim everything a dead
    connection left behind.  All methods are thread-safe; ``write``
    returns ``None`` — the caller's cue to fall back to the npz payload —
    when the pool is closed, over budget, or shm allocation fails.
    """

    def __init__(self, *, max_bytes: int = DEFAULT_POOL_BYTES):
        self.max_bytes = int(max_bytes)
        self._lock = threading.Lock()
        self._segments: dict[str, _Segment] = {}
        self._bytes = 0
        self._closed = False

    # ------------------------------------------------------------ writing
    def write(self, arrays: list[np.ndarray],
              owner: Any = None) -> Optional[dict]:
        """Copy ``arrays`` into one new segment; returns the wire
        descriptor doc ``{"seg": name, "items": [[offset, shape, dtype],
        ...]}`` or ``None`` when the caller should fall back to npz."""
        if _shared_memory is None or not arrays:
            return None
        offsets: list[int] = []
        total = 0
        for a in arrays:
            total = _align(total)
            offsets.append(total)
            total += int(a.nbytes)
        size = max(total, 1)
        with self._lock:
            if self._closed or self._bytes + size > self.max_bytes:
                return None
            self._bytes += size  # reserve before the (unlocked) copy
        try:
            seg = _shared_memory.SharedMemory(create=True, size=size)
        except OSError:
            with self._lock:
                self._bytes -= size
            return None
        try:
            for a, off in zip(arrays, offsets):
                if a.nbytes:
                    dst = np.ndarray(a.shape, dtype=a.dtype,
                                     buffer=seg.buf, offset=off)
                    dst[...] = a
                    del dst
        finally:
            # drop the server's mapping NOW: the name (held in _Segment
            # for unlink) is what keeps the pages alive, and an idle
            # server should not hold a vma per outstanding lease
            try:
                seg.close()
            except BufferError:  # pragma: no cover - exports still alive
                pass
        rec = _Segment(seg, size, owner)
        _OWNED_NAMES.add(seg.name)
        with self._lock:
            if self._closed:  # raced close(): reclaim immediately
                self._bytes -= size
            else:
                self._segments[seg.name] = rec
                rec = None
        if rec is not None:
            _unlink(rec.shm)
            _OWNED_NAMES.discard(seg.name)
            return None
        return {"seg": seg.name,
                "items": [[off, list(a.shape), str(a.dtype)]
                          for a, off in zip(arrays, offsets)]}

    # -------------------------------------------------------- negotiation
    def probe(self, owner: Any = None) -> tuple[str, int]:
        """Allocate a nonce segment for transport negotiation: the client
        proves /dev/shm is genuinely shared (not a container-private
        namespace that happens to exist on both sides) by reading the
        nonce back.  Returns ``(segment_name, nonce_length)``."""
        nonce = os.urandom(16)
        doc = self.write([np.frombuffer(nonce, dtype=np.uint8)],
                         owner=owner)
        if doc is None:
            raise RuntimeError("shared-memory pool closed or exhausted")
        with self._lock:
            rec = self._segments.get(doc["seg"])
            if rec is not None:
                rec.nonce = nonce
        return doc["seg"], len(nonce)

    def verify(self, name: str, nonce_hex: str) -> bool:
        """Check a probe readback; the probe segment stays leased to its
        owner and is reclaimed like any reply segment."""
        try:
            nonce = bytes.fromhex(nonce_hex)
        except (TypeError, ValueError):
            return False
        with self._lock:
            rec = self._segments.get(name)
            return (rec is not None and rec.nonce is not None
                    and rec.nonce == nonce)

    # ------------------------------------------------------------ leases
    def release(self, names, owner: Any = None) -> int:
        """Unlink segments by name; with ``owner`` given, only names
        leased to that owner are honoured.  Unknown names are ignored
        (double releases and post-sweep stragglers are expected)."""
        freed = 0
        for name in names:
            with self._lock:
                rec = self._segments.get(str(name))
                if rec is None or (owner is not None
                                   and rec.owner is not owner):
                    continue
                del self._segments[str(name)]
                self._bytes -= rec.size
            _unlink(rec.shm)
            _OWNED_NAMES.discard(str(name))
            freed += 1
        return freed

    def release_owner(self, owner: Any) -> int:
        """Reclaim every segment leased to ``owner`` (connection drop)."""
        with self._lock:
            names = [n for n, r in self._segments.items()
                     if r.owner is owner]
        return self.release(names, owner=owner)

    def sweep(self, live_owners) -> int:
        """Reclaim segments whose owner is no longer in ``live_owners`` —
        the backstop for leases orphaned by a SIGKILLed client whose
        connection teardown raced a concurrent reply."""
        live = {id(o) for o in live_owners}
        with self._lock:
            names = [n for n, r in self._segments.items()
                     if r.owner is not None and id(r.owner) not in live]
        return self.release(names)

    # ------------------------------------------------------------- admin
    def stats(self) -> dict:
        with self._lock:
            return {"segments": len(self._segments), "bytes": self._bytes}

    def close(self) -> None:
        """Unlink everything.  Clients still holding views keep valid
        mappings (POSIX unlink-vs-mmap semantics); new ``write`` calls
        return ``None`` from here on."""
        with self._lock:
            self._closed = True
            names = list(self._segments)
        self.release(names)
