"""Tiling policies (paper §4.2–4.4).

Every policy sees each executed query (per-SOT) and proposes re-tilings.

- :class:`KQKOPolicy`      — §4.2 known-query/known-object optimization.
- :class:`LazyPolicy`      — §4.3 lazy detection (tile once locations known).
- :class:`MorePolicy`      — §5.3 "Incremental, more": after a query, re-tile
                              queried SOTs around all labels queried so far.
- :class:`RegretPolicy`    — §4.4 online regret accumulation; re-tile when
                              accumulated regret exceeds eta * R(s, L).
- :class:`NoTilingPolicy`  — baseline ω everywhere.

All policies share the cost model's what-if interface: candidate layouts are
costed with C(s,q,L) without re-encoding anything (paper §4.1's [12]).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Optional

from repro.core.cost import CostModel, pixels_and_tiles
from repro.core.layout import TileLayout, partition, single_tile_layout
from repro.core.semantic_index import SemanticIndex
from repro.core.storage import SOTRecord, TileStore

ALPHA = 0.8  # §3.4.4/§5.2.3 minimum decode-reduction threshold
ETA = 1.0    # §4.4 regret multiplier (online-indexing setting of [11])


@dataclass
class QueryInfo:
    """One executed query as seen by a policy, restricted to one SOT."""
    video: str
    labels: tuple[str, ...]           # flat set of labels requested
    frame_range: tuple[int, int]
    boxes_by_frame: dict              # frame -> [bbox] (requested regions)
    sot: SOTRecord


class Policy:
    name = "base"
    #: True for policies carrying runtime state that must be persisted in
    #: the manifest (and re-saved after every ``observe``): see
    #: :meth:`state_dict`/:meth:`load_state`.
    stateful = False

    def on_ingest(self, index: SemanticIndex, store: TileStore,
                  video: str, frame_hw) -> dict[int, TileLayout]:
        """Layouts to apply at ingest time (sot_id -> layout)."""
        return {}

    def observe(self, q: QueryInfo, index: SemanticIndex, store: TileStore,
                model: CostModel) -> Optional[TileLayout]:
        """Pure proposal function, called once per executed query per SOT:
        returns a layout *proposal* for ``q.sot`` (or None).  It may mutate
        the policy's own runtime state but must never touch tile data —
        whether/when the proposal is applied is the caller's business (the
        scan path applies it synchronously under ``tuning="inline"``; the
        :class:`~repro.core.tuner.PhysicalTuner` coalesces, scores, and
        applies asynchronously under ``tuning="background"``)."""
        return None

    def on_applied(self, sot_id: int, layout: TileLayout) -> None:
        """Proposal-feedback hook: an :meth:`observe` proposal for
        ``sot_id`` was resolved — applied (or found to be a no-op because
        the SOT already had the layout).  Policies that mutate bookkeeping
        when *proposing* can finalize it here.  Called under the scheduler
        lock by whichever path resolves the proposal (inline hook or the
        background tuner)."""

    def on_superseded(self, sot_id: int, layout: TileLayout) -> None:
        """Proposal-feedback hook: an :meth:`observe` proposal for
        ``sot_id`` will never be applied — a newer proposal coalesced it
        away, a foreground retile made it stale, or tuner admission
        deferred it as net-negative.  Policies that reset bookkeeping when
        proposing (RegretPolicy zeroes the winning alternative's regret)
        restore it here instead of silently losing it, so a superseded
        proposal can re-trigger once the workload warrants it again."""

    def spec(self) -> dict:
        """JSON-serializable constructor spec for manifest persistence.
        Runtime state travels separately via :meth:`state_dict`."""
        return {"name": self.name}

    def state_dict(self) -> dict:
        """JSON-serializable runtime state (accumulated regret, seen
        labels, ...), persisted per video in the manifest shard so a
        reopened store resumes tuning where it left off instead of cold.
        Stateless policies return ``{}``."""
        return {}

    def load_state(self, state: dict) -> None:
        """Restore :meth:`state_dict` output (tolerant of ``{}``/missing
        keys: absent state means a cold start)."""


class NoTilingPolicy(Policy):
    name = "not_tiled"


def _sot_boxes(index: SemanticIndex, video: str, labels: Iterable[str],
               sot: SOTRecord) -> list:
    out = []
    for label in labels:
        for f, boxes in index.boxes_for_label(
                video, label, (sot.frame_start, sot.frame_end)).items():
            out.extend(boxes)
    return out


def _alpha_ok(layout: TileLayout, q: QueryInfo, gop: int, alpha: float) -> bool:
    """P(s,q,L) < alpha * P(s,q,omega)."""
    omega = single_tile_layout(layout.frame_height, layout.frame_width)
    span = (q.sot.frame_start, q.sot.frame_end)
    p_l, _ = pixels_and_tiles(layout, q.boxes_by_frame, gop=gop, sot_frames=span)
    p_o, _ = pixels_and_tiles(omega, q.boxes_by_frame, gop=gop, sot_frames=span)
    return p_l < alpha * p_o if p_o > 0 else True


class PretileAllPolicy(Policy):
    """Tile every SOT around ALL detected objects before queries ("All
    objects" baseline in §5.3)."""

    name = "pretile_all"

    def __init__(self, granularity: str = "fine"):
        self.granularity = granularity

    def spec(self):
        return {"name": self.name, "granularity": self.granularity}

    def on_ingest(self, index, store, video, frame_hw):
        H, W = frame_hw
        layouts = {}
        for rec in store.sots:
            boxes = _sot_boxes(index, video, index.labels(video), rec)
            if boxes:
                layouts[rec.sot_id] = partition(H, W, boxes,
                                                granularity=self.granularity)
        return layouts


class KQKOPolicy(Policy):
    """§4.2: known workload objects O_Q with locations in the index.  Tiles
    each SOT with the fine-grained layout around O_Q ∩ SOT, unless the alpha
    rule says tiling won't pay."""

    name = "kqko"

    def __init__(self, query_objects: Iterable[str], alpha: float = ALPHA):
        self.o_q = tuple(query_objects)
        self.alpha = alpha

    def spec(self):
        return {"name": self.name, "query_objects": list(self.o_q),
                "alpha": self.alpha}

    def on_ingest(self, index, store, video, frame_hw):
        H, W = frame_hw
        layouts = {}
        for rec in store.sots:
            boxes = _sot_boxes(index, video, self.o_q, rec)
            if not boxes:
                continue
            cand = partition(H, W, boxes, granularity="fine")
            # alpha rule against the whole-workload proxy: pixels of tiles
            # containing the boxes vs full frames
            boxes_by_frame = {}
            for label in self.o_q:
                for f, bs in index.boxes_for_label(
                        video, label, (rec.frame_start, rec.frame_end)).items():
                    boxes_by_frame.setdefault(f, []).extend(bs)
            qi = QueryInfo(video, self.o_q, (rec.frame_start, rec.frame_end),
                           boxes_by_frame, rec)
            if _alpha_ok(cand, qi, store.encoder.gop, self.alpha):
                layouts[rec.sot_id] = cand
        return layouts


class LazyPolicy(Policy):
    """§4.3 lazy detection: after each query, tile the touched SOTs whose O_Q
    locations are now all known."""

    name = "lazy"

    def __init__(self, query_objects: Iterable[str], alpha: float = ALPHA):
        self.o_q = tuple(query_objects)
        self.alpha = alpha

    def spec(self):
        return {"name": self.name, "query_objects": list(self.o_q),
                "alpha": self.alpha}

    def observe(self, q, index, store, model):
        rec = q.sot
        span = (rec.frame_start, rec.frame_end)
        if not index.has_locations(q.video, self.o_q, span):
            return None  # wait: future queries target objects not yet located
        H, W = rec.layout.frame_height, rec.layout.frame_width
        boxes = _sot_boxes(index, q.video, self.o_q, rec)
        if not boxes:
            return None
        cand = partition(H, W, boxes, granularity="fine")
        if cand == rec.layout:
            return None
        if not _alpha_ok(cand, q, store.encoder.gop, self.alpha):
            return None
        return cand


class MorePolicy(Policy):
    """"Incremental, more" (§5.3): re-tile each queried SOT around all object
    classes queried so far."""

    name = "incremental_more"
    stateful = True

    def __init__(self):
        self.seen: set[str] = set()

    def state_dict(self):
        return {"seen": sorted(self.seen)}

    def load_state(self, state):
        self.seen = set(state.get("seen", ()))

    def observe(self, q, index, store, model):
        self.seen.update(q.labels)
        rec = q.sot
        H, W = rec.layout.frame_height, rec.layout.frame_width
        boxes = _sot_boxes(index, q.video, self.seen, rec)
        if not boxes:
            return None
        cand = partition(H, W, boxes, granularity="fine")
        if cand == rec.layout:
            return None
        return cand


class RegretPolicy(Policy):
    """§4.4: accumulate regret per (SOT, alternative layout); re-tile when
    delta_k > eta * R(s, L_k), skipping layouts that would hurt (alpha rule
    on any observed query)."""

    name = "incremental_regret"
    stateful = True

    def __init__(self, eta: float = ETA, alpha: float = ALPHA,
                 max_subsets: int = 16):
        self.eta = eta
        self.alpha = alpha
        self.max_subsets = max_subsets
        self.seen: set[str] = set()
        self.queried_combos: set[frozenset] = set()
        # (sot_id, labelset) -> accumulated regret seconds
        self.regret: dict[tuple[int, frozenset], float] = {}
        # (sot_id, labelset) vetoed by the alpha rule on some observed query
        self.vetoed: set[tuple[int, frozenset]] = set()
        # in-flight proposal bookkeeping: (sot_id, layout) -> list of
        # (regret key, pre-reset regret value), one per not-yet-resolved
        # proposal of that layout.  observe() resets the winning
        # alternative's regret when it proposes; the whole entry is
        # discarded when the layout is applied and restored when it is
        # superseded (transient — not part of state_dict: the tuner
        # resolves every pending proposal before a durable flush)
        self._pending: dict[tuple[int, TileLayout], list] = {}

    def spec(self):
        return {"name": self.name, "eta": self.eta, "alpha": self.alpha,
                "max_subsets": self.max_subsets}

    def state_dict(self):
        # frozenset keys become sorted label lists; entry order is sorted so
        # the serialization is deterministic across runs/hash seeds
        key = lambda k: (k[0], sorted(k[1]))   # (sot_id, labelset)
        return {
            "seen": sorted(self.seen),
            "queried_combos": sorted(sorted(c) for c in self.queried_combos),
            "regret": [[s, sorted(ls), v] for (s, ls), v in
                       sorted(self.regret.items(), key=lambda kv: key(kv[0]))],
            "vetoed": [[s, sorted(ls)] for s, ls in
                       sorted(self.vetoed, key=key)],
        }

    def load_state(self, state):
        self.seen = set(state.get("seen", ()))
        self.queried_combos = {frozenset(c)
                               for c in state.get("queried_combos", ())}
        self.regret = {(s, frozenset(ls)): float(v)
                       for s, ls, v in state.get("regret", ())}
        self.vetoed = {(s, frozenset(ls))
                       for s, ls in state.get("vetoed", ())}

    def _alternatives(self) -> list[frozenset]:
        alts = [frozenset([l]) for l in sorted(self.seen)]
        if len(self.seen) > 1:
            alts.append(frozenset(self.seen))
        for combo in self.queried_combos:
            if combo not in alts:
                alts.append(combo)
        return alts[: self.max_subsets]

    def observe(self, q, index, store, model):
        self.seen.update(q.labels)
        if len(q.labels) >= 1:
            self.queried_combos.add(frozenset(q.labels))
        rec = q.sot
        H, W = rec.layout.frame_height, rec.layout.frame_width
        gop = store.encoder.gop
        span = (rec.frame_start, rec.frame_end)
        p_cur, t_cur = pixels_and_tiles(rec.layout, q.boxes_by_frame,
                                        gop=gop, sot_frames=span)
        c_cur = model.cost(p_cur, t_cur)

        best = None
        for labelset in self._alternatives():
            key = (rec.sot_id, labelset)
            boxes = _sot_boxes(index, q.video, labelset, rec)
            if not boxes:
                continue
            cand = partition(H, W, boxes, granularity="fine")
            if cand == rec.layout:
                continue
            p_k, t_k = pixels_and_tiles(cand, q.boxes_by_frame,
                                        gop=gop, sot_frames=span)
            # delta regret = C(s, q, L_cur) - C(s, q, L_k)
            self.regret[key] = self.regret.get(key, 0.0) + (
                c_cur - model.cost(p_k, t_k))
            if not _alpha_ok(cand, q, gop, self.alpha):
                self.vetoed.add(key)
            if key in self.vetoed:
                continue
            # R(s, L_k): re-encode cost of the whole SOT under L_k
            n_frames = rec.frame_end - rec.frame_start
            r = model.encode_cost(cand.total_pixels() * n_frames, cand.n_tiles)
            if self.regret[key] > self.eta * r:
                score = self.regret[key] - self.eta * r
                if best is None or score > best[0]:
                    best = (score, key, cand)
        if best is None:
            return None
        _, key, cand = best
        self._pending.setdefault((rec.sot_id, cand), []).append(
            (key, self.regret[key]))
        self.regret[key] = 0.0
        return cand

    def on_applied(self, sot_id, layout):
        # every pending proposal of this exact layout is satisfied by the
        # one re-encode (re-proposals of one layout pile up under one key,
        # see the tuner's coalescing): all their resets become legitimate
        self._pending.pop((sot_id, layout), None)

    def on_superseded(self, sot_id, layout):
        # the re-encode never happened (coalesced away by a *different*
        # layout, deferred, or epoch-stale): restore the regret the
        # proposal(s) zeroed so the alternative can win again on evidence
        for key, value in self._pending.pop((sot_id, layout), ()):
            self.regret[key] = self.regret.get(key, 0.0) + value


# ---------------------------------------------------------------------------
# Manifest persistence: JSON-serializable policy specs (engine.py manifest)
# ---------------------------------------------------------------------------
def policy_spec(policy: Policy) -> dict:
    """Serialize a policy's *construction* (not its runtime state)."""
    return policy.spec()


_REGISTRY: dict[str, type] = {
    NoTilingPolicy.name: NoTilingPolicy,
    PretileAllPolicy.name: PretileAllPolicy,
    KQKOPolicy.name: KQKOPolicy,
    LazyPolicy.name: LazyPolicy,
    MorePolicy.name: MorePolicy,
    RegretPolicy.name: RegretPolicy,
}


def policy_from_spec(spec: dict) -> Policy:
    """Rebuild a policy from :func:`policy_spec` output.  Unknown names fall
    back to :class:`NoTilingPolicy` (manifests stay readable across
    versions)."""
    kwargs = {k: v for k, v in spec.items() if k != "name"}
    cls = _REGISTRY.get(spec.get("name", ""), NoTilingPolicy)
    try:
        return cls(**kwargs)
    except TypeError:
        return NoTilingPolicy()
