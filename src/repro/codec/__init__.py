from repro.codec.transform import dct2_blocks, idct2_blocks, to_blocks, from_blocks
from repro.codec.encode import (
    EncoderConfig,
    encode_tile,
    decode_tile,
    encoded_size_bytes,
)
from repro.codec.psnr import psnr
