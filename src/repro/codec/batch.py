"""Batched tile decode: many ``(tile, GOP-range, block-mask)`` selections in
one (or a few) fused accelerator dispatches.

``decode_tile_batch`` is the batched counterpart of
:func:`repro.codec.encode.decode_tile` — the numpy path stays the oracle,
and this path is **bit-identical** to it item by item.  Instead of one
einsum call per tile per GOP inside a Python loop, the whole batch is
flattened into a padded block stream:

1. **Gather** — for every item, the selected GOPs' coefficient blocks are
   gathered (ROI block masks applied *here*, on the host, so masked-out
   blocks never reach the accelerator) into columns of a ``[F, M, 8, 8]``
   int16 stream: row 0 the intra keyframe, rows 1..n-1 the inter residuals.
2. **Bucket** — items are grouped by ``(qp, F bucket)``; each group's
   stream is allocated at power-of-two column counts
   (:func:`repro.kernels.decode.ops.pad_bucket`) so jit traces stay bounded
   across arbitrary tile layouts.  Frame-depth padding appends zero
   coefficient rows, which decode to zero pixels *after* every real frame
   and are sliced off.
3. **Dispatch** — one fused dequant+IDCT+cumsum call per group: the Pallas
   kernel on TPU, the jitted jnp path under XLA elsewhere (both
   bit-identical to numpy — see ``repro/kernels/decode``).
4. **Scatter** — each item's columns are scattered back into its output
   canvas exactly like the oracle (full tiles via the block-grid reshape,
   ROI masks via the same advanced-index write, unselected blocks zero).
"""
from __future__ import annotations

import numpy as np

from repro.kernels.decode.ops import MIN_COLUMNS, decode_fused_op, pad_bucket

#: one decode request: (enc dict, gop_indices, frames_within, blocks) with
#: the exact semantics of ``decode_tile``'s parameters of the same names
DecodeItem = tuple


def _gather_gops(seq, idx: list[int]) -> np.ndarray:
    """Select GOP members from the ``kq``/``pq`` field, which is a stacked
    ndarray for in-memory tiles or a per-GOP list for lazy npz reads."""
    if isinstance(seq, np.ndarray):
        return seq[idx]
    return np.stack([seq[g] for g in idx])


class _Slot:
    """Where one item's columns live inside its group's block stream."""

    __slots__ = ("item", "n", "n_gops", "bsel", "offset", "span")

    def __init__(self, item, n, n_gops, bsel, offset, span):
        self.item = item
        self.n = n                  # frames decoded per selected GOP
        self.n_gops = n_gops
        self.bsel = bsel            # None = full tile
        self.offset = offset
        self.span = span


def decode_tile_batch(items, *, use_pallas: bool | None = None,
                      interpret: bool = False) -> list[np.ndarray]:
    """Decode many tile selections with fused batched dispatches.

    ``items``: sequence of ``(enc, gop_indices, frames_within, blocks)``
    tuples.  Returns one ``[T', h, w] float32`` array per item, bit-identical
    to ``decode_tile(enc, gop_indices, frames_within, blocks)``.
    """
    results: list = [None] * len(items)
    # (qp, F_bucket) -> next free column / that group's slots
    columns: dict[tuple[int, int], int] = {}
    slots_by_group: dict[tuple[int, int], list[_Slot]] = {}

    for i, (enc, gop_indices, frames_within, blocks) in enumerate(items):
        h, w, gop, qp = enc["h"], enc["w"], enc["gop"], enc["qp"]
        n_gops_total = len(enc["kq"])
        idx = (list(range(n_gops_total)) if gop_indices is None
               else list(gop_indices))
        n = gop if frames_within is None else max(1, min(frames_within, gop))
        if blocks is not None:
            bsel = np.asarray(sorted(set(blocks)), dtype=np.intp)
            nb_sel = int(bsel.size)
        else:
            bsel = None
            nb_sel = (h // 8) * (w // 8)
        if not idx or nb_sel == 0:
            # nothing to dispatch: the oracle returns an all-zero canvas
            results[i] = np.zeros((len(idx) * n, h, w), dtype=np.float32)
            continue
        key = (qp, pad_bucket(n, lo=1))
        off = columns.get(key, 0)
        span = len(idx) * nb_sel
        columns[key] = off + span
        slots_by_group.setdefault(key, []).append(
            _Slot((i, enc, idx), n, len(idx), bsel, off, span))

    for (qp, f_bucket), slots in slots_by_group.items():
        total = columns[(qp, f_bucket)]
        m_pad = pad_bucket(total, lo=MIN_COLUMNS)
        q = np.zeros((f_bucket, m_pad, 8, 8), dtype=np.int16)
        for s in slots:
            _, enc, idx = s.item
            kq = _gather_gops(enc["kq"], idx)          # [G, nb, 8, 8]
            if s.bsel is not None:
                kq = kq[:, s.bsel]
            q[0, s.offset:s.offset + s.span] = kq.reshape(-1, 8, 8)
            if s.n > 1:
                pq = _gather_gops(enc["pq"], idx)[:, :s.n - 1]
                if s.bsel is not None:
                    pq = pq[:, :, s.bsel]
                # [G, n-1, nb, 8, 8] -> [n-1, G*nb, 8, 8] gop-major columns
                q[1:s.n, s.offset:s.offset + s.span] = \
                    pq.transpose(1, 0, 2, 3, 4).reshape(s.n - 1, s.span, 8, 8)
        out = np.asarray(decode_fused_op(q, qp=qp, use_pallas=use_pallas,
                                         interpret=interpret))
        for s in slots:
            i, enc, _ = s.item
            h, w = enc["h"], enc["w"]
            seg = out[:s.n, s.offset:s.offset + s.span]
            if s.bsel is None:
                # [n, G, h/8, w/8, 8, 8] -> gop-major frames [G*n, h, w]
                arr = seg.reshape(s.n, s.n_gops, h // 8, w // 8, 8, 8)
                arr = arr.transpose(1, 0, 2, 4, 3, 5)
                results[i] = np.ascontiguousarray(
                    arr.reshape(s.n_gops * s.n, h, w))
            else:
                canvas = np.zeros((s.n_gops * s.n, h, w), dtype=np.float32)
                view = canvas.reshape(-1, h // 8, 8, w // 8, 8)
                rs, cs = np.divmod(s.bsel, w // 8)
                frames = seg.reshape(s.n, s.n_gops, -1, 8, 8)
                frames = frames.transpose(1, 0, 2, 3, 4).reshape(
                    s.n_gops * s.n, -1, 8, 8)
                # same advanced-index write as the oracle's ROI scatter
                view[:, rs, :, cs] = frames.transpose(1, 0, 2, 3)
                results[i] = canvas
    return results
