"""Quantization for the transform codec.

A JPEG-style base matrix scaled by QP; intra (keyframe) blocks use the full
matrix, inter (residual) blocks a flatter one — mirroring how real codecs
spend more bits on keyframes (this is what makes short GOPs storage-heavy,
the effect behind the paper's Fig. 9 tradeoff).
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

# JPEG luminance base quantization matrix
_BASE = np.array([
    [16, 11, 10, 16, 24, 40, 51, 61],
    [12, 12, 14, 19, 26, 58, 60, 55],
    [14, 13, 16, 24, 40, 57, 69, 56],
    [14, 17, 22, 29, 51, 87, 80, 62],
    [18, 22, 37, 56, 68, 109, 103, 77],
    [24, 35, 55, 64, 81, 104, 113, 92],
    [49, 64, 78, 87, 103, 121, 120, 101],
    [72, 92, 95, 98, 112, 100, 103, 99],
], dtype=np.float32)


@functools.lru_cache(maxsize=None)
def quant_matrix(qp: int, intra: bool) -> np.ndarray:
    scale = max(qp, 1) / 16.0
    m = _BASE * scale
    if not intra:
        m = np.maximum(m * 0.75, 1.0)  # flatter for residuals
    return np.maximum(m, 1.0).astype(np.float32)


def quantize(coeffs: jnp.ndarray, qp: int, intra: bool) -> jnp.ndarray:
    m = jnp.asarray(quant_matrix(qp, intra))
    return jnp.round(coeffs / m).astype(jnp.int16)


def dequantize(q: jnp.ndarray, qp: int, intra: bool) -> jnp.ndarray:
    m = jnp.asarray(quant_matrix(qp, intra))
    return q.astype(jnp.float32) * m
