"""Entropy-coded size model.

We do not implement a binary arithmetic coder; storage size is estimated from
the quantized coefficients with a zig-zag run-length + exp-Golomb bit model,
which tracks real codec size behaviour (keyframes cost more, busy tiles cost
more, empty residual blocks cost ~nothing).  The estimate is deterministic
and is what the paper's storage-size experiments (Fig. 9) measure against.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.lru_cache(maxsize=None)
def _zigzag_order(n: int = 8) -> np.ndarray:
    idx = np.arange(n * n).reshape(n, n)
    order = []
    for s in range(2 * n - 1):
        diag = [(i, s - i) for i in range(n) if 0 <= s - i < n]
        if s % 2 == 0:
            diag = diag[::-1]
        order.extend(idx[i, j] for i, j in diag)
    return np.asarray(order, dtype=np.int32)


def block_bits(q: jnp.ndarray) -> jnp.ndarray:
    """Estimated bits per 8x8 quantized block.  q: [..., 8, 8] int."""
    flat = q.reshape(q.shape[:-2] + (64,)).astype(jnp.float32)
    zz = flat[..., _zigzag_order()]
    mag = jnp.abs(zz)
    # exp-Golomb-ish: ~ 2*log2(|c|+1)+1 bits per nonzero coefficient
    coef_bits = jnp.where(mag > 0, 2.0 * jnp.log2(mag + 1.0) + 1.0, 0.0)
    nz = (mag > 0).astype(jnp.float32)
    # run-length overhead: ~ one terminator + per-nonzero position cost
    run_bits = 4.0 + 2.0 * nz.sum(-1)
    return coef_bits.sum(-1) + run_bits


def stream_bytes(q: jnp.ndarray) -> float:
    """Total estimated bytes for a tensor of quantized blocks."""
    bits = block_bits(q)
    return float(jnp.sum(bits)) / 8.0 + 64.0  # + tiny header


def stream_bytes_np(q: np.ndarray) -> float:
    """Numpy fast path of ``stream_bytes`` (same model, no tracing)."""
    flat = q.reshape(-1, 64).astype(np.float32)
    zz = flat[:, _zigzag_order()]
    mag = np.abs(zz)
    coef_bits = np.where(mag > 0, 2.0 * np.log2(mag + 1.0) + 1.0, 0.0)
    nz = (mag > 0).sum(axis=-1).astype(np.float32)
    run_bits = 4.0 + 2.0 * nz
    return float(coef_bits.sum() + run_bits.sum()) / 8.0 + 64.0
