"""PSNR quality metric (luma, 8-bit range) — the paper's Fig. 6(b) metric."""
from __future__ import annotations

import numpy as np


def psnr(ref: np.ndarray, test: np.ndarray, peak: float = 255.0) -> float:
    ref = np.asarray(ref, dtype=np.float64)
    test = np.asarray(test, dtype=np.float64)
    mse = np.mean((ref - test) ** 2)
    if mse <= 1e-12:
        return 99.0
    return float(10.0 * np.log10(peak * peak / mse))
