"""Tile encode/decode: GOP-structured transform coding.

Keyframes (first frame of each GOP) are intra-coded (DCT + quant of pixels);
the rest are P-frames coding the residual against the previous *reconstructed*
frame (closed-loop, like a real encoder, so decode drift is zero).  A tile is
an independently decodable unit: encoding never references pixels outside the
tile — exactly the HEVC tile property TASM exploits.

The reference implementation is numpy: tile shapes vary per layout, so a jit
cache would recompile per shape (retiling would pay seconds of XLA compile
per tile).  The MXU-shaped jnp/Pallas implementations live in
``repro/codec/transform.py`` and ``repro/kernels/*`` and are validated
against this path; decode cost remains proportional to (pixels, tiles) on
both, which is what the calibrated cost model captures.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.codec import bitstream
from repro.codec.quant import quant_matrix
from repro.codec.transform import dct_matrix


@dataclass(frozen=True)
class EncoderConfig:
    gop: int = 16          # frames per GOP (keyframe interval)
    qp: int = 8            # quantization level (~42dB on the synthetic corpus)
    block: int = 8


# --------------------------------------------------------------------------
# numpy blockwise DCT helpers
# --------------------------------------------------------------------------
def _to_blocks(frame: np.ndarray, b: int = 8) -> np.ndarray:
    h, w = frame.shape
    x = frame.reshape(h // b, b, w // b, b).swapaxes(1, 2)
    return x.reshape(-1, b, b)


def _from_blocks(blocks: np.ndarray, h: int, w: int, b: int = 8) -> np.ndarray:
    x = blocks.reshape(h // b, w // b, b, b).swapaxes(1, 2)
    return x.reshape(h, w)


def _dct2(blocks: np.ndarray) -> np.ndarray:
    d = dct_matrix()
    return np.einsum("ij,njk,lk->nil", d, blocks, d, optimize=True)


def _idct2(coeffs: np.ndarray) -> np.ndarray:
    d = dct_matrix()
    return np.einsum("ji,njk,kl->nil", d, coeffs, d, optimize=True)


def _q(coeffs: np.ndarray, qp: int, intra: bool) -> np.ndarray:
    m = quant_matrix(qp, intra)
    return np.round(coeffs / m).astype(np.int16)


def _dq(q: np.ndarray, qp: int, intra: bool) -> np.ndarray:
    return q.astype(np.float32) * quant_matrix(qp, intra)


# --------------------------------------------------------------------------
# Tile encode / decode
# --------------------------------------------------------------------------
def encode_tile(frames: np.ndarray, cfg: EncoderConfig) -> dict:
    """frames: [T, h, w] float32 in [0, 255]; T must be a multiple of gop."""
    t, h, w = frames.shape
    assert t % cfg.gop == 0, (t, cfg.gop)
    assert h % cfg.block == 0 and w % cfg.block == 0, (h, w)
    n_gops = t // cfg.gop
    nb = (h // cfg.block) * (w // cfg.block)
    kq = np.empty((n_gops, nb, 8, 8), dtype=np.int16)
    pq = np.empty((n_gops, cfg.gop - 1, nb, 8, 8), dtype=np.int16)
    for g in range(n_gops):
        f0 = g * cfg.gop
        kq[g] = _q(_dct2(_to_blocks(frames[f0].astype(np.float32))), cfg.qp, True)
        recon = _from_blocks(_idct2(_dq(kq[g], cfg.qp, True)), h, w)
        for i in range(1, cfg.gop):
            resid = frames[f0 + i].astype(np.float32) - recon
            q = _q(_dct2(_to_blocks(resid)), cfg.qp, False)
            pq[g, i - 1] = q
            recon = recon + _from_blocks(_idct2(_dq(q, cfg.qp, False)), h, w)
    size = bitstream.stream_bytes_np(kq) + bitstream.stream_bytes_np(pq)
    return {"kq": kq, "pq": pq, "h": h, "w": w, "gop": cfg.gop, "qp": cfg.qp,
            "size_bytes": float(size), "n_frames": t}


def decode_tile(enc: dict, gop_indices=None,
                frames_within: int | None = None,
                blocks=None) -> np.ndarray:
    """Decode (a subset of GOPs of) an encoded tile -> [T', h, w] float32.

    P-frame residuals are independent given the keyframe, so the whole GOP's
    dequant+IDCT runs as ONE batched einsum followed by a cumulative sum over
    frames — this collapses per-frame call overhead (the gamma term of the
    cost model) by ~8x vs a sequential loop and mirrors how the Pallas decode
    kernel batches blocks on TPU.

    ``frames_within``: decode only the first n frames of each selected GOP
    (temporal random access stops at the last requested frame — a decoder
    never needs the rest of the GOP).  Fixes long-SOT overdecode in Fig. 9.

    ``blocks``: ROI-restricted decode — only the given (tile-local,
    row-major) 8x8-block indices are dequantized, transformed and summed;
    the rest of the output stays zero.  The codec has no intra-block
    prediction, so each selected block's pixels are bit-identical to the
    same block of a full decode (dequant+IDCT+cumsum all operate per
    block).  Work becomes proportional to ``len(blocks)``, not tile area.
    ``blocks=None`` is the full-tile path, unchanged.
    """
    h, w, gop, qp = enc["h"], enc["w"], enc["gop"], enc["qp"]
    n_gops = len(enc["kq"])
    idx = list(range(n_gops)) if gop_indices is None else list(gop_indices)
    n = gop if frames_within is None else max(1, min(frames_within, gop))
    d = dct_matrix()
    m_k = quant_matrix(qp, True)
    m_p = quant_matrix(qp, False)
    if blocks is not None:
        bsel = np.asarray(sorted(set(blocks)), dtype=np.intp)
        out = np.zeros((len(idx) * n, h, w), dtype=np.float32)
        if bsel.size == 0:
            return out
        rs, cs = np.divmod(bsel, w // 8)
        # writable block view of the output canvas: [T', h/8, 8, w/8, 8]
        view = out.reshape(len(idx) * n, h // 8, 8, w // 8, 8)
        for j, g in enumerate(idx):
            key = _idct2(enc["kq"][g][bsel].astype(np.float32) * m_k)
            pq = enc["pq"][g][: n - 1][:, bsel]  # [n-1, nb_sel, 8, 8]
            coeffs = pq.astype(np.float32) * m_p
            resid = np.einsum("ji,fnjk,kl->fnil", d, coeffs, d, optimize=True)
            frames = np.concatenate([key[None], resid], axis=0)
            np.cumsum(frames, axis=0, out=frames)  # [n, nb_sel, 8, 8]
            # advanced indices on axes 1 and 3 land first: [nb_sel, n, 8, 8]
            view[j * n:(j + 1) * n][:, rs, :, cs] = \
                frames.transpose(1, 0, 2, 3)
        return out
    out = np.empty((len(idx) * n, h, w), dtype=np.float32)
    for j, g in enumerate(idx):
        key = _from_blocks(_idct2(enc["kq"][g].astype(np.float32) * m_k), h, w)
        pq = enc["pq"][g][: n - 1]  # [n-1, nb, 8, 8]
        coeffs = pq.astype(np.float32) * m_p
        resid = np.einsum("ji,fnjk,kl->fnil", d, coeffs, d, optimize=True)
        resid = resid.reshape(n - 1, h // 8, w // 8, 8, 8)
        resid = resid.swapaxes(2, 3).reshape(n - 1, h, w)
        frames = np.concatenate([key[None], resid], axis=0)
        np.cumsum(frames, axis=0, out=frames)
        out[j * n:(j + 1) * n] = frames
    return out


def encoded_size_bytes(enc: dict) -> float:
    return enc["size_bytes"]
