"""Blockwise 8x8 DCT-II transform, jnp reference implementation.

The TPU hot path lives in ``repro/kernels/dct`` (Pallas); this module is the
numerical ground truth used by the codec and as the kernels' ref oracle.
The 8x8 DCT is expressed as two small constant matmuls per block
(``D @ X @ D.T``) so even the reference path is MXU-shaped.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 8


@functools.lru_cache(maxsize=None)
def dct_matrix(n: int = BLOCK) -> np.ndarray:
    """Orthonormal DCT-II basis matrix [n, n] (float32)."""
    k = np.arange(n)[:, None]
    i = np.arange(n)[None, :]
    m = np.sqrt(2.0 / n) * np.cos(np.pi * (2 * i + 1) * k / (2 * n))
    m[0] = np.sqrt(1.0 / n)
    return m.astype(np.float32)


def to_blocks(frame: jnp.ndarray, block: int = BLOCK) -> jnp.ndarray:
    """[H, W] -> [H/b * W/b, b, b] row-major blocks.  H, W must divide b."""
    h, w = frame.shape[-2:]
    lead = frame.shape[:-2]
    nb_h, nb_w = h // block, w // block
    x = frame.reshape(lead + (nb_h, block, nb_w, block))
    x = jnp.swapaxes(x, -3, -2)
    return x.reshape(lead + (nb_h * nb_w, block, block))


def from_blocks(blocks: jnp.ndarray, h: int, w: int, block: int = BLOCK) -> jnp.ndarray:
    nb_h, nb_w = h // block, w // block
    lead = blocks.shape[:-3]
    x = blocks.reshape(lead + (nb_h, nb_w, block, block))
    x = jnp.swapaxes(x, -3, -2)
    return x.reshape(lead + (h, w))


def dct2_blocks(blocks: jnp.ndarray) -> jnp.ndarray:
    """2D DCT per block: [..., 8, 8] -> [..., 8, 8]."""
    d = jnp.asarray(dct_matrix())
    return jnp.einsum("ij,...jk,lk->...il", d, blocks.astype(jnp.float32), d)


def idct2_blocks(coeffs: jnp.ndarray) -> jnp.ndarray:
    d = jnp.asarray(dct_matrix())
    return jnp.einsum("ji,...jk,kl->...il", d, coeffs.astype(jnp.float32), d)
