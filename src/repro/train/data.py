"""Host-side data pipeline with straggler mitigation.

A background-threaded prefetcher keeps a bounded queue of ready batches; a
per-batch deadline implements *skip-and-backfill*: if the upstream source
stalls (straggling storage / preprocessing shard), the pipeline substitutes
the most recent ready batch instead of blocking the whole step, and the
skipped batch is consumed later (bounded staleness, counted in stats).

Sources: a synthetic token stream (training examples), and a TASM-backed
stream that decodes tile regions as VLM training crops — the storage manager
feeding the training framework (paper Fig. 2 wired end-to-end).
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Iterator, Optional

import numpy as np


@dataclass
class PrefetchStats:
    produced: int = 0
    consumed: int = 0
    stall_substitutions: int = 0
    max_wait_s: float = 0.0


class PrefetchPipeline:
    """Bounded prefetch + deadline-based straggler substitution."""

    def __init__(self, source: Iterator, *, depth: int = 4,
                 deadline_s: float = 1.0):
        self._source = source
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._deadline = deadline_s
        self._last: Optional[object] = None
        self._done = threading.Event()
        self.stats = PrefetchStats()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        try:
            for item in self._source:
                if self._done.is_set():
                    return
                self._q.put(item)
                self.stats.produced += 1
        finally:
            self._q.put(StopIteration)

    def __iter__(self):
        return self

    def __next__(self):
        t0 = time.perf_counter()
        try:
            item = self._q.get(timeout=self._deadline)
        except queue.Empty:
            # straggler: substitute the last ready batch rather than stall
            if self._last is None:
                item = self._q.get()  # nothing to substitute yet: block
            else:
                self.stats.stall_substitutions += 1
                item = self._last
        self.stats.max_wait_s = max(self.stats.max_wait_s,
                                    time.perf_counter() - t0)
        if item is StopIteration:
            raise StopIteration
        self._last = item
        self.stats.consumed += 1
        return item

    def close(self):
        self._done.set()


def synthetic_token_batches(vocab: int, batch: int, seq: int, *,
                            seed: int = 0, n_batches: Optional[int] = None,
                            structured: bool = True):
    """Seeded LM token stream: targets are inputs shifted by one.

    structured=True emits learnable arithmetic sequences (token_{i+1} =
    token_i + stride mod vocab, random start/stride) so example training
    loss demonstrably falls; structured=False is uniform noise (entropy
    floor log(vocab) — useful for throughput-only runs).
    """
    rng = np.random.default_rng(seed)
    i = 0
    while n_batches is None or i < n_batches:
        if structured:
            start = rng.integers(0, vocab, size=(batch, 1))
            stride = rng.integers(1, 4, size=(batch, 1))
            idx = np.arange(seq + 1)[None, :]
            toks = ((start + stride * idx) % vocab).astype(np.int32)
        else:
            toks = rng.integers(0, vocab, size=(batch, seq + 1), dtype=np.int32)
        yield {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        i += 1


def tasm_region_batches(source, labels, *, batch: int, crop: int = 32,
                        frame_step: int = 16, seed: int = 0,
                        video: Optional[str] = None):
    """Stream fixed-size crops of storage-manager object regions (VLM fuel).

    ``source`` is a ``VideoStore`` (pass ``video=``; defaults to the only
    catalog entry) or a legacy ``TASM`` facade.  Each batch:
    {'pixels': [B, crop, crop] float32, 'labels': [B] int32}.
    """
    rng = np.random.default_rng(seed)
    label_ids = {l: i for i, l in enumerate(sorted(labels))}
    if hasattr(source, "add_video"):  # VideoStore engine
        name = video or source.videos()[0]
        store = source.video(name).store

        def scan(label, t_range):
            return (source.scan(name).labels(label)
                    .frames(*t_range).execute())
    else:  # deprecated TASM shim
        store, scan = source.store, source.scan
    n_frames = store.sots[-1].frame_end if store.sots else 0
    while True:
        pixels, ys = [], []
        while len(pixels) < batch:
            f0 = int(rng.integers(0, max(n_frames - frame_step, 1)))
            label = sorted(labels)[int(rng.integers(0, len(labels)))]
            res = scan(label, (f0, f0 + frame_step))
            for _, _, px in res.regions:
                if min(px.shape) < 8:
                    continue
                out = np.zeros((crop, crop), np.float32)
                h, w = min(crop, px.shape[0]), min(crop, px.shape[1])
                out[:h, :w] = px[:h, :w]
                pixels.append(out)
                ys.append(label_ids[label])
                if len(pixels) >= batch:
                    break
        yield {"pixels": np.stack(pixels), "labels": np.asarray(ys, np.int32)}
