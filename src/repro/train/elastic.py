"""Elastic / fault-tolerant training loop.

The recoverable loop wraps a train step with:
- periodic (async) checkpointing via :class:`CheckpointManager`;
- crash recovery: on any step failure, restore the latest checkpoint and
  continue (the failure hook is injectable so tests can simulate dying
  nodes);
- elastic re-meshing: ``reshard_state`` re-device_puts a state tree onto a
  *different* mesh (fewer/more healthy devices) using the same logical rules,
  which is how a 1000-node job continues after losing a slice.

Straggler mitigation lives in repro/train/data.py (prefetch + deadline
skip-and-backfill); at the step level, synchronous SPMD means stragglers are
absorbed by the collective schedule — the knobs we expose are microbatch
resharding and checkpoint-restart onto a smaller mesh.
"""
from __future__ import annotations

import logging
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax

from repro.train.checkpoint import CheckpointManager

log = logging.getLogger(__name__)


@dataclass
class LoopConfig:
    total_steps: int
    checkpoint_every: int = 50
    checkpoint_async: bool = True
    max_restarts: int = 3


def reshard_state(state: Any, shardings: Any) -> Any:
    """device_put a state tree onto (possibly different) target shardings."""
    return jax.tree.map(
        lambda x, s: jax.device_put(jax.device_get(x), s), state, shardings)


def recoverable_train_loop(state, batch_iter, step_fn: Callable, *,
                           ckpt: CheckpointManager, cfg: LoopConfig,
                           start_step: int = 0,
                           fault_hook: Optional[Callable[[int], None]] = None,
                           on_metrics: Optional[Callable] = None):
    """Runs step_fn(state, batch) -> (state, metrics) with checkpoint/restart.

    Returns (final_state, steps_run, restarts)."""
    step = start_step
    restarts = 0
    while step < cfg.total_steps:
        try:
            if fault_hook is not None:
                fault_hook(step)  # tests raise here to simulate node loss
            batch = next(batch_iter)
            state, metrics = step_fn(state, batch)
            step += 1
            if on_metrics is not None:
                on_metrics(step, metrics)
            if step % cfg.checkpoint_every == 0 or step == cfg.total_steps:
                if cfg.checkpoint_async:
                    ckpt.save_async(step, state, extra={"step": step})
                else:
                    ckpt.save(step, state, extra={"step": step})
        except (StopIteration,):
            break
        except Exception as e:  # noqa: BLE001 - the recovery path
            restarts += 1
            log.warning("step %d failed (%s); restart %d", step, e, restarts)
            if restarts > cfg.max_restarts:
                raise
            ckpt.wait()
            latest = ckpt.latest_step()
            if latest is not None:
                state, extra = ckpt.restore(state)
                step = extra.get("step", latest)
            # else: restart from the initial state
    ckpt.wait()
    return state, step, restarts
