"""AdamW with decoupled weight decay and global-norm clipping (built here —
no optax dependency).  Optimizer state shards exactly like the params (the
moments inherit the FSDP+TP PartitionSpecs), giving ZeRO-style sharded state.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def init_opt_state(params, *, master: bool = None) -> dict:
    """master=True (auto when params are sub-fp32) keeps an fp32 master copy
    in the optimizer state — params can then live/gather in bf16 while the
    update math stays fp32 (SS Perf: halves FSDP gather + grad-sync bytes)."""
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    if master is None:
        master = any(x.dtype != jnp.float32 for x in jax.tree.leaves(params))
    state = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if master:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def lr_schedule(cfg: AdamWConfig, step):
    """Linear warmup then cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * (step + 1.0) / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    masters = opt_state.get("master", params)

    def upd(p, w, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        step_delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
            step_delta = step_delta + cfg.weight_decay * w.astype(jnp.float32)
        new_w = w.astype(jnp.float32) - lr * step_delta
        return new_w.astype(p.dtype), new_w, m, v

    out = jax.tree.map(upd, params, masters, grads, opt_state["m"],
                       opt_state["v"])
    flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = jax.tree.unflatten(treedef, [t[0] for t in flat])
    new_w = jax.tree.unflatten(treedef, [t[1] for t in flat])
    new_m = jax.tree.unflatten(treedef, [t[2] for t in flat])
    new_v = jax.tree.unflatten(treedef, [t[3] for t in flat])
    new_state = {"m": new_m, "v": new_v, "step": step}
    if "master" in opt_state:
        new_state["master"] = new_w
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
