"""Fault-tolerant checkpointing: atomic, sharded, resumable, elastic.

Layout (one directory per step):

    <root>/step_000123/
        manifest.json        # step, config hash, tree structure, leaf shapes
        shard_<i>.npz        # leaf arrays (host-gathered)
    <root>/LATEST            # atomically-renamed pointer file

Writes go to ``step_<n>.tmp`` and are renamed only after every shard and the
manifest are fsynced — a crash mid-save can never corrupt the latest
checkpoint (restart restores the previous one).  ``restore`` device_puts each
leaf with the *target* sharding, so a checkpoint written on N devices
restores onto M != N (elastic resharding: scale-down after node loss, or
scale-up).  An async mode hands the host-transfer + write to a worker thread
so training overlaps the I/O.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.utils.tree import flatten_names


def _tree_structure_fingerprint(tree: Any) -> str:
    names = [n for n, _ in flatten_names(tree)]
    return hashlib.sha256("|".join(names).encode()).hexdigest()[:16]


class CheckpointManager:
    def __init__(self, root: str, *, keep: int = 3):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._async_thread: Optional[threading.Thread] = None
        self._async_error: Optional[BaseException] = None

    # ---------------------------------------------------------------- save
    def save(self, step: int, tree: Any, *, extra: Optional[dict] = None,
             leaves_per_shard: int = 64) -> pathlib.Path:
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        return self._write(step, host_tree, extra or {}, leaves_per_shard)

    def save_async(self, step: int, tree: Any, *, extra: Optional[dict] = None
                   ) -> None:
        """Snapshot to host memory synchronously, write in a worker thread."""
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                self._write(step, host_tree, extra or {}, 64)
            except BaseException as e:  # noqa: BLE001
                self._async_error = e

        self._async_thread = threading.Thread(target=work, daemon=True)
        self._async_thread.start()

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None
        if self._async_error is not None:
            err, self._async_error = self._async_error, None
            raise err

    def _write(self, step: int, host_tree, extra: dict,
               leaves_per_shard: int) -> pathlib.Path:
        final = self.root / f"step_{step:09d}"
        tmp = self.root / f"step_{step:09d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = flatten_names(host_tree)
        shards = [flat[i:i + leaves_per_shard]
                  for i in range(0, len(flat), leaves_per_shard)]
        manifest = {
            "step": step,
            "extra": extra,
            "fingerprint": _tree_structure_fingerprint(host_tree),
            "time": time.time(),
            "leaves": {},
            "n_shards": len(shards),
        }
        for i, shard in enumerate(shards):
            arrays = {}
            for j, (name, leaf) in enumerate(shard):
                key = f"a{j}"
                arrays[key] = leaf
                manifest["leaves"][name] = {
                    "shard": i, "key": key, "shape": list(leaf.shape),
                    "dtype": str(leaf.dtype),
                }
            path = tmp / f"shard_{i}.npz"
            with open(path, "wb") as f:
                np.savez(f, **arrays)
                f.flush()
                os.fsync(f.fileno())
        mpath = tmp / "manifest.json"
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish
        self._update_latest(final.name)
        self._gc()
        return final

    def _update_latest(self, name: str) -> None:
        tmp = self.root / "LATEST.tmp"
        tmp.write_text(name)
        tmp.rename(self.root / "LATEST")

    def _gc(self) -> None:
        steps = sorted(self.list_steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.root / f"step_{s:09d}", ignore_errors=True)

    # -------------------------------------------------------------- restore
    def list_steps(self) -> list[int]:
        out = []
        for p in self.root.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        ptr = self.root / "LATEST"
        if ptr.exists():
            name = ptr.read_text().strip()
            p = self.root / name
            if (p / "manifest.json").exists():
                return int(name.split("_")[1])
        steps = self.list_steps()  # fall back to a directory scan
        return steps[-1] if steps else None

    def restore(self, target_tree: Any, *, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``target_tree``; device_put each
        leaf with ``shardings`` (same tree structure) when given — this is
        what makes restores elastic across device counts."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.root}")
        d = self.root / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        if manifest["fingerprint"] != _tree_structure_fingerprint(target_tree):
            raise ValueError("checkpoint tree structure mismatch")
        cache: dict[int, Any] = {}

        flat_target = flatten_names(target_tree)
        flat_shard = flatten_names(shardings) if shardings is not None else None
        leaves = []
        for idx, (name, leaf) in enumerate(flat_target):
            info = manifest["leaves"][name]
            if info["shard"] not in cache:
                cache[info["shard"]] = np.load(d / f"shard_{info['shard']}.npz")
            arr = cache[info["shard"]][info["key"]]
            if list(arr.shape) != list(leaf.shape):
                raise ValueError(f"shape mismatch for {name}")
            if flat_shard is not None:
                arr = jax.device_put(arr, flat_shard[idx][1])
            leaves.append(arr)
        treedef = jax.tree.structure(target_tree)
        return jax.tree.unflatten(treedef, leaves), manifest["extra"]
