"""Train-step factory: loss + grad + AdamW, with optional microbatch
gradient accumulation (lax.scan over micro-slices, fp32 accumulators) and
optional int8 error-feedback gradient compression for the DP all-reduce.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import zoo
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def make_train_step(cfg: ArchConfig, opt_cfg: Optional[AdamWConfig] = None, *,
                    microbatches: int = 1, remat: bool = True):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""
    opt_cfg = opt_cfg or AdamWConfig()

    def loss_of(params, batch):
        loss, metrics = zoo.loss_fn(params, cfg, batch, remat=remat)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def accumulated(params, batch):
        def slice_micro(x, i):
            mb = x.shape[0] // microbatches
            return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

        def body(carry, i):
            acc, loss_acc = carry
            micro = jax.tree.map(lambda x: slice_micro(x, i), batch)
            (loss, _), grads = grad_fn(params, micro)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / microbatches, acc, grads)
            return (acc, loss_acc + loss / microbatches), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss), _ = jax.lax.scan(
            body, (zero, jnp.float32(0.0)), jnp.arange(microbatches))
        return loss, {"loss": loss}, grads

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            loss, metrics, grads = accumulated(params, batch)
        else:
            loss, metrics, grads = single(params, batch)
        params, opt_state, opt_metrics = adamw_update(grads, opt_state, params, opt_cfg)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ArchConfig, *, remat: bool = False):
    def eval_step(params, batch):
        loss, metrics = zoo.loss_fn(params, cfg, batch, remat=remat)
        return metrics

    return eval_step


__all__ = ["make_train_step", "make_eval_step", "init_opt_state", "AdamWConfig"]
