"""Per-layer transformer/SSM blocks with a uniform (params, h, aux) interface
so each family lowers to a single lax.scan over stacked layer params."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.ctx import constrain
from repro.models import attention as attn_mod
from repro.models.attention import attention_apply, init_attention, init_mla_attention, mla_apply
from repro.models.layers import init_mlp, init_norm, mlp_apply, norm_apply
from repro.models.moe import init_moe, moe_apply
from repro.models.ssm import (init_mamba1, init_mamba2, mamba1_apply, mamba2_apply)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def init_block(key, cfg: ArchConfig, kind: str) -> dict:
    """kind: dense | moe | ssm1 | ssm2 | enc | dec (cross-attn decoder)."""
    keys = jax.random.split(key, 4)
    dt = cfg.param_dtype
    n = lambda: init_norm(cfg.norm, cfg.d_model, dtype=dt)
    if kind == "dense":
        attn_init = init_mla_attention if cfg.mla is not None else init_attention
        return {"ln1": n(), "attn": attn_init(keys[0], cfg), "ln2": n(),
                "mlp": init_mlp(keys[1], cfg.d_model, cfg.d_ff, dtype=dt)}
    if kind == "moe":
        attn_init = init_mla_attention if cfg.mla is not None else init_attention
        return {"ln1": n(), "attn": attn_init(keys[0], cfg), "ln2": n(),
                "moe": init_moe(keys[1], cfg)}
    if kind == "ssm1":
        return {"ln1": n(), "mamba": init_mamba1(keys[0], cfg)}
    if kind == "ssm2":
        return {"ln1": n(), "mamba": init_mamba2(keys[0], cfg)}
    if kind == "enc":
        return {"ln1": n(), "attn": init_attention(keys[0], cfg), "ln2": n(),
                "mlp": init_mlp(keys[1], cfg.d_model, cfg.d_ff, dtype=dt)}
    if kind == "dec":
        return {"ln1": n(), "attn": init_attention(keys[0], cfg),
                "ln_x": n(), "cross": init_attention(keys[1], cfg), "ln2": n(),
                "mlp": init_mlp(keys[2], cfg.d_model, cfg.d_ff, dtype=dt)}
    raise ValueError(kind)


# --------------------------------------------------------------------------
# apply
# --------------------------------------------------------------------------
def block_apply(p: dict, h: jnp.ndarray, cfg: ArchConfig, kind: str, *,
                positions=None, cache: Optional[dict] = None, cache_index=None,
                cache_len=None, enc_out=None, causal: bool = True):
    """Returns (h, new_cache_or_None).

    Megatron-SP dataflow (§Perf iteration 1): the residual stream h stays
    SEQUENCE-SHARDED over the TP axis end to end; each sub-block's
    *contribution* is constrained back to the sequence-sharded layout BEFORE
    the residual add, so GSPMD lowers the TP combine as a bf16
    reduce-scatter instead of a full all-reduce (2x the bytes) followed by a
    slice.  The constraint auto-drops when S doesn't divide the axis (e.g.
    decode S=1).
    """
    seq = lambda x: constrain(x, "batch", "seq_shard", None)
    h = seq(h)
    new_cache = None
    if kind in ("dense", "moe", "enc", "dec"):
        hn = norm_apply(cfg.norm, p["ln1"], h)
        attn_fn = mla_apply if cfg.mla is not None else attention_apply
        self_cache = cache.get("self") if isinstance(cache, dict) and "self" in cache else cache
        a, upd = attn_fn(p["attn"], hn, cfg, causal=causal, positions=positions,
                         kv_cache=self_cache, cache_index=cache_index,
                         cache_len=cache_len)
        h = h + seq(a)
        if kind == "dec":
            # cross attention over encoder outputs (enc_out is precomputed and
            # static across decode steps, so it is not cached)
            hn = norm_apply(cfg.norm, p["ln_x"], h)
            x, _ = _cross_attention(p["cross"], hn, enc_out, cfg)
            h = h + seq(x)
        hn = norm_apply(cfg.norm, p["ln2"], h)
        if kind == "moe":
            f = moe_apply(p["moe"], hn, cfg)
        else:
            f = mlp_apply(p["mlp"], hn, cfg.compute_dtype)
        h = h + seq(f)
        if cache is not None:
            new_cache = {"self": upd} if isinstance(cache, dict) and "self" in cache else upd
    elif kind in ("ssm1", "ssm2"):
        hn = norm_apply(cfg.norm, p["ln1"], h)
        fn = mamba1_apply if kind == "ssm1" else mamba2_apply
        y, new_cache = fn(p["mamba"], hn, cfg, state=cache)
        h = h + seq(y)
    else:
        raise ValueError(kind)
    return h, new_cache


def _cross_attention(p: dict, x: jnp.ndarray, enc_out: jnp.ndarray, cfg: ArchConfig):
    """Decoder cross-attention: queries from x, keys/values from enc_out."""
    import numpy as np

    from repro.models.layers import dense_apply

    B, S, _ = x.shape
    Se = enc_out.shape[1]
    h_, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = h_ // kvh
    cd = cfg.compute_dtype
    q = dense_apply(p["wq"], x, cd).reshape(B, S, kvh, G, hd)
    k = dense_apply(p["wk"], enc_out, cd).reshape(B, Se, kvh, hd)
    v = dense_apply(p["wv"], enc_out, cd).reshape(B, Se, kvh, hd)
    out = attn_mod.grouped_attention(
        q, k, v, causal=False, q_pos=jnp.arange(S), kv_pos=jnp.arange(Se),
        impl="chunked" if S > 1 else "naive", q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    out = out.reshape(B, S, h_ * hd)
    return dense_apply(p["wo"], out, cd), None
