"""Model assembly: init, train forward/loss, KV-cache decode — per family.

Public API (used by launch/train/serve/dryrun):

    params        = init_model(cfg, key)
    loss, metrics = loss_fn(params, cfg, batch)             # train/prefill
    caches        = init_cache_specs(cfg, batch, max_len)   # ShapeDtypeStructs
    logits, cache = decode_step(params, cfg, batch, cache)  # one token
    specs         = input_specs(cfg, shape)                 # dry-run stand-ins

All families lower their layer stack through lax.scan over stacked layer
params (HLO stays O(1) in depth).  Special layers sit outside the scan:
DeepSeek's leading dense layer, and Zamba2's shared attention block — the
hybrid stack is segmented as [every-layers scan → shared attn] × n_sites so
the shared block's KV cache is stacked only over its n_sites call sites.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec
from repro.distributed.ctx import constrain
from repro.models.attention import attention_apply, init_attention
from repro.models.blocks import block_apply, init_block
from repro.models.layers import (dense_apply, embedding_apply, init_dense,
                                 init_embedding, init_mlp, init_norm,
                                 mlp_apply, norm_apply)
from repro.models.ssm import mamba1_state_specs, mamba2_state_specs
from repro.utils.tree import tree_param_count


# ==========================================================================
# Family layout
# ==========================================================================
def _family_block_kind(cfg: ArchConfig) -> str:
    if cfg.family in ("ssm", "hybrid"):
        return "ssm1" if cfg.ssm.kind == "mamba1" else "ssm2"
    if cfg.family == "moe":
        return "moe"
    return "dense"  # dense | vlm backbone; audio handled separately


def _stacked_init(key, n: int, one_init):
    keys = jax.random.split(key, n)
    return jax.vmap(one_init)(keys)


def _wide_cfg(cfg: ArchConfig) -> ArchConfig:
    """Zamba2 shared block runs at 2*d_model."""
    d2 = 2 * cfg.d_model
    return dataclasses.replace(cfg, d_model=d2, head_dim=d2 // cfg.n_heads)


def _hybrid_sites(cfg: ArchConfig) -> tuple[int, int]:
    every = cfg.hybrid.shared_attn_every
    n_sites = cfg.n_layers // every
    trailing = cfg.n_layers - n_sites * every
    return n_sites, trailing


# ==========================================================================
# init
# ==========================================================================
def init_model(cfg: ArchConfig, key) -> dict:
    keys = jax.random.split(key, 12)
    dt = cfg.param_dtype
    params: dict[str, Any] = {
        "embed": init_embedding(keys[0], cfg.vocab, cfg.d_model, dtype=dt),
        "final_norm": init_norm(cfg.norm, cfg.d_model, dtype=dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(keys[1], cfg.d_model, cfg.vocab, dtype=dt)

    if cfg.is_encdec:
        params["enc_layers"] = _stacked_init(
            keys[2], cfg.enc_layers, lambda k: init_block(k, cfg, "enc"))
        params["enc_norm"] = init_norm(cfg.norm, cfg.d_model, dtype=dt)
        params["dec_layers"] = _stacked_init(
            keys[3], cfg.n_layers, lambda k: init_block(k, cfg, "dec"))
        return params

    kind = _family_block_kind(cfg)
    n_scanned = cfg.n_layers
    if cfg.moe is not None and cfg.moe.first_dense_layers:
        dense_cfg = dataclasses.replace(cfg, d_ff=cfg.moe.d_first_dense_ff)
        params["dense_layers"] = _stacked_init(
            keys[4], cfg.moe.first_dense_layers,
            lambda k: init_block(k, dense_cfg, "dense"))
        n_scanned = cfg.n_layers - cfg.moe.first_dense_layers
    params["layers"] = _stacked_init(keys[5], n_scanned,
                                     lambda k: init_block(k, cfg, kind))
    if cfg.family == "hybrid":
        params["shared_attn"] = _init_shared_attn(keys[6], cfg)
    if cfg.frontend == "patch":
        k_a, k_b = jax.random.split(keys[7])
        params["projector"] = {
            "fc1": init_dense(k_a, cfg.frontend_dim, cfg.d_model, bias=True, dtype=dt),
            "fc2": init_dense(k_b, cfg.d_model, cfg.d_model, bias=True, dtype=dt),
        }
    return params


def _init_shared_attn(key, cfg: ArchConfig) -> dict:
    wide = _wide_cfg(cfg)
    d2 = wide.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_norm(cfg.norm, d2, dtype=cfg.param_dtype),
        "attn": init_attention(k1, wide),
        "ln2": init_norm(cfg.norm, d2, dtype=cfg.param_dtype),
        "mlp": init_mlp(k2, d2, cfg.d_ff, dtype=cfg.param_dtype),
        "out_proj": init_dense(k3, d2, cfg.d_model, dtype=cfg.param_dtype),
    }


def _shared_attn_apply(p: dict, h, emb0, cfg: ArchConfig, *, positions=None,
                       cache=None, cache_index=None, cache_len=None):
    wide = _wide_cfg(cfg)
    x = jnp.concatenate([h, emb0], axis=-1)
    xn = norm_apply(cfg.norm, p["ln1"], x)
    a, new_cache = attention_apply(p["attn"], xn, wide, causal=True,
                                   positions=positions, kv_cache=cache,
                                   cache_index=cache_index, cache_len=cache_len)
    x = x + a
    xn = norm_apply(cfg.norm, p["ln2"], x)
    x = x + mlp_apply(p["mlp"], xn, cfg.compute_dtype)
    return h + dense_apply(p["out_proj"], x, cfg.compute_dtype), new_cache


# ==========================================================================
# Layer-stack scan
# ==========================================================================
def _scan_layers(layers, h, cfg: ArchConfig, kind: str, *, positions=None,
                 caches=None, cache_index=None, cache_len=None, enc_out=None,
                 causal=True, remat: bool = True):
    """lax.scan over stacked layer params. Returns (h, new_caches)."""

    def body(h, xs):
        p, cache = xs
        h, new_cache = block_apply(p, h, cfg, kind, positions=positions,
                                   cache=cache, cache_index=cache_index,
                                   cache_len=cache_len, enc_out=enc_out,
                                   causal=causal)
        return h, new_cache

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, new_caches = jax.lax.scan(body, h, (layers, caches))
    return h, new_caches


# ==========================================================================
# Hybrid (Zamba2) stack: [every-layer scan -> shared attn] x n_sites + tail
# ==========================================================================
def _hybrid_stack(params: dict, cfg: ArchConfig, h, *, positions=None,
                  caches=None, cache_index=None, cache_len=None,
                  remat: bool = True):
    kind = _family_block_kind(cfg)
    every = cfg.hybrid.shared_attn_every
    n_sites, trailing = _hybrid_sites(cfg)
    layers = params["layers"]
    n_seg = n_sites * every

    seg = jax.tree.map(
        lambda x: x[:n_seg].reshape((n_sites, every) + x.shape[1:]), layers)
    tail = jax.tree.map(lambda x: x[n_seg:], layers) if trailing else None
    lc = caches["layers"] if caches is not None else None
    seg_c = (jax.tree.map(
        lambda x: x[:n_seg].reshape((n_sites, every) + x.shape[1:]), lc)
        if lc is not None else None)
    tail_c = (jax.tree.map(lambda x: x[n_seg:], lc)
              if (lc is not None and trailing) else None)
    sc = caches.get("shared") if caches is not None else None

    emb0 = h
    new_seg_c, new_shared_c = [], []
    for i in range(n_sites):
        seg_i = jax.tree.map(lambda x: x[i], seg)
        cache_i = jax.tree.map(lambda x: x[i], seg_c) if seg_c is not None else None
        h, nc = _scan_layers(seg_i, h, cfg, kind, positions=positions,
                             caches=cache_i, cache_index=cache_index,
                             cache_len=cache_len, remat=remat)
        sc_i = jax.tree.map(lambda x: x[i], sc) if sc is not None else None
        h, nsc = _shared_attn_apply(params["shared_attn"], h, emb0, cfg,
                                    positions=positions, cache=sc_i,
                                    cache_index=cache_index, cache_len=cache_len)
        if seg_c is not None:
            new_seg_c.append(nc)
        if sc is not None:
            new_shared_c.append(nsc)
    new_tail_c = None
    if trailing:
        h, new_tail_c = _scan_layers(tail, h, cfg, kind, positions=positions,
                                     caches=tail_c, cache_index=cache_index,
                                     cache_len=cache_len, remat=remat)
    new_caches = None
    if caches is not None:
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *new_seg_c)
        flat = jax.tree.map(
            lambda x: x.reshape((n_seg,) + x.shape[2:]), stacked)
        if trailing:
            flat = jax.tree.map(lambda a, b: jnp.concatenate([a, b]), flat,
                                new_tail_c)
        new_caches = {"layers": flat,
                      "shared": jax.tree.map(lambda *xs: jnp.stack(xs),
                                             *new_shared_c)}
    return h, new_caches


def _embed_inputs(params: dict, cfg: ArchConfig, batch: dict):
    """Token embedding (+ projected patch embeddings for VLM prefill)."""
    cd = cfg.compute_dtype
    h = embedding_apply(params["embed"], batch["tokens"], cd)
    if cfg.frontend == "patch" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(cd)
        pe = dense_apply(params["projector"]["fc1"], pe, cd)
        pe = dense_apply(params["projector"]["fc2"], jax.nn.gelu(pe), cd)
        h = jnp.concatenate([pe, h], axis=1)  # image tokens lead the sequence
    return constrain(h, "batch", None, None)


# ==========================================================================
# Train/prefill forward
# ==========================================================================
def forward(params: dict, cfg: ArchConfig, batch: dict, *, remat: bool = True):
    """Returns final hidden states [B, S, d] (final norm applied)."""
    cd = cfg.compute_dtype
    if cfg.is_encdec:
        enc_h = batch["frames"].astype(cd)  # stub frontend: frame embeddings
        enc_h = constrain(enc_h, "batch", None, None)
        enc_h, _ = _scan_layers(params["enc_layers"], enc_h, cfg, "enc",
                                positions=jnp.arange(enc_h.shape[1]),
                                causal=False, remat=remat)
        enc_out = norm_apply(cfg.norm, params["enc_norm"], enc_h)
        h = embedding_apply(params["embed"], batch["tokens"], cd)
        h = constrain(h, "batch", None, None)
        h, _ = _scan_layers(params["dec_layers"], h, cfg, "dec",
                            positions=jnp.arange(h.shape[1]),
                            enc_out=enc_out, causal=True, remat=remat)
        return norm_apply(cfg.norm, params["final_norm"], h)

    h = _embed_inputs(params, cfg, batch)
    positions = jnp.arange(h.shape[1])

    if cfg.moe is not None and cfg.moe.first_dense_layers:
        dense_cfg = dataclasses.replace(cfg, d_ff=cfg.moe.d_first_dense_ff)
        h, _ = _scan_layers(params["dense_layers"], h, dense_cfg, "dense",
                            positions=positions, remat=remat)

    if cfg.family == "hybrid":
        h, _ = _hybrid_stack(params, cfg, h, positions=positions, remat=remat)
    else:
        kind = _family_block_kind(cfg)
        h, _ = _scan_layers(params["layers"], h, cfg, kind,
                            positions=positions, remat=remat)
    return norm_apply(cfg.norm, params["final_norm"], h)


# ==========================================================================
# Loss (token-chunked cross-entropy; never materialises full [T, V] logits)
# ==========================================================================
def loss_fn(params: dict, cfg: ArchConfig, batch: dict, *, remat: bool = True):
    h = forward(params, cfg, batch, remat=remat)
    B, S, d = h.shape
    targets = batch["targets"]
    if cfg.frontend == "patch":
        n_img = S - targets.shape[1]  # image tokens carry no LM loss
        h = h[:, n_img:]
        S = h.shape[1]
    w = (params["embed"]["table"].T if cfg.tie_embeddings
         else params["lm_head"]["w"])  # [d, vocab]
    # Cast-then-gather: constrain the bf16 copy so the FSDP all-gather moves
    # 2-byte, not 4-byte, elements (SS Perf iteration: halves the lm_head
    # gather bytes).  'vocab' keeps the TP sharding; the fsdp axis is gone.
    w = constrain(w.astype(cfg.compute_dtype), None, "vocab")
    # Chunk the vocab projection over the SEQUENCE axis.  The chunk COUNT is
    # what matters for collectives: the lm_head gradient is all-reduced once
    # per scan trip, so chunks are sized from a per-chip logits-memory budget
    # (~256 MB) instead of a fixed token count (SS Perf iteration: 128 trips
    # -> 4-16, cutting the dominant train collective ~10x).
    from repro.distributed.ctx import current_mesh

    mesh = current_mesh()
    chips = 1.0
    if mesh is not None:
        import numpy as _np

        chips = float(_np.prod(list(mesh.shape.values())))
    logits_bytes = B * S * cfg.vocab * 4.0 / chips
    want = max(1, int(-(-logits_bytes // 256e6)))
    n_chunk = 1
    while n_chunk < want and n_chunk < S:
        n_chunk *= 2
    while S % n_chunk:
        n_chunk //= 2
    s_chunk = S // n_chunk

    def body(acc, i):
        hc = jax.lax.dynamic_slice_in_dim(h, i * s_chunk, s_chunk, axis=1)
        tc = jax.lax.dynamic_slice_in_dim(targets, i * s_chunk, s_chunk, axis=1)
        logits = hc.astype(cfg.compute_dtype) @ w
        logits = constrain(logits, "batch", None, "vocab").astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[:, :, None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    acc, _ = jax.lax.scan(body, jnp.float32(0.0), jnp.arange(n_chunk))
    T = B * S
    loss = acc / T
    return loss, {"loss": loss, "tokens": jnp.float32(T)}


def logits_fn(params: dict, cfg: ArchConfig, h_last: jnp.ndarray) -> jnp.ndarray:
    w = (params["embed"]["table"].T if cfg.tie_embeddings
         else params["lm_head"]["w"])  # [d, vocab]
    logits = h_last.astype(cfg.compute_dtype) @ w.astype(cfg.compute_dtype)
    return constrain(logits, "batch", None, "vocab").astype(jnp.float32)


# ==========================================================================
# KV caches + decode
# ==========================================================================
def _attn_cache_spec(cfg: ArchConfig, batch: int, max_len: int):
    cd = jnp.dtype(cfg.compute_dtype)
    if cfg.mla is not None:
        if cfg.kv_cache_quant:  # int8 latent + bf16 per-row scales (SS Perf)
            return {
                "c_kv": jax.ShapeDtypeStruct(
                    (batch, max_len, cfg.mla.kv_lora_rank), jnp.dtype(jnp.int8)),
                "c_kv_scale": jax.ShapeDtypeStruct(
                    (batch, max_len), jnp.dtype(jnp.bfloat16)),
                "k_rope": jax.ShapeDtypeStruct(
                    (batch, max_len, cfg.mla.qk_rope_head_dim), cd),
            }
        return {
            "c_kv": jax.ShapeDtypeStruct((batch, max_len, cfg.mla.kv_lora_rank), cd),
            "k_rope": jax.ShapeDtypeStruct((batch, max_len, cfg.mla.qk_rope_head_dim), cd),
        }
    kv_eff = cfg.n_kv_heads * cfg.kv_repeat
    if cfg.kv_cache_quant:  # int8 rows + bf16 per-row scales (SS Perf)
        i8 = jnp.dtype(jnp.int8)
        bf = jnp.dtype(jnp.bfloat16)
        return {
            "k": jax.ShapeDtypeStruct((batch, max_len, kv_eff, cfg.head_dim), i8),
            "v": jax.ShapeDtypeStruct((batch, max_len, kv_eff, cfg.head_dim), i8),
            "k_scale": jax.ShapeDtypeStruct((batch, max_len, kv_eff), bf),
            "v_scale": jax.ShapeDtypeStruct((batch, max_len, kv_eff), bf),
        }
    return {
        "k": jax.ShapeDtypeStruct((batch, max_len, kv_eff, cfg.head_dim), cd),
        "v": jax.ShapeDtypeStruct((batch, max_len, kv_eff, cfg.head_dim), cd),
    }


def init_cache_specs(cfg: ArchConfig, batch: int, max_len: int):
    """ShapeDtypeStruct pytree of the decode cache (stacked over layers)."""

    def stack(spec_tree, n):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), spec_tree)

    if cfg.is_encdec:
        return {"dec": stack(_attn_cache_spec(cfg, batch, max_len), cfg.n_layers)}

    caches: dict[str, Any] = {}
    kind = _family_block_kind(cfg)
    n_scanned = cfg.n_layers
    if cfg.moe is not None and cfg.moe.first_dense_layers:
        n_scanned -= cfg.moe.first_dense_layers
        caches["dense_layers"] = stack(_attn_cache_spec(cfg, batch, max_len),
                                       cfg.moe.first_dense_layers)
    if kind in ("dense", "moe"):
        caches["layers"] = stack(_attn_cache_spec(cfg, batch, max_len), n_scanned)
    elif kind == "ssm1":
        caches["layers"] = stack(mamba1_state_specs(cfg, batch), n_scanned)
    else:
        caches["layers"] = stack(mamba2_state_specs(cfg, batch), n_scanned)
    if cfg.family == "hybrid":
        n_sites, _ = _hybrid_sites(cfg)
        caches["shared"] = stack(_attn_cache_spec(_wide_cfg(cfg), batch, max_len),
                                 n_sites)
    return caches


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        init_cache_specs(cfg, batch, max_len))


def decode_step(params: dict, cfg: ArchConfig, batch: dict, caches, *,
                cache_index, enc_out=None):
    """One-token decode.  batch['tokens']: [B, 1].  Returns (logits, caches)."""
    cd = cfg.compute_dtype
    h = _embed_inputs(params, cfg, batch)
    S_in = h.shape[1]
    cache_len = cache_index + S_in
    positions = jnp.arange(S_in) + cache_index
    new_caches = dict(caches)

    if cfg.is_encdec:
        if enc_out is None:
            enc_out = batch["enc_out"].astype(cd)
        h, nc = _scan_layers(params["dec_layers"], h, cfg, "dec",
                             positions=positions, caches=caches["dec"],
                             cache_index=cache_index, cache_len=cache_len,
                             enc_out=enc_out, remat=False)
        new_caches["dec"] = nc
        h = norm_apply(cfg.norm, params["final_norm"], h)
        if S_in > 1:
            h = h[:, -1:]
        return logits_fn(params, cfg, h), new_caches

    if cfg.moe is not None and cfg.moe.first_dense_layers:
        dense_cfg = dataclasses.replace(cfg, d_ff=cfg.moe.d_first_dense_ff)
        h, nc = _scan_layers(params["dense_layers"], h, dense_cfg, "dense",
                             positions=positions, caches=caches["dense_layers"],
                             cache_index=cache_index, cache_len=cache_len,
                             remat=False)
        new_caches["dense_layers"] = nc

    if cfg.family == "hybrid":
        h, nc = _hybrid_stack(params, cfg, h, positions=positions, caches=caches,
                              cache_index=cache_index, cache_len=cache_len,
                              remat=False)
        new_caches.update(nc)
    else:
        kind = _family_block_kind(cfg)
        h, nc = _scan_layers(params["layers"], h, cfg, kind, positions=positions,
                             caches=caches["layers"], cache_index=cache_index,
                             cache_len=cache_len, remat=False)
        new_caches["layers"] = nc
    h = norm_apply(cfg.norm, params["final_norm"], h)
    if S_in > 1:  # prefill: only the last position's logits are needed
        h = h[:, -1:]
    return logits_fn(params, cfg, h), new_caches


def encode_frames(params: dict, cfg: ArchConfig, frames, *, remat: bool = False):
    """Run the encoder stack on (stub) frame embeddings -> enc_out."""
    cd = cfg.compute_dtype
    enc_h = frames.astype(cd)
    enc_h = constrain(enc_h, "batch", None, None)
    enc_h, _ = _scan_layers(params["enc_layers"], enc_h, cfg, "enc",
                            positions=jnp.arange(enc_h.shape[1]),
                            causal=False, remat=remat)
    return norm_apply(cfg.norm, params["enc_norm"], enc_h)


# ==========================================================================
# Dry-run input specs
# ==========================================================================
def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this shape cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.dtype("int32")
    f32 = jnp.dtype("float32")
    if shape.kind in ("train", "prefill"):
        train = shape.kind == "train"
        specs: dict[str, Any] = {}
        if cfg.frontend == "patch":
            n_img = min(cfg.frontend_tokens, S // 4)
            specs["patch_embeds"] = jax.ShapeDtypeStruct((B, n_img, cfg.frontend_dim), f32)
            specs["tokens"] = jax.ShapeDtypeStruct((B, S - n_img), i32)
            if train:
                specs["targets"] = jax.ShapeDtypeStruct((B, S - n_img), i32)
        elif cfg.is_encdec:
            specs["frames"] = jax.ShapeDtypeStruct((B, max(S // 4, 1), cfg.d_model), f32)
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
            if train:
                specs["targets"] = jax.ShapeDtypeStruct((B, S), i32)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
            if train:
                specs["targets"] = jax.ShapeDtypeStruct((B, S), i32)
        return specs
    specs = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    if cfg.is_encdec:
        specs["enc_out"] = jax.ShapeDtypeStruct((B, max(S // 4, 1), cfg.d_model), f32)
    return specs


# ==========================================================================
# Param counting (for 6ND roofline terms)
# ==========================================================================
@functools.lru_cache(maxsize=64)
def _param_count_cached(cfg: ArchConfig) -> int:
    shapes = jax.eval_shape(lambda: init_model(cfg, jax.random.key(0)))
    return tree_param_count(shapes)


def analytic_param_count(cfg: ArchConfig, active_only: bool = False) -> int:
    total = _param_count_cached(cfg)
    if not active_only or cfg.moe is None:
        return total
    shapes = jax.eval_shape(lambda: init_model(cfg, jax.random.key(0)))
    from repro.utils.tree import flatten_names

    expert = sum(int(np.prod(leaf.shape)) for name, leaf in flatten_names(shapes)
                 if any(t in name for t in ("w_gate", "w_up", "w_down")))
    active_frac = cfg.moe.top_k / cfg.moe.n_routed
    return int(total - expert + expert * active_frac)
