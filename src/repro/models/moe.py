"""Mixture-of-Experts FFN with top-k routing and capacity-bounded dispatch.

Dispatch is *sort-free and einsum-dispatch-free*: a cumsum-of-one-hot position
assignment plus scatter into per-expert buffers — O(T·E) for the position
bookkeeping and O(T·d) for data movement, never materialising the GShard
[T, E, C] dispatch tensor (intractable at E=128, T=1M).

Two distribution schedules (selected by ``moe_schedule``):

- ``tp_psum``  — activations replicated over the 'model' axis; each model
  shard owns E/|model| experts, processes every local token routed to them,
  and contributions are combined with a psum over 'model' (cost == one TP
  all-reduce of [T_local, d]).  Implemented with shard_map so dispatch
  bookkeeping stays device-local.
- ``local``    — no mesh: plain single-device dispatch (smoke tests / CPU).

(An all-to-all EP schedule — tokens sequence-split over the expert axis,
exchanged with all_to_all, computed, and combined — is the classic
alternative; for this mesh the psum schedule moves the same [T_local, d]
payload with one collective and no dispatch imbalance, so it is the one
implemented.  See EXPERIMENTS.md §Perf for the napkin comparison.)
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, MoEConfig
from repro.distributed.ctx import current_mesh, current_rules
from repro.models.layers import dense_apply, init_dense, init_mlp, mlp_apply
from repro.utils.jax_compat import shard_map


def init_moe(key, cfg: ArchConfig) -> dict:
    m: MoEConfig = cfg.moe
    d = cfg.d_model
    k_r, k_g, k_u, k_dn, k_s = jax.random.split(key, 5)
    dt = cfg.param_dtype
    scale = float(1.0 / np.sqrt(d))  # float(): keep bf16 weak-typed
    p = {
        "router": init_dense(k_r, d, m.n_routed, dtype=dt),
        # stacked expert weights [E, d, ff] / [E, ff, d]
        "w_gate": jax.random.normal(k_g, (m.n_routed, d, m.d_expert_ff), dtype=dt) * scale,
        "w_up": jax.random.normal(k_u, (m.n_routed, d, m.d_expert_ff), dtype=dt) * scale,
        "w_down": jax.random.normal(k_dn, (m.n_routed, m.d_expert_ff, d), dtype=dt)
        * float(1.0 / np.sqrt(m.d_expert_ff)),
    }
    if m.n_shared:
        p["shared"] = init_mlp(k_s, d, m.d_shared_ff * m.n_shared, dtype=dt)
    return p


# --------------------------------------------------------------------------
# Local (per-shard) dispatch + expert compute.
# --------------------------------------------------------------------------
def _topk_routing(router_logits: jnp.ndarray, top_k: int):
    """Returns (weights [T,k], idx [T,k]) with weights renormalised over top-k."""
    gates = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    vals, idx = jax.lax.top_k(gates, top_k)
    vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
    return vals, idx


def _positions_in_expert(idx: jnp.ndarray, n_expert: int):
    """idx: [T, k] expert assignment. Returns pos [T, k]: arrival order of each
    assignment within its expert (row-major over (T, k))."""
    T, k = idx.shape
    flat = idx.reshape(T * k)
    onehot = jax.nn.one_hot(flat, n_expert, dtype=jnp.int32)  # [T*k, E]
    pos = jnp.cumsum(onehot, axis=0) - 1  # position per expert
    pos = jnp.take_along_axis(pos, flat[:, None], axis=1)[:, 0]
    return pos.reshape(T, k)


def moe_ffn_local(p: dict, x: jnp.ndarray, cfg: ArchConfig, *,
                  expert_slice: Optional[tuple[int, int]] = None) -> jnp.ndarray:
    """x: [T, d] tokens (local). Computes routed-expert output.

    ``expert_slice=(start, count)``: only experts in [start, start+count) are
    computed (the caller psums partial outputs across expert shards).  Weights
    passed in ``p`` are the *local* slice when expert_slice is given.
    """
    m: MoEConfig = cfg.moe
    T, d = x.shape
    cd = cfg.compute_dtype
    logits = dense_apply(p["router"], x, jnp.float32)  # router in fp32
    weights, idx = _topk_routing(logits, m.top_k)  # [T,k]
    pos = _positions_in_expert(idx, m.n_routed)  # [T,k]
    cap = int(np.ceil(m.top_k * T * m.capacity_factor / m.n_routed))
    cap = max(cap, 1)

    e_start, e_count = expert_slice if expert_slice is not None else (0, m.n_routed)
    local_e = idx - e_start  # [T,k] index into local expert buffer
    in_shard = (local_e >= 0) & (local_e < e_count)
    keep = in_shard & (pos < cap)
    safe_e = jnp.where(keep, local_e, 0)
    safe_p = jnp.where(keep, pos, 0)

    # scatter tokens into per-expert buffers [E_loc, C, d]
    xk = jnp.broadcast_to(x[:, None, :], (T, m.top_k, d)).reshape(T * m.top_k, d)
    flat_keep = keep.reshape(-1)
    flat_e = safe_e.reshape(-1)
    flat_p = safe_p.reshape(-1)
    buf = jnp.zeros((e_count, cap, d), cd)
    buf = buf.at[flat_e, flat_p].add(
        jnp.where(flat_keep[:, None], xk.astype(cd), 0), mode="drop"
    )

    # expert GEMMs: [E,C,d] x [E,d,ff]
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(cd))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(cd))
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(cd))

    # gather back: each (token, slot) reads its (expert, pos) row
    gathered = out_buf[flat_e, flat_p]  # [T*k, d]
    gathered = jnp.where(flat_keep[:, None], gathered, 0)
    gathered = gathered.reshape(T, m.top_k, d)
    out = jnp.einsum("tkd,tk->td", gathered.astype(jnp.float32),
                     weights.astype(jnp.float32))
    return out.astype(cd)


def _aux_load_balance_loss(logits: jnp.ndarray, idx: jnp.ndarray, n_expert: int):
    """Switch-style auxiliary loss: E * sum(fraction_tokens * router_prob)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).mean(0)
    counts = jnp.zeros((n_expert,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    frac = counts / jnp.maximum(counts.sum(), 1.0)
    return n_expert * jnp.sum(frac * probs)


# --------------------------------------------------------------------------
# Distributed apply
# --------------------------------------------------------------------------
def moe_apply(p: dict, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """x: [B, S, d] -> [B, S, d].  Routed experts + optional shared experts."""
    m: MoEConfig = cfg.moe
    B, S, d = x.shape
    xt = x.reshape(B * S, d)

    mesh = current_mesh()
    rules = current_rules()
    model_axis = rules.rules.get("experts") if rules else None
    if mesh is not None and model_axis is not None and model_axis in mesh.axis_names \
            and mesh.shape[model_axis] > 1 and m.n_routed % mesh.shape[model_axis] == 0:
        out = _moe_tp_psum(p, xt, cfg, mesh, model_axis)
    else:
        out = moe_ffn_local(p, xt, cfg)

    out = out.reshape(B, S, d)
    if m.n_shared:
        out = out + mlp_apply(p["shared"], x, cfg.compute_dtype)
    return out


def _moe_tp_psum(p: dict, xt: jnp.ndarray, cfg: ArchConfig, mesh, model_axis: str):
    """shard_map schedule: tokens sharded over data axes (replicated over
    'model'); experts sharded over 'model'; partial outputs psum'd."""
    m: MoEConfig = cfg.moe
    rules = current_rules()
    batch_axes = rules.rules.get("batch")
    n_shards = mesh.shape[model_axis]
    e_per = m.n_routed // n_shards

    tok_spec = P(batch_axes, None)
    router_spec = jax.tree.map(lambda _: P(None, None), p["router"])
    in_specs = (
        {
            "router": router_spec,
            "w_gate": P(model_axis, None, None),
            "w_up": P(model_axis, None, None),
            "w_down": P(model_axis, None, None),
        },
        tok_spec,
    )

    def shard_fn(pl, xl):
        ax = jax.lax.axis_index(model_axis)
        out = moe_ffn_local(
            {"router": pl["router"], "w_gate": pl["w_gate"], "w_up": pl["w_up"],
             "w_down": pl["w_down"]},
            xl, cfg, expert_slice=(ax * e_per, e_per),
        )
        return jax.lax.psum(out, model_axis)

    routed = {k: p[k] for k in ("router", "w_gate", "w_up", "w_down")}
    fn = shard_map(shard_fn, mesh=mesh, in_specs=in_specs,
                   out_specs=tok_spec, check_vma=False)
    return fn(routed, xt)
