"""State-space model blocks: Mamba-1 (selective scan) and Mamba-2 (SSD).

TPU adaptation: both scans are *chunked* — a lax.scan over sequence chunks
carrying the SSM state, with the intra-chunk work expressed as dense matmuls
(associative scan for Mamba-1; the SSD block-decomposition for Mamba-2, which
is explicitly matmul-structured and therefore MXU-friendly).  Single-token
``*_step`` variants implement decode with O(1)-in-context state carries —
this is why the ``long_500k`` shape runs only for the SSM/hybrid archs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, SSMConfig
from repro.distributed.ctx import constrain
from repro.models.layers import dense_apply, init_dense, init_norm, norm_apply


# ==========================================================================
# Shared helpers
# ==========================================================================
def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 conv_state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv1d.  x: [B,S,C]; w: [K,C]; b: [C].

    Returns (y [B,S,C], new_conv_state [B,K-1,C]).
    """
    B, S, C = x.shape
    K = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)  # [B, S+K-1, C]
    y = jnp.zeros((B, S, C), jnp.float32)
    for k in range(K):  # K is 4: unrolled taps, fuses into a few adds
        y = y + xp[:, k:k + S].astype(jnp.float32) * w[k].astype(jnp.float32)
    y = y + b.astype(jnp.float32)
    new_state = xp[:, S:]
    return y.astype(x.dtype), new_state


def _segsum_decay(log_a: jnp.ndarray) -> jnp.ndarray:
    """log_a: [..., Q]. Returns L[..., i, j] = exp(sum_{t=j+1..i} log_a_t) for
    i>=j else 0 (the SSD 1-semiseparable decay matrix)."""
    Q = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # [.., i, j] = sum_{j+1..i}
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


# ==========================================================================
# Mamba-1 (falcon-mamba-7b)
# ==========================================================================
def init_mamba1(key, cfg: ArchConfig) -> dict:
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    dt_rank = s.dt_rank or int(np.ceil(d / 16))
    keys = jax.random.split(key, 8)
    dt_p = cfg.param_dtype
    p = {
        "in_proj": init_dense(keys[0], d, 2 * di, dtype=dt_p),
        "conv_w": jax.random.normal(keys[1], (s.d_conv, di), dtype=dt_p) * 0.1,
        "conv_b": jnp.zeros((di,), dtype=dt_p),
        "x_proj": init_dense(keys[2], di, dt_rank + 2 * s.d_state, dtype=dt_p),
        "dt_proj": init_dense(keys[3], dt_rank, di, bias=True, dtype=dt_p),
        # S4D-real init: A = -(1..N) per channel
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, s.d_state + 1, dtype=jnp.float32), (di, s.d_state)).astype(dt_p)),
        "D": jnp.ones((di,), dtype=dt_p),
        "out_proj": init_dense(keys[4], di, d, dtype=dt_p),
    }
    return p


def _mamba1_scan(dA: jnp.ndarray, dBx: jnp.ndarray, C: jnp.ndarray,
                 chunk: int, h0: Optional[jnp.ndarray] = None):
    """Chunked selective scan.

    dA:  [B,S,di,N] per-step decay  (exp(dt*A))
    dBx: [B,S,di,N] per-step input  (dt*B*x)
    C:   [B,S,N]    readout
    Returns (y [B,S,di], h_last [B,di,N]).
    """
    B, S, di, N = dA.shape
    Q = min(chunk, S)
    if S % Q:
        Q = S  # fall back to a single chunk for ragged smoke shapes
    nC = S // Q
    dA_c = dA.reshape(B, nC, Q, di, N)
    dBx_c = dBx.reshape(B, nC, Q, di, N)
    C_c = C.reshape(B, nC, Q, N)
    if h0 is None:
        h0 = jnp.zeros((B, di, N), jnp.float32)

    def combine(l, r):
        a_l, b_l = l
        a_r, b_r = r
        return a_l * a_r, b_l * a_r + b_r

    def body(h, xs):
        dA_q, dBx_q, C_q = xs  # [B,Q,di,N], [B,Q,N]
        A_cum, B_cum = jax.lax.associative_scan(
            combine, (dA_q.astype(jnp.float32), dBx_q.astype(jnp.float32)), axis=1)
        h_t = A_cum * h[:, None] + B_cum  # [B,Q,di,N]
        y_q = jnp.einsum("bqdn,bqn->bqd", h_t, C_q.astype(jnp.float32))
        return h_t[:, -1], y_q

    h_last, y = jax.lax.scan(body, h0, (dA_c.swapaxes(0, 1), dBx_c.swapaxes(0, 1),
                                        C_c.swapaxes(0, 1)))
    y = y.swapaxes(0, 1).reshape(B, S, di)
    return y, h_last


def mamba1_apply(p: dict, x: jnp.ndarray, cfg: ArchConfig, *,
                 state: Optional[dict] = None):
    """x: [B,S,d].  state (decode): {'conv': [B,K-1,di], 'ssm': [B,di,N]}.

    Returns (y [B,S,d], new_state or None)."""
    s: SSMConfig = cfg.ssm
    cd = cfg.compute_dtype
    B, S, d = x.shape
    di = s.expand * d
    dt_rank = s.dt_rank or int(np.ceil(d / 16))

    xz = dense_apply(p["in_proj"], x, cd)
    xin, z = xz[..., :di], xz[..., di:]
    xin = constrain(xin, "batch", None, "ff")

    conv_state = state["conv"] if state is not None else None
    xc, new_conv = _causal_conv(xin, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)

    proj = dense_apply(p["x_proj"], xc, cd)
    dt_in = proj[..., :dt_rank]
    Bm = proj[..., dt_rank:dt_rank + s.d_state].astype(jnp.float32)
    Cm = proj[..., dt_rank + s.d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(dense_apply(p["dt_proj"], dt_in, jnp.float32))  # [B,S,di]

    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # [di,N]
    dA = jnp.exp(dt[..., None] * A)  # [B,S,di,N]
    dBx = (dt * xc.astype(jnp.float32))[..., None] * Bm[:, :, None, :]  # [B,S,di,N]

    h0 = state["ssm"].astype(jnp.float32) if state is not None else None
    y, h_last = _mamba1_scan(dA, dBx, Cm, s.chunk, h0)
    y = y + xc.astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = y.astype(cd) * jax.nn.silu(z)
    out = dense_apply(p["out_proj"], y, cd)
    out = constrain(out, "batch", None, None)
    new_state = {"conv": new_conv, "ssm": h_last.astype(jnp.float32)} if state is not None else None
    return out, new_state


def mamba1_state_specs(cfg: ArchConfig, batch: int):
    """ShapeDtypeStructs for the decode state."""
    s = cfg.ssm
    di = s.expand * cfg.d_model
    return {
        "conv": jax.ShapeDtypeStruct((batch, s.d_conv - 1, di), jnp.dtype(cfg.compute_dtype)),
        "ssm": jax.ShapeDtypeStruct((batch, di, s.d_state), jnp.float32),
    }


# ==========================================================================
# Mamba-2 / SSD (zamba2).  Projections are split per stream (z|x|B|C|dt) so
# tensor parallelism can shard d_inner/heads over 'model' while keeping the
# small B/C/dt streams replicated — no awkward fused-projection resharding.
# ==========================================================================
def init_mamba2(key, cfg: ArchConfig) -> dict:
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    H = di // s.headdim
    N = s.d_state
    keys = jax.random.split(key, 9)
    dt_p = cfg.param_dtype
    return {
        "in_z": init_dense(keys[8], d, di, dtype=dt_p),
        "in_x": init_dense(keys[1], d, di, dtype=dt_p),
        "in_B": init_dense(keys[2], d, N, dtype=dt_p),
        "in_C": init_dense(keys[3], d, N, dtype=dt_p),
        "in_dt": init_dense(keys[4], d, H, dtype=dt_p),
        "conv_x_w": jax.random.normal(keys[5], (s.d_conv, di), dtype=dt_p) * 0.1,
        "conv_x_b": jnp.zeros((di,), dtype=dt_p),
        "conv_B_w": jax.random.normal(keys[6], (s.d_conv, N), dtype=dt_p) * 0.1,
        "conv_B_b": jnp.zeros((N,), dtype=dt_p),
        "conv_C_w": jax.random.normal(keys[7], (s.d_conv, N), dtype=dt_p) * 0.1,
        "conv_C_b": jnp.zeros((N,), dtype=dt_p),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dt_p),
        "D": jnp.ones((H,), dtype=dt_p),
        "dt_bias": jnp.zeros((H,), dtype=dt_p),
        "norm": init_norm("rmsnorm", di, dtype=dt_p),
        "out_proj": init_dense(keys[0], di, d, dtype=dt_p),
    }


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, h0=None):
    """Chunked SSD (Mamba-2) forward.

    xh: [B,S,H,P]; dt: [B,S,H] (post-softplus); A: [H] (negative);
    Bm, Cm: [B,S,N].  Returns (y [B,S,H,P], h_last [B,H,P,N]).
    """
    B, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    if S % Q:
        Q = S
    nC = S // Q
    xc = xh.reshape(B, nC, Q, H, Pd).astype(jnp.float32)
    dtc = dt.reshape(B, nC, Q, H).astype(jnp.float32)
    Bc = Bm.reshape(B, nC, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(B, nC, Q, N).astype(jnp.float32)
    la = dtc * A  # [B,nC,Q,H] log-decay per step
    if h0 is None:
        h0 = jnp.zeros((B, H, Pd, N), jnp.float32)

    def body(h, xs):
        x_q, dt_q, B_q, C_q, la_q = xs  # [B,Q,H,P],[B,Q,H],[B,Q,N]x2,[B,Q,H]
        la_h = la_q.swapaxes(1, 2)  # [B,H,Q]
        L = _segsum_decay(la_h)  # [B,H,Q,Q]
        scores = jnp.einsum("bqn,bpn->bqp", C_q, B_q)  # [B,Q,Q]
        M = scores[:, None] * L  # [B,H,Q,Q]
        dx = x_q * dt_q[..., None]  # [B,Q,H,P]
        y_intra = jnp.einsum("bhqp,bphd->bqhd", M, dx)
        # inter-chunk: contribution of the carried state
        decay_from_start = jnp.exp(jnp.cumsum(la_h, axis=-1))  # [B,H,Q]
        y_inter = jnp.einsum("bqn,bhpn,bhq->bqhp", C_q, h, decay_from_start)
        # state update: h' = total_decay * h + sum_t decay_to_end[t] dx_t B_t^T
        total = decay_from_start[..., -1]  # [B,H]
        decay_to_end = jnp.exp(jnp.cumsum(la_h[..., ::-1], axis=-1)[..., ::-1] - la_h)
        contrib = jnp.einsum("bqhp,bqn,bhq->bhpn", dx, B_q, decay_to_end)
        h_new = h * total[..., None, None] + contrib
        return h_new, y_intra + y_inter

    xs = (xc.swapaxes(0, 1), dtc.swapaxes(0, 1), Bc.swapaxes(0, 1),
          Cc.swapaxes(0, 1), la.swapaxes(0, 1))
    h_last, y = jax.lax.scan(body, h0, xs)
    y = y.swapaxes(0, 1).reshape(B, S, H, Pd)
    return y, h_last


def mamba2_apply(p: dict, x: jnp.ndarray, cfg: ArchConfig, *,
                 state: Optional[dict] = None):
    """x: [B,S,d]. state (decode): {'conv_x','conv_B','conv_C','ssm'}."""
    s: SSMConfig = cfg.ssm
    cd = cfg.compute_dtype
    B, S, d = x.shape
    di = s.expand * d
    H = di // s.headdim
    N = s.d_state

    z = dense_apply(p["in_z"], x, cd)
    xin = dense_apply(p["in_x"], x, cd)
    xin = constrain(xin, "batch", None, "ff")
    z = constrain(z, "batch", None, "ff")
    Braw = dense_apply(p["in_B"], x, cd)
    Craw = dense_apply(p["in_C"], x, cd)
    dt_raw = dense_apply(p["in_dt"], x, cd)

    cs = state if state is not None else {}
    xc, new_conv_x = _causal_conv(xin, p["conv_x_w"], p["conv_x_b"], cs.get("conv_x"))
    Bc, new_conv_B = _causal_conv(Braw, p["conv_B_w"], p["conv_B_b"], cs.get("conv_B"))
    Cc, new_conv_C = _causal_conv(Craw, p["conv_C_w"], p["conv_C_b"], cs.get("conv_C"))
    xc = jax.nn.silu(xc)
    Bm = jax.nn.silu(Bc).astype(jnp.float32)
    Cm = jax.nn.silu(Cc).astype(jnp.float32)
    xh = xc.reshape(B, S, H, s.headdim)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # [H]

    h0 = state["ssm"].astype(jnp.float32) if state is not None else None
    y, h_last = _ssd_chunked(xh, dt, A, Bm, Cm, s.chunk, h0)
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, di).astype(cd)
    y = norm_apply("rmsnorm", p["norm"], y * jax.nn.silu(z))
    out = dense_apply(p["out_proj"], y, cd)
    out = constrain(out, "batch", None, None)
    new_state = None
    if state is not None:
        new_state = {"conv_x": new_conv_x, "conv_B": new_conv_B,
                     "conv_C": new_conv_C, "ssm": h_last.astype(jnp.float32)}
    return out, new_state


def mamba2_state_specs(cfg: ArchConfig, batch: int):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    H = di // s.headdim
    cd = jnp.dtype(cfg.compute_dtype)
    return {
        "conv_x": jax.ShapeDtypeStruct((batch, s.d_conv - 1, di), cd),
        "conv_B": jax.ShapeDtypeStruct((batch, s.d_conv - 1, s.d_state), cd),
        "conv_C": jax.ShapeDtypeStruct((batch, s.d_conv - 1, s.d_state), cd),
        "ssm": jax.ShapeDtypeStruct((batch, H, s.headdim, s.d_state), jnp.float32),
    }
