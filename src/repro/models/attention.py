"""Attention: GQA/MHA (chunked flash-style, naive, decode-with-cache) and MLA.

The ``chunked`` implementation is the default compile path: a lax.scan over
KV chunks with an online-softmax carry — FlashAttention's memory behaviour
expressed in pure jnp so it lowers on any backend (the Pallas TPU kernel in
``repro/kernels/flash_attention`` is the hardware fast path and is validated
against the same reference).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, MLAConfig
from repro.distributed.ctx import constrain
from repro.models.layers import apply_rope, dense_apply, init_dense, init_norm, norm_apply

NEG_INF = -1e30


# ==========================================================================
# Parameter init
# ==========================================================================
def init_attention(key, cfg: ArchConfig) -> dict:
    """Standard q/k/v/o projection params for MHA/GQA."""
    kq, kk, kv, ko, kn = jax.random.split(key, 5)
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.param_dtype
    p = {
        "wq": init_dense(kq, d, h * hd, bias=cfg.qkv_bias, dtype=dt),
        "wk": init_dense(kk, d, kvh * hd, bias=cfg.qkv_bias, dtype=dt),
        "wv": init_dense(kv, d, kvh * hd, bias=cfg.qkv_bias, dtype=dt),
        "wo": init_dense(ko, h * hd, d, dtype=dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_norm("rmsnorm", hd, dtype=dt)
        p["k_norm"] = init_norm("rmsnorm", hd, dtype=dt)
    return p


def init_mla_attention(key, cfg: ArchConfig) -> dict:
    """DeepSeek-V2 MLA params. KV is compressed to a rank-`kv_lora` latent."""
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    keys = jax.random.split(key, 8)
    dt = cfg.param_dtype
    p = {}
    if m.q_lora_rank:
        p["wq_a"] = init_dense(keys[0], d, m.q_lora_rank, dtype=dt)
        p["q_a_norm"] = init_norm("rmsnorm", m.q_lora_rank, dtype=dt)
        p["wq_b"] = init_dense(keys[1], m.q_lora_rank, h * qk_dim, dtype=dt)
    else:
        p["wq"] = init_dense(keys[0], d, h * qk_dim, dtype=dt)
    # joint down-projection: latent c_kv [r] + shared rope key [qk_rope]
    p["wkv_a"] = init_dense(keys[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype=dt)
    p["kv_a_norm"] = init_norm("rmsnorm", m.kv_lora_rank, dtype=dt)
    p["wkv_b"] = init_dense(
        keys[3], m.kv_lora_rank, h * (m.qk_nope_head_dim + m.v_head_dim), dtype=dt
    )
    p["wo"] = init_dense(keys[4], h * m.v_head_dim, d, dtype=dt)
    return p


# ==========================================================================
# Core softmax-attention over explicit q/k/v (heads grouped for GQA)
# ==========================================================================
def _naive_attention(q, k, v, *, causal: bool, q_pos, kv_pos, kv_len=None):
    """q: [B,Sq,KV,G,D]; k,v: [B,Skv,KV,D]. Returns [B,Sq,KV,G,D]."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    s = jnp.einsum("bqkgd,bpkd->bkgqp", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    mask = jnp.ones(s.shape[-2:], dtype=bool)
    if causal:
        mask = kv_pos[None, :] <= q_pos[:, None]
    if kv_len is not None:
        mask = mask & (kv_pos[None, :] < kv_len)
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqp,bpkd->bqkgd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def _chunked_attention(q, k, v, *, causal: bool, q_pos, kv_pos, q_chunk: int,
                       kv_chunk: int, kv_len=None, block_skip: bool = True):
    """Flash-style online-softmax attention in pure jnp.

    q: [B,Sq,KV,G,D]; k,v: [B,Skv,KV,D].  Scans over q chunks (outer, unrolled
    python loop so causal upper blocks can be *statically* skipped) and kv
    chunks (inner lax.scan with (m, l, acc) carry).
    """
    B, Sq, KV, G, D = q.shape
    Skv = k.shape[1]
    Dv = v.shape[-1]  # v head dim may differ from q/k (MLA)
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    nq = -(-Sq // q_chunk)
    nkv = -(-Skv // kv_chunk)
    scale = 1.0 / np.sqrt(D)
    kc = k.reshape(B, nkv, kv_chunk, KV, D)
    vc = v.reshape(B, nkv, kv_chunk, KV, Dv)
    kv_posc = kv_pos.reshape(nkv, kv_chunk)

    def one_q_chunk(qi: int, n_kv_blocks: int):
        qs = q[:, qi * q_chunk:(qi + 1) * q_chunk].astype(jnp.float32)
        qp = q_pos[qi * q_chunk:(qi + 1) * q_chunk]
        m0 = jnp.full((B, KV, G, qs.shape[1]), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qs.shape[1]), jnp.float32)
        a0 = jnp.zeros((B, qs.shape[1], KV, G, Dv), jnp.float32)

        def body(carry, xs):
            m, l, acc = carry
            kb, vb, kp = xs
            s = jnp.einsum("bqkgd,bpkd->bkgqp", qs, kb.astype(jnp.float32)) * scale
            mask = jnp.ones((qs.shape[1], kv_chunk), bool)
            if causal:
                mask = kp[None, :] <= qp[:, None]
            if kv_len is not None:
                mask = mask & (kp[None, :] < kv_len)
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha.transpose(0, 3, 1, 2)[..., None]
            acc = acc + jnp.einsum("bkgqp,bpkd->bqkgd", p, vb.astype(jnp.float32))
            return (m_new, l, acc), None

        xs = (
            kc[:, :n_kv_blocks].swapaxes(0, 1),
            vc[:, :n_kv_blocks].swapaxes(0, 1),
            kv_posc[:n_kv_blocks],
        )
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), xs)
        l = jnp.maximum(l, 1e-30)
        out = acc / l.transpose(0, 3, 1, 2)[..., None]
        return out.astype(q.dtype)

    outs = []
    for qi in range(nq):
        if causal and block_skip and Sq == Skv:
            # causal block skipping: q chunk qi only attends to kv blocks
            # whose first position <= last q position of this chunk.
            last_q = (qi + 1) * q_chunk - 1
            n_blocks = min(nkv, last_q // kv_chunk + 1)
        else:
            n_blocks = nkv
        outs.append(one_q_chunk(qi, n_blocks))
    return jnp.concatenate(outs, axis=1)


def grouped_attention(q, k, v, *, causal, q_pos, kv_pos, impl="chunked",
                      q_chunk=512, kv_chunk=512, kv_len=None):
    """Dispatch over attention implementations. Shapes as in _naive_attention."""
    Sq, Skv = q.shape[1], k.shape[1]
    divisible = Sq % min(q_chunk, Sq) == 0 and Skv % min(kv_chunk, Skv) == 0
    if impl == "naive" or q.shape[1] == 1 or not divisible:
        return _naive_attention(q, k, v, causal=causal, q_pos=q_pos, kv_pos=kv_pos,
                                kv_len=kv_len)
    if impl == "chunked":
        return _chunked_attention(q, k, v, causal=causal, q_pos=q_pos, kv_pos=kv_pos,
                                  q_chunk=q_chunk, kv_chunk=kv_chunk, kv_len=kv_len)
    if impl == "chunked_noskip":
        return _chunked_attention(q, k, v, causal=causal, q_pos=q_pos, kv_pos=kv_pos,
                                  q_chunk=q_chunk, kv_chunk=kv_chunk, kv_len=kv_len,
                                  block_skip=False)
    raise ValueError(f"unknown attention impl {impl!r}")


def _kv_quant(x: jnp.ndarray):
    """Per-(batch, position, head) int8 quantization of K/V rows."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(scale, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale[..., 0].astype(jnp.bfloat16)


def _kv_dequant(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]).astype(dtype)


# ==========================================================================
# GQA block (train/prefill full-sequence, and single-token decode)
# ==========================================================================
def attention_apply(p: dict, x: jnp.ndarray, cfg: ArchConfig, *, causal: bool = True,
                    positions: Optional[jnp.ndarray] = None,
                    kv_cache: Optional[dict] = None,
                    cache_index: Optional[jnp.ndarray] = None,
                    cache_len: Optional[jnp.ndarray] = None):
    """x: [B, S, d]. If kv_cache given (decode): append k/v at cache_index and
    attend over cache[:cache_len]. Returns (out [B,S,d], new_cache|None)."""
    B, S, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = h // kvh
    cd = cfg.compute_dtype

    q = dense_apply(p["wq"], x, cd).reshape(B, S, kvh, G, hd)
    k = dense_apply(p["wk"], x, cd).reshape(B, S, kvh, hd)
    v = dense_apply(p["wv"], x, cd).reshape(B, S, kvh, hd)
    if cfg.qk_norm:
        q = norm_apply("rmsnorm", p["q_norm"], q)
        k = norm_apply("rmsnorm", p["k_norm"], k)

    if positions is None:
        positions = jnp.arange(S)
        if cache_index is not None:
            positions = positions + cache_index
    q = apply_rope(q.reshape(B, S, kvh * G, hd), positions, cfg.rope_theta)
    q = q.reshape(B, S, kvh, G, hd)
    k = apply_rope(k, positions, cfg.rope_theta)

    rep = cfg.kv_repeat
    if rep > 1:  # vLLM-style KV-head replication so TP divides the KV axis
        assert G % rep == 0, (G, rep)
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
        q = q.reshape(B, S, kvh, rep, G // rep, hd).reshape(
            B, S, kvh * rep, G // rep, hd)
        kvh, G = kvh * rep, G // rep

    q = constrain(q, "batch", None, "kv_heads", None, None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)

    new_cache = None
    if kv_cache is not None:
        if "k_scale" in kv_cache:  # int8-quantized KV (SS Perf iteration)
            kq, ks = _kv_quant(k)
            vq, vs = _kv_quant(v)
            upd = lambda c, x: jax.lax.dynamic_update_slice_in_dim(
                c, x.astype(c.dtype), cache_index, axis=1)
            new_cache = {"k": upd(kv_cache["k"], kq),
                         "v": upd(kv_cache["v"], vq),
                         "k_scale": upd(kv_cache["k_scale"], ks),
                         "v_scale": upd(kv_cache["v_scale"], vs)}
            ck = _kv_dequant(new_cache["k"], new_cache["k_scale"], k.dtype)
            cv = _kv_dequant(new_cache["v"], new_cache["v_scale"], v.dtype)
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["k"], k.astype(kv_cache["k"].dtype), cache_index, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["v"], v.astype(kv_cache["v"].dtype), cache_index, axis=1)
            new_cache = {"k": ck, "v": cv}
        kv_pos = jnp.arange(ck.shape[1])
        # decode (S==1) dispatches to the naive path inside grouped_attention;
        # prefill-with-cache (S==Smax) runs the chunked causal path.
        out = grouped_attention(q, ck, cv, causal=(S > 1), q_pos=positions,
                                kv_pos=kv_pos, impl=cfg.attention_impl,
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                kv_len=cache_len)
    else:
        kv_pos = positions
        out = grouped_attention(q, k, v, causal=causal, q_pos=positions, kv_pos=kv_pos,
                                impl=cfg.attention_impl, q_chunk=cfg.q_chunk,
                                kv_chunk=cfg.kv_chunk)

    out = out.reshape(B, S, h * hd)
    out = dense_apply(p["wo"], out, cd)
    out = constrain(out, "batch", None, None)
    return out, new_cache



# ==========================================================================
# MLA block (DeepSeek-V2).
#
# Prefill/train: the latent is up-projected ONCE to per-head K/V and attention
# runs through the same chunked online-softmax core as GQA (O(S) memory).
# Decode: the *absorbed* formulation — W_uk is folded into the query and W_uv
# into the output so scores/values are computed directly against the cached
# rank-r latent.  Per-token cost is O(S·r·h) instead of O(S·r·h·d_head) for a
# naive cache up-projection; this is the whole point of MLA serving.
# ==========================================================================
def _mla_qkv_latent(p: dict, x: jnp.ndarray, cfg: ArchConfig, positions):
    """Shared first stage: queries + compressed latent (+rope key)."""
    m: MLAConfig = cfg.mla
    B, S, _ = x.shape
    h = cfg.n_heads
    dn, dr = m.qk_nope_head_dim, m.qk_rope_head_dim
    cd = cfg.compute_dtype
    if m.q_lora_rank:
        cq = dense_apply(p["wq_a"], x, cd)
        cq = norm_apply("rmsnorm", p["q_a_norm"], cq)
        q = dense_apply(p["wq_b"], cq, cd)
    else:
        q = dense_apply(p["wq"], x, cd)
    q = q.reshape(B, S, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = dense_apply(p["wkv_a"], x, cd)  # [B,S,r+dr]
    c_kv = norm_apply("rmsnorm", p["kv_a_norm"], kv_a[..., : m.kv_lora_rank])
    k_rope = apply_rope(kv_a[..., m.kv_lora_rank:][:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]  # [B,S,dr], shared by heads
    return q_nope, q_rope, c_kv, k_rope


def mla_apply(p: dict, x: jnp.ndarray, cfg: ArchConfig, *, causal: bool = True,
              positions: Optional[jnp.ndarray] = None,
              kv_cache: Optional[dict] = None,
              cache_index: Optional[jnp.ndarray] = None,
              cache_len: Optional[jnp.ndarray] = None):
    m: MLAConfig = cfg.mla
    B, S, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    cd = cfg.compute_dtype
    if positions is None:
        positions = jnp.arange(S)
        if cache_index is not None:
            positions = positions + cache_index

    q_nope, q_rope, c_kv, k_rope = _mla_qkv_latent(p, x, cfg, positions)

    new_cache = None
    if kv_cache is not None:
        if "c_kv_scale" in kv_cache:  # int8 latent cache (SS Perf)
            cq, cs = _kv_quant(c_kv)
            upd = lambda c, x: jax.lax.dynamic_update_slice_in_dim(
                c, x.astype(c.dtype), cache_index, axis=1)
            new_cache = {"c_kv": upd(kv_cache["c_kv"], cq),
                         "c_kv_scale": upd(kv_cache["c_kv_scale"], cs),
                         "k_rope": upd(kv_cache["k_rope"], k_rope)}
            c_kv = _kv_dequant(new_cache["c_kv"], new_cache["c_kv_scale"],
                               cfg.compute_dtype)
            k_rope = new_cache["k_rope"]
        else:
            c_kv = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["c_kv"], c_kv.astype(kv_cache["c_kv"].dtype), cache_index, axis=1)
            k_rope = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["k_rope"], k_rope.astype(kv_cache["k_rope"].dtype), cache_index, axis=1)
            new_cache = {"c_kv": c_kv, "k_rope": k_rope}
        if S == 1:
            out = _mla_absorbed_attention(p, q_nope, q_rope, c_kv, k_rope, cfg,
                                          cache_len=cache_len)
            out = dense_apply(p["wo"], out.reshape(B, S, h * dv), cd)
            return constrain(out, "batch", None, None), new_cache
        # prefill-with-cache: fall through to the full-sequence path below,
        # attending over the (just-updated) cached latents with a causal mask.
        causal = True

    # full-sequence path: materialise per-head K/V from the latent once
    Skv = c_kv.shape[1]
    kvb = dense_apply(p["wkv_b"], c_kv, cd).reshape(B, Skv, h, dn + dv)
    k_nope, vv = kvb[..., :dn], kvb[..., dn:]
    k_nope = constrain(k_nope, "batch", None, "heads", None)
    vv = constrain(vv, "batch", None, "heads", None)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, Skv, h, dr))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)[:, :, :, None, :]  # G=1
    q_full = q_full.transpose(0, 1, 2, 3, 4)  # [B,S,h,1,dn+dr]
    out = grouped_attention(
        q_full, k_full, vv, causal=causal, q_pos=positions,
        kv_pos=jnp.arange(Skv), impl=cfg.attention_impl,
        q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk, kv_len=cache_len)
    out = out.reshape(B, S, h * dv)
    out = dense_apply(p["wo"], out, cd)
    return constrain(out, "batch", None, None), new_cache


def _mla_absorbed_attention(p, q_nope, q_rope, c_kv, k_rope, cfg: ArchConfig,
                            cache_len=None):
    """Decode attention in latent space. q_*: [B,1,h,*]; c_kv: [B,Skv,r]."""
    m: MLAConfig = cfg.mla
    B, S, h, dn = q_nope.shape
    Skv = c_kv.shape[1]
    dv = m.v_head_dim
    w_kv_b = p["wkv_b"]["w"].astype(jnp.float32).reshape(m.kv_lora_rank, h, dn + dv)
    w_uk = w_kv_b[..., :dn]  # [r,h,dn]
    w_uv = w_kv_b[..., dn:]  # [r,h,dv]

    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32), w_uk)
    s = jnp.einsum("bqhr,bpr->bhqp", q_lat, c_kv.astype(jnp.float32))
    s = s + jnp.einsum("bqhd,bpd->bhqp", q_rope.astype(jnp.float32),
                       k_rope.astype(jnp.float32))
    s = s / np.sqrt(dn + m.qk_rope_head_dim)
    kv_pos = jnp.arange(Skv)
    if cache_len is not None:
        s = jnp.where(kv_pos[None, None, None, :] < cache_len, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhqp,bpr->bqhr", w, c_kv.astype(jnp.float32))
    out = jnp.einsum("bqhr,rhd->bqhd", o_lat, w_uv)
    return out.astype(cfg.compute_dtype)
