"""Core NN primitives: dense layers, norms, rotary embeddings, embeddings.

Everything is functional: ``init_*`` builds a param pytree (nested dicts of
jnp arrays), ``*_apply`` consumes it.  No framework dependency (flax-free) so
that param trees stay plain pytrees for pjit/shard_map/checkpointing.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _dtype(name: str):
    return jnp.dtype(name)


# --------------------------------------------------------------------------
# Dense
# --------------------------------------------------------------------------
def init_dense(key, d_in: int, d_out: int, *, bias: bool = False, dtype="float32",
               scale: Optional[float] = None) -> dict:
    # NOTE: float() keeps the multiply weakly-typed — a np.float64 scalar
    # would silently promote bf16 params to f32 (doubling serve memory)
    scale = float(scale if scale is not None else 1.0 / np.sqrt(d_in))
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype=_dtype(dtype)) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=_dtype(dtype))
    return p


def dense_apply(p: dict, x: jnp.ndarray, compute_dtype="bfloat16") -> jnp.ndarray:
    w = p["w"].astype(compute_dtype)
    y = x.astype(compute_dtype) @ w
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------
def init_norm(kind: str, dim: int, dtype="float32") -> dict:
    if kind == "rmsnorm":
        return {"scale": jnp.ones((dim,), dtype=_dtype(dtype))}
    if kind == "layernorm":
        return {"scale": jnp.ones((dim,), dtype=_dtype(dtype)),
                "bias": jnp.zeros((dim,), dtype=_dtype(dtype))}
    if kind == "layernorm_nonparam":  # OLMo: non-parametric LN
        return {}
    raise ValueError(f"unknown norm kind {kind!r}")


def norm_apply(kind: str, p: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32)
    else:
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + eps)
        if kind == "layernorm":
            y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape [head_dim // 2] (float32)."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotate pairs. x: [..., S, H, D] (D even); positions: broadcastable [..., S]."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)  # [d/2]
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., S, d/2]
    cos = jnp.cos(ang)[..., None, :]  # [..., S, 1, d/2]
    sin = jnp.sin(ang)[..., None, :]
    x1 = x[..., : d // 2].astype(jnp.float32)
    x2 = x[..., d // 2:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Embeddings
# --------------------------------------------------------------------------
def init_embedding(key, vocab: int, d_model: int, dtype="float32") -> dict:
    return {"table": jax.random.normal(key, (vocab, d_model), dtype=_dtype(dtype)) * 0.02}


def embedding_apply(p: dict, tokens: jnp.ndarray, compute_dtype="bfloat16") -> jnp.ndarray:
    from repro.distributed.ctx import constrain

    # cast-then-gather: the FSDP gather of the table moves bf16, not f32
    table = constrain(p["table"].astype(compute_dtype), "vocab", None)
    return table[tokens]


# --------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# --------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, dtype="float32") -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_dense(k1, d_model, d_ff, dtype=dtype),
        "up": init_dense(k2, d_model, d_ff, dtype=dtype),
        "down": init_dense(k3, d_ff, d_model, dtype=dtype),
    }


def mlp_apply(p: dict, x: jnp.ndarray, compute_dtype="bfloat16") -> jnp.ndarray:
    g = dense_apply(p["gate"], x, compute_dtype)
    u = dense_apply(p["up"], x, compute_dtype)
    h = jax.nn.silu(g) * u
    return dense_apply(p["down"], h, compute_dtype)
