"""Pallas TPU kernel: causal GQA FlashAttention (model-side hot spot).

Grid: (batch*q_heads, n_q_blocks, n_kv_blocks) with the KV dimension
innermost; online-softmax statistics (m, l) and the output accumulator live
in VMEM scratch and persist across the KV grid steps of one q block.  Fully
masked (future) KV blocks are skipped with pl.when — the causal-skip that
halves prefill compute.  BlockSpecs keep one [Bq, D] query tile, one
[Bkv, D] K/V tile and the [Bq, D] f32 accumulator in VMEM per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BKV = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, bq: int, bkv: int, causal: bool, n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal block skip: KV block strictly after the last q row is dead
    live = (not causal) or (ki * bkv <= qi * bq + bq - 1)

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32)        # [bq, d]
        k = k_ref[0].astype(jnp.float32)        # [bkv, d]
        v = v_ref[0].astype(jnp.float32)
        s = q @ k.T * scale                     # [bq, bkv] (MXU)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            kpos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v
        m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, bq: int = DEFAULT_BQ,
                    bkv: int = DEFAULT_BKV, interpret: bool = False):
    """q: [B, H, S, D]; k, v: [B, KV, S, D].  Returns [B, H, S, D].

    GQA is handled by indexing the KV head as H // group in the BlockSpec
    index maps — no KV replication in HBM.
    """
    b, h, s, d = q.shape
    kv = k.shape[1]
    g = h // kv
    bq = min(bq, s)
    bkv = min(bkv, s)
    assert s % bq == 0 and s % bkv == 0, (s, bq, bkv)
    n_kv = s // bkv
    grid = (b * h, s // bq, n_kv)
    scale = 1.0 / np.sqrt(d)

    kernel = functools.partial(_kernel, scale=scale, bq=bq, bkv=bkv,
                               causal=causal, n_kv=n_kv)
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * kv, s, d)
    vf = v.reshape(b * kv, s, d)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bkv, d), lambda bh, qi, ki, g=g: (bh // g, ki, 0)),
            pl.BlockSpec((1, bkv, d), lambda bh, qi, ki, g=g: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
