"""Jit'd wrapper for the flash-attention kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.flash import flash_attention


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bkv", "interpret"))
def flash_attention_op(q, k, v, *, causal: bool = True, bq: int = 128,
                       bkv: int = 128, interpret: bool = False):
    s = q.shape[2]
    bq = min(bq, s)
    bkv = min(bkv, s)
    while s % bq:
        bq //= 2
    while s % bkv:
        bkv //= 2
    return flash_attention(q, k, v, causal=causal, bq=max(bq, 1),
                           bkv=max(bkv, 1), interpret=interpret)
