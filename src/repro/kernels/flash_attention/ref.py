"""Pure-jnp oracle for the causal GQA flash-attention kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True) -> jnp.ndarray:
    """q: [B, H, S, D]; k, v: [B, KV, S, D] with H % KV == 0."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    g = h // kv
    qg = q.reshape(b, kv, g, s, d).astype(jnp.float32)
    scores = jnp.einsum("bkgqd,bkpd->bkgqp", qg, k.astype(jnp.float32))
    scores = scores / np.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqp,bkpd->bkgqd", w, v.astype(jnp.float32))
    return out.reshape(b, h, s, d).astype(q.dtype)


import jax  # noqa: E402  (kept at bottom to keep the oracle self-contained)
