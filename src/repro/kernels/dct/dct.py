"""Pallas TPU kernel: fused blockwise 8x8 DCT + quantization (encode hot loop).

TPU mapping: a tile of BLK consecutive 8x8 blocks lives in VMEM as
[BLK, 8, 8]; the two constant 8x8 basis matmuls are expressed as einsums that
lower to MXU dot_generals batched over the BLK dimension; the quant divide +
round runs on the VPU; output int16 stays in VMEM until the grid step ends.
Grid: one program per BLK-row of blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.codec.quant import quant_matrix
from repro.codec.transform import dct_matrix

BLK = 256  # 8x8 blocks per grid step: [256, 8, 8] f32 = 64 KiB in VMEM


def _kernel(x_ref, d_ref, m_ref, out_ref):
    d = d_ref[...]
    m = m_ref[...]
    x = x_ref[...].astype(jnp.float32)          # [BLK, 8, 8]
    c = jnp.einsum("ij,njk->nik", d, x)          # D @ X
    c = jnp.einsum("nik,lk->nil", c, d)          # ... @ D^T
    out_ref[...] = jnp.round(c / m).astype(jnp.int16)


def dct_quant(blocks: jnp.ndarray, qp: int, intra: bool, *,
              interpret: bool = False, blk: int = BLK) -> jnp.ndarray:
    """blocks: [N, 8, 8] f32, N % blk == 0 -> [N, 8, 8] int16."""
    n = blocks.shape[0]
    assert n % blk == 0, (n, blk)
    grid = (n // blk,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, 8, 8), lambda i: (i, 0, 0)),
            pl.BlockSpec((8, 8), lambda i: (0, 0)),
            pl.BlockSpec((8, 8), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk, 8, 8), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 8, 8), jnp.int16),
        interpret=interpret,
    )(blocks, jnp.asarray(dct_matrix()), jnp.asarray(quant_matrix(qp, intra)))
