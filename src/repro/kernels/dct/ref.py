"""Pure-jnp oracle for the fused DCT+quantize encode kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.codec.quant import quant_matrix
from repro.codec.transform import dct_matrix


def dct_quant_ref(blocks: jnp.ndarray, qp: int, intra: bool) -> jnp.ndarray:
    """blocks: [N, 8, 8] f32 -> quantized coeffs [N, 8, 8] int16."""
    d = jnp.asarray(dct_matrix())
    coeffs = jnp.einsum("ij,njk,lk->nil", d, blocks.astype(jnp.float32), d)
    m = jnp.asarray(quant_matrix(qp, intra))
    return jnp.round(coeffs / m).astype(jnp.int16)
