"""Jit'd public wrapper for the DCT+quant kernel with shape padding.

Padding happens *outside* the jit and clamps to the shared power-of-two
buckets (:func:`repro.kernels.decode.ops.pad_bucket`), so the jitted inner
only ever sees one shape per octave — previously the whole wrapper was
jitted on the raw block count and retraced for every distinct tile size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.dct.dct import BLK, dct_quant
from repro.kernels.decode.ops import pad_bucket


@functools.partial(jax.jit, static_argnames=("qp", "intra", "interpret"))
def _dct_quant(blocks: jnp.ndarray, *, qp: int, intra: bool,
               interpret: bool) -> jnp.ndarray:
    return dct_quant(blocks, qp, intra, interpret=interpret,
                     blk=min(BLK, blocks.shape[0]))


def dct_quant_op(blocks: jnp.ndarray, *, qp: int, intra: bool,
                 interpret: bool = False) -> jnp.ndarray:
    """[N, 8, 8] f32 -> [N, 8, 8] int16; pads N up to the shared bucket."""
    n = blocks.shape[0]
    padded = pad_bucket(n)
    if padded != n:
        blocks = jnp.concatenate(
            [blocks, jnp.zeros((padded - n, 8, 8), blocks.dtype)], axis=0)
    out = _dct_quant(blocks, qp=qp, intra=intra, interpret=interpret)
    return out[:n]
