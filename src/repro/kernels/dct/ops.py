"""Jit'd public wrapper for the DCT+quant kernel with shape padding."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.dct.dct import BLK, dct_quant


@functools.partial(jax.jit, static_argnames=("qp", "intra", "interpret"))
def dct_quant_op(blocks: jnp.ndarray, *, qp: int, intra: bool,
                 interpret: bool = False) -> jnp.ndarray:
    """[N, 8, 8] f32 -> [N, 8, 8] int16; pads N up to the kernel tile."""
    n = blocks.shape[0]
    blk = min(BLK, max(8, 1 << (n - 1).bit_length()))
    pad = (-n) % blk
    if pad:
        blocks = jnp.concatenate(
            [blocks, jnp.zeros((pad, 8, 8), blocks.dtype)], axis=0)
    out = dct_quant(blocks, qp, intra, interpret=interpret, blk=blk)
    return out[:n]
