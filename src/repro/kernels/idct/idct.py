"""Pallas TPU kernel: fused dequantize + 8x8 IDCT — THE decode hot loop.

Every pixel a TASM query touches passes through this kernel; 'decode cost
∝ pixels decoded' is literally this kernel's runtime.  Same VMEM tiling as
the forward DCT: [BLK, 8, 8] int16 in, f32 out, two MXU matmuls + VPU scale.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.codec.quant import quant_matrix
from repro.codec.transform import dct_matrix

BLK = 256


def _kernel(q_ref, d_ref, m_ref, out_ref):
    d = d_ref[...]
    m = m_ref[...]
    c = q_ref[...].astype(jnp.float32) * m       # dequant (VPU)
    x = jnp.einsum("ji,njk->nik", d, c)          # D^T @ C
    x = jnp.einsum("nik,kl->nil", x, d)          # ... @ D
    out_ref[...] = x


def idct_dequant(q: jnp.ndarray, qp: int, intra: bool, *,
                 interpret: bool = False, blk: int = BLK) -> jnp.ndarray:
    n = q.shape[0]
    assert n % blk == 0, (n, blk)
    return pl.pallas_call(
        _kernel,
        grid=(n // blk,),
        in_specs=[
            pl.BlockSpec((blk, 8, 8), lambda i: (i, 0, 0)),
            pl.BlockSpec((8, 8), lambda i: (0, 0)),
            pl.BlockSpec((8, 8), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk, 8, 8), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 8, 8), jnp.float32),
        interpret=interpret,
    )(q, jnp.asarray(dct_matrix()), jnp.asarray(quant_matrix(qp, intra)))
