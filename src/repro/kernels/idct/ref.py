"""Pure-jnp oracle for the fused dequant+IDCT decode kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.codec.quant import quant_matrix
from repro.codec.transform import dct_matrix


def idct_dequant_ref(q: jnp.ndarray, qp: int, intra: bool) -> jnp.ndarray:
    """q: [N, 8, 8] int16 -> pixels/residual [N, 8, 8] f32."""
    m = jnp.asarray(quant_matrix(qp, intra))
    coeffs = q.astype(jnp.float32) * m
    d = jnp.asarray(dct_matrix())
    return jnp.einsum("ji,njk,kl->nil", d, coeffs, d)
