"""Jit'd public wrapper for the dequant+IDCT kernel with shape padding."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.idct.idct import BLK, idct_dequant


@functools.partial(jax.jit, static_argnames=("qp", "intra", "interpret"))
def idct_dequant_op(q: jnp.ndarray, *, qp: int, intra: bool,
                    interpret: bool = False) -> jnp.ndarray:
    n = q.shape[0]
    blk = min(BLK, max(8, 1 << (n - 1).bit_length()))
    pad = (-n) % blk
    if pad:
        q = jnp.concatenate([q, jnp.zeros((pad, 8, 8), q.dtype)], axis=0)
    out = idct_dequant(q, qp, intra, interpret=interpret, blk=blk)
    return out[:n]
