"""Jit'd public wrapper for the dequant+IDCT kernel with shape padding.

Padding happens *outside* the jit and clamps to the shared power-of-two
buckets (:func:`repro.kernels.decode.ops.pad_bucket`), so the jitted inner
only ever sees one shape per octave — previously the whole wrapper was
jitted on the raw block count and retraced for every distinct tile size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode.ops import pad_bucket
from repro.kernels.idct.idct import BLK, idct_dequant


@functools.partial(jax.jit, static_argnames=("qp", "intra", "interpret"))
def _idct_dequant(q: jnp.ndarray, *, qp: int, intra: bool,
                  interpret: bool) -> jnp.ndarray:
    return idct_dequant(q, qp, intra, interpret=interpret,
                        blk=min(BLK, q.shape[0]))


def idct_dequant_op(q: jnp.ndarray, *, qp: int, intra: bool,
                    interpret: bool = False) -> jnp.ndarray:
    """[N, 8, 8] int16 -> [N, 8, 8] f32; pads N up to the shared bucket."""
    n = q.shape[0]
    padded = pad_bucket(n)
    if padded != n:
        q = jnp.concatenate([q, jnp.zeros((padded - n, 8, 8), q.dtype)],
                            axis=0)
    out = _idct_dequant(q, qp=qp, intra=intra, interpret=interpret)
    return out[:n]
