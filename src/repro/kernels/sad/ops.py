"""Jit'd wrapper for the SAD motion-search kernel + frame-level helper."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.sad.sad import BLK, sad_search


@functools.partial(jax.jit, static_argnames=("interpret",))
def sad_search_op(cur_blocks: jnp.ndarray, ref_windows: jnp.ndarray, *,
                  interpret: bool = False):
    n = cur_blocks.shape[0]
    blk = min(BLK, max(8, 1 << (n - 1).bit_length()))
    pad = (-n) % blk
    if pad:
        cur_blocks = jnp.concatenate(
            [cur_blocks, jnp.zeros((pad,) + cur_blocks.shape[1:],
                                   cur_blocks.dtype)], axis=0)
        ref_windows = jnp.concatenate(
            [ref_windows, jnp.zeros((pad,) + ref_windows.shape[1:],
                                    ref_windows.dtype)], axis=0)
    dy, dx, sad = sad_search(cur_blocks, ref_windows, interpret=interpret,
                             blk=blk)
    return dy[:n], dx[:n], sad[:n]


def frame_motion_blocks(cur: np.ndarray, ref: np.ndarray, *, b: int = 16,
                        r: int = 8):
    """Host helper: cut a frame into blocks + padded search windows."""
    H, W = cur.shape
    assert H % b == 0 and W % b == 0
    ref_pad = np.pad(ref, r, mode="edge")
    blocks, windows = [], []
    for y in range(0, H, b):
        for x in range(0, W, b):
            blocks.append(cur[y:y + b, x:x + b])
            windows.append(ref_pad[y:y + b + 2 * r, x:x + b + 2 * r])
    return np.stack(blocks), np.stack(windows)
