"""Pure-jnp oracle for block motion search (sum of absolute differences)."""
from __future__ import annotations

import jax.numpy as jnp


def sad_search_ref(cur_blocks: jnp.ndarray, ref_windows: jnp.ndarray):
    """cur_blocks: [N, B, B]; ref_windows: [N, B+2R, B+2R].

    Exhaustive +-R search.  Returns (best_dy [N], best_dx [N], best_sad [N])
    with displacement in [0, 2R] (subtract R for signed motion).
    """
    n, b, _ = cur_blocks.shape
    win = ref_windows.shape[-1]
    r2 = win - b + 1  # 2R+1 candidate positions per axis
    sads = []
    for dy in range(r2):
        for dx in range(r2):
            cand = ref_windows[:, dy:dy + b, dx:dx + b]
            sads.append(jnp.sum(jnp.abs(cur_blocks.astype(jnp.float32)
                                        - cand.astype(jnp.float32)), axis=(1, 2)))
    sads = jnp.stack(sads, axis=1)  # [N, r2*r2]
    best = jnp.argmin(sads, axis=1)
    return best // r2, best % r2, jnp.min(sads, axis=1)
