"""Pallas TPU kernel: exhaustive block-motion SAD search.

Per grid step, BLK current blocks [BLK, B, B] and their search windows
[BLK, B+2R, B+2R] sit in VMEM; the (2R+1)^2 candidate SADs are evaluated with
VPU abs-diff reductions (unrolled — R is small and static), tracking the
running argmin without materialising the full SAD cube in HBM.  This is the
encoder-side motion-estimation hot spot; on GPU codecs this lives in fixed-
function hardware, on TPU it becomes a VPU reduction sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK = 64


def _kernel(cur_ref, win_ref, dy_ref, dx_ref, sad_ref, *, b: int, r2: int):
    cur = cur_ref[...].astype(jnp.float32)          # [BLK, B, B]
    win = win_ref[...].astype(jnp.float32)          # [BLK, B+2R, B+2R]
    n = cur.shape[0]
    best = jnp.full((n,), jnp.inf, jnp.float32)
    bdy = jnp.zeros((n,), jnp.int32)
    bdx = jnp.zeros((n,), jnp.int32)
    for dy in range(r2):                            # static unroll
        for dx in range(r2):
            cand = win[:, dy:dy + b, dx:dx + b]
            s = jnp.sum(jnp.abs(cur - cand), axis=(1, 2))
            take = s < best
            best = jnp.where(take, s, best)
            bdy = jnp.where(take, dy, bdy)
            bdx = jnp.where(take, dx, bdx)
    dy_ref[...] = bdy
    dx_ref[...] = bdx
    sad_ref[...] = best


def sad_search(cur_blocks: jnp.ndarray, ref_windows: jnp.ndarray, *,
               interpret: bool = False, blk: int = BLK):
    """cur: [N, B, B]; windows: [N, B+2R, B+2R]; N % blk == 0."""
    n, b, _ = cur_blocks.shape
    win = ref_windows.shape[-1]
    r2 = win - b + 1
    assert n % blk == 0, (n, blk)
    kernel = functools.partial(_kernel, b=b, r2=r2)
    return pl.pallas_call(
        kernel,
        grid=(n // blk,),
        in_specs=[
            pl.BlockSpec((blk, b, b), lambda i: (i, 0, 0)),
            pl.BlockSpec((blk, win, win), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(cur_blocks, ref_windows)
