"""Pallas TPU kernel: multi-tile fused dequant + 8x8 IDCT + GOP cumsum.

One dispatch decodes a whole scheduler batch: the input is a flat *block
stream* ``[F, M, 8, 8]`` where ``F`` is the (bucketed) frames-per-GOP depth
and each of the ``M`` columns is one 8x8 block of one ``(tile, GOP,
block-mask)`` selection — ROI block-gather happens on the host while
assembling the stream, so masked-out blocks never reach the kernel.

Row 0 holds intra-coded keyframe coefficients, rows 1..F-1 the inter-coded
P-frame residuals; the closed-loop reconstruction ``out[f] = out[f-1] +
IDCT(dequant(q[f]))`` is the sequential sum the numpy oracle computes, so
the result is bit-identical to per-tile ``decode_tile`` (padding rows with
zero coefficients only ever *appends* frames, which callers slice off).

Grid is over column blocks: each program reconstructs ``[F, blk, 8, 8]``
with F statically unrolled — two MXU matmuls + a VPU scale per frame, the
same VMEM tiling as the single-tile IDCT kernel, now amortized across every
tile of the batch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.codec.quant import quant_matrix
from repro.codec.transform import dct_matrix

#: columns per program — [F, BLK, 8, 8] f32 out is 0.5 MiB at F=16
BLK = 128


def _kernel(q_ref, d_ref, mk_ref, mp_ref, out_ref):
    d = d_ref[...]
    n_frames = q_ref.shape[0]
    acc = None
    for f in range(n_frames):            # static unroll over the GOP depth
        m = mk_ref[...] if f == 0 else mp_ref[...]
        c = q_ref[f].astype(jnp.float32) * m      # dequant (VPU)
        x = jnp.einsum("ji,njk->nik", d, c)       # D^T @ C   (MXU)
        x = jnp.einsum("nik,kl->nil", x, d)       # ...  @ D  (MXU)
        acc = x if acc is None else acc + x       # closed-loop cumsum
        out_ref[f] = acc


def decode_gop_blocks(q: jnp.ndarray, qp: int, *,
                      interpret: bool = False, blk: int = BLK) -> jnp.ndarray:
    """q: [F, M, 8, 8] int16, M % blk == 0 -> reconstructed [F, M, 8, 8] f32."""
    n_frames, m = q.shape[:2]
    assert m % blk == 0, (m, blk)
    return pl.pallas_call(
        _kernel,
        grid=(m // blk,),
        in_specs=[
            pl.BlockSpec((n_frames, blk, 8, 8), lambda i: (0, i, 0, 0)),
            pl.BlockSpec((8, 8), lambda i: (0, 0)),
            pl.BlockSpec((8, 8), lambda i: (0, 0)),
            pl.BlockSpec((8, 8), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n_frames, blk, 8, 8), lambda i: (0, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_frames, m, 8, 8), jnp.float32),
        interpret=interpret,
    )(q, jnp.asarray(dct_matrix()), jnp.asarray(quant_matrix(qp, True)),
      jnp.asarray(quant_matrix(qp, False)))
