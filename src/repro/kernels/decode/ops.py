"""Jit'd public wrapper for the batched multi-tile decode, with the shared
power-of-two size bucketing that bounds jit traces across arbitrary layouts.

Every block-count-shaped entry point (this op, the single-tile DCT/IDCT
ops) pads its stream length to :func:`pad_bucket` — the next power of two —
so the number of distinct compiled shapes grows logarithmically with the
largest batch ever seen instead of linearly with every distinct tile
layout.  Callers that assemble the stream themselves (``codec.batch``)
allocate at the bucket size directly so padding costs nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode.decode import BLK, decode_gop_blocks
from repro.kernels.decode.ref import decode_fused_ref

#: floor for the padded column count — tiny batches share one trace
MIN_COLUMNS = 64


def pad_bucket(n: int, lo: int = 8) -> int:
    """Smallest power of two >= max(n, lo): the shared jit-size bucket.

    Padding every variable block/column count up to a bucket keeps the
    number of distinct jit traces bounded (one per octave) no matter how
    many distinct tile shapes a workload produces."""
    if n <= lo:
        return lo
    return 1 << (int(n) - 1).bit_length()


def use_pallas_default() -> bool:
    """The Pallas kernel path is the default on TPU only; everywhere else
    the jitted jnp fused path (XLA) is both correct and faster."""
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("qp", "use_pallas", "interpret"))
def _decode_fused(q: jnp.ndarray, *, qp: int, use_pallas: bool,
                  interpret: bool) -> jnp.ndarray:
    if use_pallas:
        blk = min(BLK, q.shape[1])
        return decode_gop_blocks(q, qp, interpret=interpret, blk=blk)
    return decode_fused_ref(q, qp)


def decode_fused_op(q: jnp.ndarray, *, qp: int,
                    use_pallas: bool | None = None,
                    interpret: bool = False) -> jnp.ndarray:
    """[F, M, 8, 8] int16 -> [F, M, 8, 8] f32 reconstructed frames.

    Row 0 is dequantized with the intra matrix, rows 1+ with the inter
    matrix, each block IDCT'd, then summed cumulatively over F (the
    closed-loop GOP reconstruction).  Bit-identical to the numpy
    ``decode_tile`` arithmetic per column.

    M is padded to :func:`pad_bucket` columns (zero coefficients decode to
    zero pixels, sliced off before return), F is used as-is — callers
    bucket it (``codec.batch`` pads GOP depth with trailing zero-coefficient
    frames, which never perturb the leading cumulative sums).
    """
    m = q.shape[1]
    mp = pad_bucket(m, lo=MIN_COLUMNS)
    if mp != m:
        q = jnp.concatenate(
            [q, jnp.zeros((q.shape[0], mp - m, 8, 8), q.dtype)], axis=1)
    if use_pallas is None:
        use_pallas = use_pallas_default()
    out = _decode_fused(q, qp=qp, use_pallas=bool(use_pallas),
                        interpret=interpret)
    return out[:, :m]
