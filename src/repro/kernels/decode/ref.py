"""jnp fused reference for the multi-tile batched decode — the XLA path.

This is not just the kernel oracle: on non-TPU backends it IS the batched
decode implementation (one jitted XLA dispatch per size bucket).  Every op
is chosen to be bit-identical to the numpy ``decode_tile`` arithmetic:

- dequant + the two 8x8 IDCT matmuls match ``np.einsum`` bitwise (same
  two-GEMM contraction order);
- the GOP reconstruction uses a *sequential* ``lax.scan`` prefix sum —
  ``jnp.cumsum`` lowers to a log-depth parallel scan whose float
  accumulation order differs from ``np.cumsum``, so it must not be used
  here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.codec.quant import quant_matrix
from repro.codec.transform import dct_matrix


def decode_fused_ref(q: jnp.ndarray, qp: int) -> jnp.ndarray:
    """q: [F, M, 8, 8] int16 (row 0 intra, rows 1+ inter) -> [F, M, 8, 8]
    f32 reconstructed frames (cumulative over F)."""
    n_frames = q.shape[0]
    d = jnp.asarray(dct_matrix())
    mk = jnp.asarray(quant_matrix(qp, True))
    mp = jnp.asarray(quant_matrix(qp, False))
    if n_frames == 1:
        scale = mk[None]
    else:
        scale = jnp.concatenate(
            [mk[None], jnp.broadcast_to(mp, (n_frames - 1, 8, 8))], axis=0)
    c = (q.astype(jnp.float32) * scale[:, None]).reshape(-1, 8, 8)
    x = jnp.einsum("ji,njk->nik", d, c)
    x = jnp.einsum("nik,kl->nil", x, d).reshape(q.shape)
    if n_frames == 1:
        return x

    def step(carry, row):
        s = carry + row
        return s, s

    _, rest = jax.lax.scan(step, x[0], x[1:])
    return jnp.concatenate([x[:1], rest], axis=0)
