from repro.kernels.decode.ops import (MIN_COLUMNS, decode_fused_op,
                                      pad_bucket, use_pallas_default)
from repro.kernels.decode.ref import decode_fused_ref

__all__ = ["decode_fused_op", "decode_fused_ref", "pad_bucket",
           "use_pallas_default", "MIN_COLUMNS"]
