import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf-iteration harness: lower ONE (arch x shape) cell under a named
variant, print the three roofline terms + collective breakdown.

    PYTHONPATH=src python scripts/hillclimb.py --arch olmo-1b \
        --shape train_4k --variant baseline|nosp|...
"""
import argparse
import json

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()

    # variant switches are read inside repro via env
    os.environ["REPRO_VARIANT"] = args.variant

    from repro.configs.base import get_config, get_shape
    from repro.distributed.ctx import (SERVE_RULES_1POD, TRAIN_RULES_1POD)
    from repro.launch.dryrun import run_cell
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    shape = get_shape(args.shape)
    rules = TRAIN_RULES_1POD if shape.kind == "train" else SERVE_RULES_1POD
    row = run_cell(args.arch, args.shape, mesh,
                   "2x16x16" if args.mesh == "multi" else "16x16", rules)
    if row["status"] != "ok":
        print("ERROR:", row.get("error"))
        print(row.get("traceback", "")[-2000:])
        return
    t = row["roofline"]
    print(f"VARIANT {args.variant}: dominant={t['dominant']}")
    print(f"  compute_s    = {t['compute_s']:.4e}")
    print(f"  memory_s     = {t['memory_s']:.4e}")
    print(f"  collective_s = {t['collective_s']:.4e}")
    print(f"  useful_ratio = {t['useful_ratio']:.3f}")
    print(f"  GB/dev       = {row['memory']['total_device_bytes'] / 1e9:.2f}"
          f"  fits={row['fits_hbm']}")
    c = row["collectives"]
    for k, v in sorted(c["bytes_by_kind"].items(), key=lambda kv: -kv[1]):
        print(f"  {k:20s} {v / 1e9:10.2f} GB/chip (ops={c['count_by_kind'].get(k)})")
    out = json.dumps({"variant": args.variant, **{k: row[k] for k in
                     ("arch", "shape", "roofline", "collectives")}})
    path = f"results/hillclimb_{args.arch}_{args.shape}.jsonl"
    with open(path, "a") as f:
        f.write(out + "\n")


if __name__ == "__main__":
    main()
