#!/usr/bin/env python
"""Cluster smoke: 3 ``tasm_serve.py`` nodes behind one ``tasm_router.py``,
two concurrent client PROCESSES, and a node killed mid-workload.  Asserts
the distributed-serving contract end to end, across real process
boundaries:

- both clients' results are bit-identical to an in-process ``execute()``
  of the same scans on an identically-built local store;
- with ``--replication 2``, SIGKILLing one node while a client is
  mid-workload loses NO reads — every remaining iteration still returns
  bit-identical results (the router fails reads over to the surviving
  replica);
- the router reports the killed node down, and SIGTERM shuts router and
  nodes down cleanly (exit 0, socket files gone).

Exits non-zero on any violation — this is the CI cluster-smoke step::

    python scripts/cluster_smoke.py

The script doubles as its own client: ``cluster_smoke.py --client SOCK
OUT [ITERS SLEEP]`` connects to the router, runs the canonical workload
``ITERS`` times (sleeping ``SLEEP`` seconds between iterations), and
writes results to ``OUT.npz`` + ``OUT.json`` for the parent to compare.
"""
from __future__ import annotations

import json
import os
import pathlib
import signal
import socket
import subprocess
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.codec.encode import EncoderConfig  # noqa: E402
from repro.core import (ClusterClient, NoTilingPolicy,  # noqa: E402
                        VideoStore)
from repro.data.video_gen import generate, sparse_spec  # noqa: E402

ENC = EncoderConfig(gop=16, qp=8)
N_FRAMES, H, W = 32, 96, 160
VIDEOS = ["cam0", "cam1", "cam2", "cam3"]
#: the canonical workload: per-video windows over two labels
WORKLOAD = [(v, label, rng) for v in VIDEOS
            for label, rng in (("car", (0, 32)), ("person", (8, 24)))]


def corpus():
    return {v: generate(sparse_spec(seed=i, n_frames=N_FRAMES, height=H,
                                    width=W))
            for i, v in enumerate(VIDEOS)}


def run_workload(store):
    return [store.scan(v).labels(label).frames(*rng).execute()
            for v, label, rng in WORKLOAD]


# --------------------------------------------------------------- client
def client_main(sock_path: str, out: str, iters: str = "1",
                sleep_s: str = "0") -> int:
    with ClusterClient(sock_path) as cli:
        waves = []
        for _ in range(int(iters)):
            waves.append(run_workload(cli))
            time.sleep(float(sleep_s))
    arrays, meta = {}, []
    for w, results in enumerate(waves):
        wave_meta = []
        for i, r in enumerate(results):
            regs = []
            for j, (f, box, px) in enumerate(r.regions):
                arrays[f"px_{w}_{i}_{j}"] = px
                regs.append([f, list(box)])
            wave_meta.append(regs)
        meta.append(wave_meta)
    np.savez(out + ".npz", **arrays)
    pathlib.Path(out + ".json").write_text(json.dumps(meta))
    return 0


def load_client(out: str):
    meta = json.loads(pathlib.Path(out + ".json").read_text())
    npz = np.load(out + ".npz")
    return [[[(f, tuple(box), npz[f"px_{w}_{i}_{j}"])
              for j, (f, box) in enumerate(regs)]
             for i, regs in enumerate(wave)]
            for w, wave in enumerate(meta)]


def assert_same_regions(a, b, where: str) -> None:
    assert len(a) == len(b), f"{where}: {len(a)} vs {len(b)} regions"
    for ra, rb in zip(a, b):
        assert ra[:-1] == rb[:-1], f"{where}: region keys diverge"
        if not np.array_equal(ra[-1], rb[-1]):
            raise AssertionError(f"{where}: pixels not bit-identical at "
                                 f"frame {ra[0]}")


def assert_wave_matches(wave, reference, where: str) -> None:
    assert len(wave) == len(reference), f"{where}: workload length"
    for q, (got, ref) in enumerate(zip(wave, reference)):
        assert_same_regions(ref.regions, got, f"{where} query {q}")


# --------------------------------------------------------------- parent
def wait_for_socket(path: str, proc, timeout: float = 60.0) -> None:
    deadline = time.time() + timeout
    while time.time() < deadline:
        if proc.poll() is not None:
            raise RuntimeError(f"server died early (rc={proc.returncode})")
        if os.path.exists(path):
            s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            try:
                s.connect(path)
                return
            except OSError:
                pass
            finally:
                s.close()
        time.sleep(0.05)
    raise RuntimeError(f"socket {path} never came up")


def main() -> int:
    if len(sys.argv) > 1 and sys.argv[1] == "--client":
        return client_main(*sys.argv[2:])

    tmp = tempfile.mkdtemp(prefix="tasm_cluster_smoke_")
    here = os.path.dirname(os.path.abspath(__file__))
    node_socks = [os.path.join(tmp, f"n{i}.sock") for i in range(3)]
    router_sock = os.path.join(tmp, "router.sock")
    nodes = [subprocess.Popen(
        [sys.executable, os.path.join(here, "tasm_serve.py"),
         "--socket", sock]) for sock in node_socks]
    router = None
    try:
        for sock, proc in zip(node_socks, nodes):
            wait_for_socket(sock, proc)
        router = subprocess.Popen(
            [sys.executable, os.path.join(here, "tasm_router.py"),
             "--socket", router_sock, "--replication", "2",
             "--placement", os.path.join(tmp, "placement.json")]
            + [a for i, sock in enumerate(node_socks)
               for a in ("--node", f"n{i}={sock}")])
        wait_for_socket(router_sock, router)
        videos = corpus()

        # seed the cluster through the router, and build the in-process
        # reference store identically (encode is deterministic)
        local = VideoStore()
        with ClusterClient(router_sock) as seed:
            for name, (frames, dets) in videos.items():
                for store in (seed, local):
                    store.add_video(name, encoder=ENC,
                                    policy=NoTilingPolicy())
                    store.ingest(name, frames)
                    store.add_detections(name,
                                         {f: d for f, d in enumerate(dets)})
            placement = seed.placement()["assignments"]
        reference = run_workload(local)
        local.close()

        # two concurrent client processes over one router
        outs = [os.path.join(tmp, f"client{i}") for i in (1, 2)]
        clients = [subprocess.Popen(
            [sys.executable, os.path.abspath(__file__), "--client",
             router_sock, out]) for out in outs]
        rcs = [c.wait(timeout=300) for c in clients]
        assert rcs == [0, 0], f"client exit codes {rcs}"
        got = [load_client(out)[0] for out in outs]
        assert_wave_matches(got[0], reference, "client1 vs local")
        assert_wave_matches(got[1], reference, "client2 vs local")
        print(f"# two concurrent clients bit-identical to in-process "
              f"execute ({sum(len(r) for r in got[0])} regions)")

        # kill cam0's PRIMARY mid-workload: a third client iterates the
        # workload; with K=2 every video keeps a live replica, so every
        # wave — before, during, and after the kill — must stay
        # bit-identical
        victim = int(placement["cam0"][0][1:])  # "n2" -> index 2
        out3 = os.path.join(tmp, "client3")
        killer = subprocess.Popen(
            [sys.executable, os.path.abspath(__file__), "--client",
             router_sock, out3, "6", "0.2"])
        time.sleep(0.6)  # a couple of waves in
        nodes[victim].send_signal(signal.SIGKILL)
        nodes[victim].wait(timeout=30)
        rc = killer.wait(timeout=300)
        assert rc == 0, f"mid-kill client exit code {rc}"
        waves = load_client(out3)
        assert len(waves) == 6
        for w, wave in enumerate(waves):
            assert_wave_matches(wave, reference,
                                f"wave {w} (node n{victim} killed)")
        with ClusterClient(router_sock) as probe:
            health = probe.node_health()
            assert health[f"n{victim}"] is False, health
            assert sum(1 for ok in health.values() if ok) == 2, health
        print(f"# killed n{victim} mid-workload: 6/6 waves bit-identical, "
              f"router reports it down")

        # clean shutdown: SIGTERM -> exit 0, sockets unlinked
        router.send_signal(signal.SIGTERM)
        rc = router.wait(timeout=60)
        assert rc == 0, f"router exit code {rc}"
        assert not os.path.exists(router_sock), "router socket left behind"
        for i, proc in enumerate(nodes):
            if i == victim:
                continue
            proc.send_signal(signal.SIGTERM)
            rc = proc.wait(timeout=60)
            assert rc == 0, f"node n{i} exit code {rc}"
        print("# clean shutdown: router and surviving nodes exit 0")
        print("cluster_smoke,0.0,ok")
        return 0
    finally:
        for proc in ([router] if router else []) + nodes:
            if proc.poll() is None:
                proc.kill()
                proc.wait(timeout=30)


if __name__ == "__main__":
    raise SystemExit(main())
